#!/usr/bin/env python
"""Regenerate the golden-token fixtures for tests/test_golden_tokens.py.

Runs one tiny model per architecture family through the serving engine
at temperature 0 and records the greedy tokens.  The fixtures pin the
*numerics* of the whole serve path — model forward, paged/dense KV
bookkeeping, fused decode sampling — so an innocent-looking refactor
that shifts logits shows up as a token diff, not a silent accuracy drop.

Only rerun this when an intentional change breaks the tokens, and say so
in the commit that updates the fixture:

    PYTHONPATH=src python tools/regen_goldens.py

Keep everything here deterministic: fixed PRNG seeds, fixed prompts
derived from a seeded generator, float32 params (bf16 matmul order is
the first thing a jax upgrade reshuffles), greedy sampling.
"""
import json
import os
import sys
from pathlib import Path

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax                                                  # noqa: E402
import jax.numpy as jnp                                     # noqa: E402
import numpy as np                                          # noqa: E402

from repro.configs import get_config, scaled_down           # noqa: E402
from repro.finetune.lora import (LoraConfig, lora_init,     # noqa: E402
                                 lora_randomize)
from repro.models import model as M                         # noqa: E402
from repro.serving.adapters import supports_multi_lora      # noqa: E402
from repro.serving.engine import InferenceEngine, Request   # noqa: E402

OUT = Path(__file__).resolve().parent.parent / "tests" / "golden" / \
    "golden_tokens.json"

# one representative per serving-relevant architecture family; the
# scaled_down defaults keep each family's distinguishing machinery
# (GQA heads, MLA latent + MoE routing, SSM state, hybrid block period)
FAMILIES = {
    "gqa": "qwen1.5-4b",
    "mla_moe": "deepseek-v2-lite-16b",
    "ssm": "mamba2-1.3b",
    "hybrid_moe": "jamba-v0.1-52b",
}
MAX_NEW = 10
SPEC_K = 3
LORA_RANK = 4


def prompts_for(vocab: int, family: str):
    # no hash(): it is salted per-process; this seed is stable forever
    rng = np.random.default_rng(sum(ord(c) for c in family))
    return [[int(x) for x in rng.integers(1, vocab - 1, n)]
            for n in (5, 9, 14)]


def spec_prompts_for(vocab: int, family: str):
    # repetitive (pattern * 3 + tail) so the n-gram drafter actually
    # finds suffix matches and the acceptance path runs for real
    rng = np.random.default_rng(1 + sum(ord(c) for c in family))
    pat = [int(x) for x in rng.integers(1, vocab - 1, 5)]
    return [pat * 3 + [int(x) for x in rng.integers(1, vocab - 1, 2)]
            for _ in range(3)]


def golden_adapter(params):
    lcfg = LoraConfig(rank=LORA_RANK)
    return lora_randomize(lora_init(params, lcfg, jax.random.PRNGKey(1)),
                          jax.random.PRNGKey(2)), lcfg


def run_engine(cfg, params, prompts, adapter=None, **kw):
    eng = InferenceEngine(cfg, params, max_batch=4, capacity=128, **kw)
    if adapter is not None:
        ad, lcfg = golden_adapter(params)
        eng.register_adapter(adapter, ad, lcfg)
    reqs = [Request(prompt=list(p), max_new_tokens=MAX_NEW,
                    adapter=adapter or "") for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    return [r.generated for r in reqs], eng


def generate(family: str, arch: str):
    cfg = scaled_down(get_config(arch))
    params = M.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    prompts = prompts_for(cfg.vocab_size, family)
    generated, eng = run_engine(cfg, params, prompts)
    g = {
        "arch": arch,
        "paged": bool(eng.paged),
        "prompts": prompts,
        "generated": generated,
    }
    if M.supports_speculative(cfg):
        # one greedy token stream pins all three decode paths: the
        # fixture stores the plain engine's output and regen *verifies*
        # that both speculative drafters reproduce it exactly
        sp = spec_prompts_for(cfg.vocab_size, family)
        want, _ = run_engine(cfg, params, sp)
        for kind, kw in (("ngram", {}),
                         ("draft", {"draft_cfg": cfg,
                                    "draft_params": params})):
            got, _ = run_engine(cfg, params, sp, speculative=kind,
                                spec_k=SPEC_K, **kw)
            assert got == want, f"{family}: spec({kind}) != plain"
        g["spec_prompts"] = sp
        g["spec_generated"] = want
    if supports_multi_lora(cfg):
        got, _ = run_engine(cfg, params, prompts, adapter="golden",
                            adapter_slots=2)
        assert got != generated, f"{family}: adapter was a no-op"
        g["lora_rank"] = LORA_RANK
        g["lora_generated"] = got
    return g


def main():
    golden = {fam: generate(fam, arch) for fam, arch in FAMILIES.items()}
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(golden, indent=1) + "\n")
    for fam, g in golden.items():
        print(f"{fam:>12} ({g['arch']}, paged={g['paged']}): "
              f"{g['generated']}")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
