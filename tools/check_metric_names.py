#!/usr/bin/env python3
"""Metric-name lint for the observability layer (CI `docs` job; no deps
beyond the repo itself — ``repro.obs.registry`` imports no jax).

Scans ``src/`` and ``benchmarks/`` for string-literal metric
registrations — ``.counter("...")``, ``.gauge("...")``,
``.histogram("...")`` — and validates every name against the repo
convention enforced by :func:`repro.obs.registry.validate_metric_name`:

- ``repro_<subsystem>_<name>_<unit>`` with a known unit suffix
  (``_seconds``, ``_tokens``, ``_blocks``, ``_ratio``, ...);
- counters additionally end in ``_total``;
- gauges and histograms must NOT end in ``_total`` (that suffix is the
  Prometheus marker for monotonic series).

    python tools/check_metric_names.py [roots...]
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.obs.registry import validate_metric_name  # noqa: E402

# `reg.counter(\n    "name"` — the name literal is the first string
# argument, in either quote style, any amount of whitespace/newlines
# between the paren and the literal
CALL_RE = re.compile(
    r"\.(counter|gauge|histogram)\(\s*([\"'])([^\"']+)\2")


def scan_file(path: str) -> list:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    errors = []
    for m in CALL_RE.finditer(text):
        kind, name = m.group(1), m.group(3)
        err = validate_metric_name(name, kind)
        if err is not None:
            line = text.count("\n", 0, m.start()) + 1
            errors.append(f"{os.path.relpath(path, REPO)}:{line}: "
                          f"{kind} {name!r}: {err}")
    return errors


def main(argv: list) -> int:
    roots = argv or [os.path.join(REPO, "src"),
                     os.path.join(REPO, "benchmarks")]
    files = []
    for root in roots:
        if os.path.isfile(root):
            files.append(root)
            continue
        for dirpath, _, names in os.walk(root):
            files.extend(os.path.join(dirpath, n) for n in names
                         if n.endswith(".py"))
    errors, n_names = [], 0
    for f in sorted(files):
        with open(f, encoding="utf-8") as fh:
            n_names += len(CALL_RE.findall(fh.read()))
        errors.extend(scan_file(f))
    if errors:
        print("\n".join(errors))
        print(f"\n{len(errors)} bad metric name(s)")
        return 1
    print(f"ok: {n_names} metric registration(s) in {len(files)} "
          f"file(s) follow repro_<subsystem>_<name>_<unit>")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
