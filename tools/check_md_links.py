#!/usr/bin/env python3
"""Markdown link checker for the docs tree (CI `docs` job; no deps).

Checks every relative link target in the given markdown files (default:
root README.md, docs/**/*.md, and every */README.md in the repo)
resolves to an existing file or directory, and that anchors — both
pure in-page ``#section`` links and ``file.md#section`` fragments on
relative links to markdown files — name a real heading in the target
file (GitHub slug rules: lowercase, punctuation dropped, spaces to
dashes).  External (http/https/mailto) links are skipped.

    python tools/check_md_links.py [files...]
"""
from __future__ import annotations

import glob
import os
import re
import sys

# inline links/images: [text](target) — tolerates one level of nested
# brackets in the text; reference-style links are not used in this repo
LINK_RE = re.compile(r"!?\[(?:[^\[\]]|\[[^\]]*\])*\]\(([^()\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.M)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown emphasis/code markers,
    lowercase, drop punctuation, spaces -> dashes."""
    h = re.sub(r"[`*_]", "", heading.strip())
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", h)   # linked headings
    h = h.lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def heading_slugs(path: str) -> set:
    try:
        with open(path, encoding="utf-8") as f:
            text = f.read()
    except OSError:
        return set()
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return {github_slug(m.group(1)) for m in HEADING_RE.finditer(text)}


def default_files(root: str) -> list:
    files = []
    for pat in ("README.md", "docs/**/*.md", "**/README.md"):
        files.extend(glob.glob(os.path.join(root, pat), recursive=True))
    return sorted({os.path.abspath(f) for f in files})


def check_file(path: str) -> list:
    errors = []
    with open(path, encoding="utf-8") as f:
        text = f.read()
    # fenced code blocks may contain bracketed indexing that is not a link
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        rel, _, anchor = target.partition("#")
        if not rel:
            # in-page anchor: must name a heading in THIS file
            if anchor and github_slug(anchor) not in heading_slugs(path):
                errors.append(f"{os.path.relpath(path)}: broken anchor "
                              f"'#{anchor}' (no such heading)")
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            errors.append(f"{os.path.relpath(path)}: broken link "
                          f"'{target}' -> {os.path.relpath(resolved)}")
        elif anchor and resolved.endswith(".md") \
                and github_slug(anchor) not in heading_slugs(resolved):
            errors.append(f"{os.path.relpath(path)}: broken anchor "
                          f"'{target}' (no heading '#{anchor}' in "
                          f"{os.path.relpath(resolved)})")
    return errors


def main(argv: list) -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = ([os.path.abspath(a) for a in argv] if argv
             else default_files(root))
    errors = []
    for f in files:
        errors.extend(check_file(f))
    if errors:
        print("\n".join(errors))
        print(f"\n{len(errors)} broken link(s) in {len(files)} file(s)")
        return 1
    print(f"ok: {len(files)} markdown file(s), all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
