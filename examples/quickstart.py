"""Quickstart: pre-train a tiny LM on the synthetic corpus with the
fault-tolerant trainer, checkpoint it, and serve a few requests through
the continuous-batching engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import shutil

import jax
import jax.numpy as jnp

from repro.configs import get_config, scaled_down
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request
from repro.training.optimizer import OptConfig
from repro.training.trainer import Trainer, TrainerConfig

CKPT = "/tmp/repro_quickstart"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = scaled_down(get_config("apertus-8b"), num_layers=4, d_model=128,
                      d_ff=256, vocab_size=512, num_heads=4,
                      num_kv_heads=2, head_dim=32)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=16))
    print(f"== pre-training {cfg.name}-tiny "
          f"({cfg.param_count():,} params) ==")
    tr = Trainer(cfg, OptConfig(lr=3e-3), data,
                 TrainerConfig(num_steps=60, ckpt_every=20, ckpt_dir=CKPT,
                               log_every=10))
    res = tr.run()
    for m in res["log"]:
        print(f"  step {m['step']:4d}  loss {m['loss']:.3f}  "
              f"acc {m['accuracy']:.3f}")

    print("== serving ==")
    eng = InferenceEngine(cfg, tr.params, max_batch=4, capacity=128)
    reqs = [Request(prompt=[7, 8, 9, 10], max_new_tokens=12),
            Request(prompt=[100, 101], max_new_tokens=12,
                    temperature=0.7, top_k=20)]
    for r in reqs:
        eng.submit(r)
    summary = eng.run_until_idle()
    for r in reqs:
        print(f"  prompt={r.prompt} -> {r.generated}")
    print("  metrics:", {k: round(v, 4) for k, v in summary.items()})


if __name__ == "__main__":
    main()
