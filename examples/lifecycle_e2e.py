"""End-to-end driver for the paper's Fig. 1 lifecycle:

  data prep -> pre-train (batch plane, a few hundred steps, with a mid-run
  simulated node failure + checkpoint/restart) -> SFT (LoRA recipe) ->
  alignment (LoRA-DPO) -> capability/safety eval gates -> release
  optimization (int8) -> publish to registry -> deploy on the service
  plane -> serve through the governed gateway.

    PYTHONPATH=src python examples/lifecycle_e2e.py
"""
import shutil

import jax
import jax.numpy as jnp

from repro.configs import get_config, scaled_down
from repro.core.cluster import Cluster, NodeKind
from repro.core.gateway import Gateway, ModelEntry
from repro.core.lifecycle import LifecyclePipeline, Stage, StageResult
from repro.core.planes import DeploymentSpec, ServicePlane
from repro.core.registry import ArtifactRegistry
from repro.data.mixtures import Mixture, SourceSpec
from repro.data.pipeline import (DataConfig, PreferenceDataset, SFTDataset,
                                 SyntheticLM)
from repro.finetune.dpo import make_lora_dpo_step
from repro.finetune.evals import CapabilityGuard, evaluate
from repro.finetune.lora import lora_init, lora_merge
from repro.finetune.quantize import quantize_tree, quantized_bytes
from repro.finetune.recipes import resolve
from repro.finetune.sft import make_lora_sft_step, publish_adapter
from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request
from repro.training.optimizer import OptConfig, opt_init
from repro.training.trainer import (SimulatedNodeFailure, Trainer,
                                    TrainerConfig)

CKPT = "/tmp/repro_lifecycle"
PRETRAIN_STEPS = 200


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = scaled_down(get_config("apertus-8b"), num_layers=4, d_model=128,
                      d_ff=256, vocab_size=512, num_heads=4,
                      num_kv_heads=2, head_dim=32)
    print(f"model: {cfg.name}-tiny, {cfg.param_count():,} params")
    registry = ArtifactRegistry()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=16)
    guard = CapabilityGuard(cfg, SyntheticLM(dc), tolerance=0.5, steps=2)

    def stage_data(ctx):
        mix = Mixture([(SourceSpec("web", 0.8, "dedup_rows"),
                        SyntheticLM(dc)),
                       (SourceSpec("curated", 0.2), SyntheticLM(dc))],
                      seed=3)
        ctx.state["mixture"] = mix
        aid = ctx.register("data", "dataset",
                           f"mixture:{mix.recipe_hash()}")
        return StageResult("data", aid, {"hash": mix.recipe_hash()})

    def stage_pretrain(ctx):
        fails = {77}  # a node dies mid-run; requeue + restore handles it

        def inject(step):
            if step in fails:
                fails.discard(step)
                raise SimulatedNodeFailure(step)

        tr = Trainer(cfg, OptConfig(lr=3e-3), ctx.state["mixture"],
                     TrainerConfig(num_steps=PRETRAIN_STEPS, ckpt_every=50,
                                   ckpt_dir=CKPT, log_every=50),
                     failure_injector=inject)
        res = tr.run()
        print(f"  pretrain: {PRETRAIN_STEPS} steps, "
              f"{res['restarts']} restart(s), "
              f"loss {res['log'][0]['loss']:.3f} -> "
              f"{res['log'][-1]['loss']:.3f}")
        ctx.state["base"] = tr.params
        guard.snapshot(tr.params)
        aid = ctx.register("pretrain", "checkpoint", CKPT,
                           parent_stages=["data"], size_bytes=1 << 20)
        return StageResult("pretrain", aid,
                           {"restarts": res["restarts"]},
                           passed=res["log"][-1]["loss"]
                           < res["log"][0]["loss"])

    def stage_sft(ctx):
        base = ctx.state["base"]
        _, lcfg, opt, extra = resolve("sft_lora_safe", cfg, {"rank": 8})
        import dataclasses
        opt = dataclasses.replace(opt, lr=1.5e-3)
        ad = lora_init(base, lcfg, jax.random.PRNGKey(1))
        step = jax.jit(make_lora_sft_step(cfg, opt, base, lcfg))
        st = opt_init(opt, ad)
        # "safe-by-default" anti-forgetting: interleave base-distribution
        # replay batches with the SFT stream (3:2), exactly the recipe
        # calibration §4.3 motivates — without it this stage pushes base
        # perplexity up >100x and the eval gate aborts the pipeline.
        sft = SFTDataset(dc, prompt_len=8)
        replay = SyntheticLM(dc)
        first = last = None
        for i in range(40):
            src = sft if i % 5 < 3 else replay
            off = 0 if src is sft else 500_000
            b = {k: jnp.asarray(v) for k, v in src.batch(i + off).items()}
            ad, st, m = step(ad, st, b)
            if src is sft:
                first = first if first is not None else float(m["loss"])
                last = float(m["loss"])
        print(f"  sft (with replay): style loss {first:.3f} -> {last:.3f}")
        ctx.state["sft_adapters"], ctx.state["lcfg"] = ad, lcfg
        aid = ctx.register("sft", "adapter", "adapters/sft-v1",
                           parent_stages=["pretrain"])
        return StageResult("sft", aid, {"loss": last}, passed=last < first)

    def stage_align(ctx):
        base = ctx.state["base"]
        lcfg = ctx.state["lcfg"]
        opt = OptConfig(lr=3e-4, weight_decay=0.0)
        # continue from the SFT adapters
        ad = ctx.state["sft_adapters"]
        step = jax.jit(make_lora_dpo_step(cfg, opt, base, lcfg))
        st = opt_init(opt, ad)
        pref = PreferenceDataset(dc, prompt_len=8)
        acc = 0.0
        for i in range(12):
            pb = pref.batch(i)
            pb = {kk: {k: jnp.asarray(v) for k, v in d.items()}
                  for kk, d in pb.items()}
            ad, st, m = step(ad, st, pb)
            acc = float(m["preference_accuracy"])
        print(f"  align (DPO): preference accuracy {acc:.2f}")
        ctx.state["adapters"] = ad
        ctx.state["aligned"] = lora_merge(base, ad, lcfg)
        aid = ctx.register("align", "adapter", "adapters/dpo-v1",
                           parent_stages=["sft"])
        return StageResult("align", aid, {"pref_acc": acc},
                           passed=acc >= 0.75)

    def stage_eval(ctx):
        check = guard.check(ctx.state["aligned"])
        print(f"  eval gate: base-ppl regression {check['ppl_regression']:+.2%} "
              f"(tolerance 50%) passed={check['passed']}")
        aid = ctx.register("eval", "eval", "evals/guard-v1",
                           parent_stages=["align"])
        return StageResult("eval", aid, check, passed=check["passed"])

    def stage_release(ctx):
        q = quantize_tree(ctx.state["aligned"])
        before = sum(x.size * 4 for x in jax.tree.leaves(
            ctx.state["aligned"]))
        after = quantized_bytes(q)
        print(f"  release: int8 quantization {before/1e6:.1f}MB -> "
              f"{after/1e6:.1f}MB")
        # publish the quantized artifact itself — deploy hands it to the
        # engine, which detects the layout and dequantizes at param load
        ctx.state["released"] = q
        aid = ctx.register("release", "model", "models/tiny-v1-int8",
                           parent_stages=["align", "eval"],
                           size_bytes=after)
        ctx.registry.pin(aid)
        return StageResult("release", aid,
                           {"compression": before / after})

    def stage_deploy(ctx):
        cluster = Cluster()
        cluster.add_nodes("nid", 2, NodeKind.HPC)
        cluster.add_nodes("vm", 1, NodeKind.COMMODITY)
        sp = ServicePlane(cluster)
        engines = []

        def factory(node):
            e = InferenceEngine(cfg, ctx.state["released"], max_batch=2,
                                capacity=96, name=f"eng-{node}")
            engines.append(e)
            return e

        sp.apply(DeploymentSpec("tiny-v1", 1, NodeKind.HPC,
                                factory=factory))
        sp.reconcile()
        gw = Gateway()
        gw.vet_model(ModelEntry("tiny-v1", cfg.name, 0.1, 0.3), cfg)
        gw.bind_endpoints("tiny-v1", engines)
        key = gw.mint_key("pilot-user", budget_usd=1.0)
        out = gw.completion(api_key=key.key, model="tiny-v1",
                            prompt=[3, 5, 7, 11], max_tokens=12)
        print(f"  deployed + served: {out['tokens']}")

        # multi-tenant alternative: the same fine-tune served as a LoRA
        # adapter over the *base* weights (no merge, no per-tenant
        # replica) — registered into the engine's adapter pool and
        # addressed as model@adapter through the gateway.  Must match
        # the merged-weights route token-for-token.
        mt = InferenceEngine(cfg, ctx.state["base"], max_batch=2,
                             capacity=96, name="eng-multi",
                             adapter_slots=2)
        publish_adapter(mt, "dpo-v1", ctx.state["adapters"],
                        ctx.state["lcfg"])
        gw.vet_model(ModelEntry("tiny-v1-lora", cfg.name, 0.1, 0.3), cfg)
        gw.bind_endpoints("tiny-v1-lora", [mt])
        gw.own_adapter("dpo-v1", "pilot-user")   # tenant-private fine-tune
        out_ad = gw.completion(api_key=key.key,
                               model="tiny-v1-lora@dpo-v1",
                               prompt=[3, 5, 7, 11], max_tokens=12)
        merged_eng = InferenceEngine(cfg, ctx.state["aligned"],
                                     max_batch=2, capacity=96,
                                     name="eng-merged")
        ref = Request(prompt=[3, 5, 7, 11], max_new_tokens=12)
        merged_eng.submit(ref)
        merged_eng.run_until_idle()
        same = out_ad["tokens"] == ref.generated
        print(f"  multi-LoRA serve (tiny-v1-lora@dpo-v1): "
              f"{out_ad['tokens']} merged-route-identical={same}")
        print(f"  usage by adapter: {gw.usage_by_adapter()}")
        aid = ctx.register("deploy", "model", "endpoints/tiny-v1",
                           parent_stages=["release"])
        return StageResult("deploy", aid,
                           {"served": len(out["tokens"]),
                            "adapter_route_identical": same},
                           passed=len(out["tokens"]) == 12 and same)

    pipe = LifecyclePipeline(
        [Stage("data", stage_data), Stage("pretrain", stage_pretrain),
         Stage("sft", stage_sft), Stage("align", stage_align),
         Stage("eval", stage_eval), Stage("release", stage_release),
         Stage("deploy", stage_deploy)], registry)
    history = pipe.run()

    print("\n== lifecycle summary ==")
    for h in history:
        print(f"  {h.stage:9s} artifact={h.artifact_id} passed={h.passed}")
    deploy_id = pipe.ctx.artifacts["deploy"]
    chain = " -> ".join(a.artifact_id
                        for a in registry.lineage(deploy_id))
    print(f"  provenance: {chain} -> {deploy_id}")
    print(f"  storage by kind: {registry.storage_by_kind()}")


if __name__ == "__main__":
    main()
