"""The "one-click" fine-tuning flow (paper §4.3): a service-plane client
picks a curated recipe from the catalog, the FirecREST-style bridge
submits it to the batch plane, and the capability guard gates the result.

    PYTHONPATH=src python examples/finetune_lora.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, scaled_down
from repro.core.bridge import PlaneBridge
from repro.core.cluster import Cluster, NodeKind
from repro.core.planes import BatchPlane
from repro.data.pipeline import DataConfig, SFTDataset, SyntheticLM
from repro.finetune.evals import CapabilityGuard
from repro.finetune.lora import lora_init, lora_merge, lora_param_count
from repro.finetune.recipes import CATALOG, resolve
from repro.finetune.sft import make_lora_sft_step
from repro.models import model as M
from repro.training.optimizer import opt_init


def main():
    cfg = scaled_down(get_config("qwen1.5-4b"), num_layers=2, d_model=64,
                      d_ff=128, vocab_size=128, num_heads=4,
                      num_kv_heads=2, head_dim=16)
    base = M.init(cfg, jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    guard = CapabilityGuard(cfg, SyntheticLM(dc), tolerance=0.5, steps=2)
    guard.snapshot(base)

    print("== recipe catalog ==")
    for name, r in CATALOG.items():
        print(f"  {name:18s} [{r.tier:9s}] {r.description}")

    def recipe_runner(script, params, job):
        recipe, lcfg, opt, extra = resolve(script, cfg, params)
        import dataclasses
        opt = dataclasses.replace(opt, lr=3e-3)  # tiny-model scale
        ad = lora_init(base, lcfg, jax.random.PRNGKey(1))
        print(f"  [batch-plane] {job.name}: LoRA r={lcfg.rank} "
              f"targets={sorted(lcfg.targets)} "
              f"({lora_param_count(ad):,} adapter params)")
        step = jax.jit(make_lora_sft_step(cfg, opt, base, lcfg))
        st = opt_init(opt, ad)
        sft = SFTDataset(dc, prompt_len=8)
        for i in range(int(extra.get("steps", 20))):
            b = {k: jnp.asarray(v) for k, v in sft.batch(i).items()}
            ad, st, m = step(ad, st, b)
        merged = lora_merge(base, ad, lcfg)
        check = guard.check(merged)
        return {"final_loss": float(m["loss"]), "guard": check}

    cluster = Cluster()
    cluster.add_nodes("nid", 2, NodeKind.HPC)
    batch = BatchPlane(cluster)
    bridge = PlaneBridge(batch, recipe_runner,
                         allowed_scripts=[n for n, r in CATALOG.items()
                                          if r.tier == "one-click"])

    print("== one-click submission via bridge ==")
    resp = bridge.submit(script="sft_lora_safe",
                         params={"rank": 8, "steps": 25}, nodes=1,
                         tenant="sme-weather")
    batch.tick()
    status = bridge.status(resp.job_id)
    result = bridge.result(resp.job_id)
    print(f"  job {resp.job_id}: {status['state']}")
    print(f"  final SFT loss: {result['final_loss']:.3f}")
    g = result["guard"]
    print(f"  capability guard: regression={g['ppl_regression']:+.3%} "
          f"passed={g['passed']}")

    print("== expert script outside the catalog is rejected ==")
    try:
        bridge.submit(script="sft_full_expert", params={}, nodes=1,
                      tenant="sme-weather")
    except PermissionError as e:
        print(f"  rejected: {e}")


if __name__ == "__main__":
    main()
