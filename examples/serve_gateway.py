"""Managed inference service (paper §4.4 + §6.2 + §6.3): deploy engine
replicas on HPC nodes via the service plane, govern access through the
gateway (keys/budgets/rate limits), scale elastically under load, and
fail over across active-active sites.

    PYTHONPATH=src python examples/serve_gateway.py
"""
import itertools

import jax

from repro.configs import get_config, scaled_down
from repro.core.cluster import Cluster, NodeKind
from repro.core.elastic import ElasticController, ElasticPolicy
from repro.core.gateway import Gateway, ModelEntry, RateLimited
from repro.core.ha import ClusterMesh, Site
from repro.core.planes import DeploymentSpec, ServicePlane
from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request


def main():
    cfg = scaled_down(get_config("apertus-8b"), num_layers=2, d_model=64,
                      d_ff=128, vocab_size=256, num_heads=2,
                      num_kv_heads=2, head_dim=32)
    params = M.init(cfg, jax.random.PRNGKey(0))

    cluster = Cluster()
    cluster.add_nodes("nid", 4, NodeKind.HPC)
    cluster.add_nodes("vm", 2, NodeKind.COMMODITY)
    sp = ServicePlane(cluster)
    engines = []

    def factory(node):
        e = InferenceEngine(cfg, params, max_batch=2, capacity=96,
                            name=f"eng-{node}")
        engines.append(e)
        return e

    sp.apply(DeploymentSpec("apertus-tiny", 1, NodeKind.HPC,
                            factory=factory))
    sp.reconcile()

    gw = Gateway()
    gw.vet_model(ModelEntry("apertus-tiny", cfg.name, 0.5, 1.5, hot=True),
                 cfg, reserved_failover_gb=1.0)
    gw.bind_endpoints("apertus-tiny", engines)
    key = gw.mint_key("public-ai", budget_usd=5.0, rate_limit_per_min=120)

    print("== governed completions ==")
    out = gw.completion(api_key=key.key, model="apertus-tiny",
                        prompt=[5, 6, 7], max_tokens=8)
    print(f"  tokens: {out['tokens']}  cost=${out['usage']['cost_usd']:.5f}")
    print(f"  project usage: {gw.usage_by_project()}")

    print("== elastic scale-out under queue pressure (§6.2) ==")
    def load():
        return {"queue": sum(len(e.queue) for e in engines),
                "active": sum(len(e.running) for e in engines),
                "capacity": 2}
    ec = ElasticController(cluster, sp, "apertus-tiny",
                           ElasticPolicy(patience=2, max_replicas=3),
                           load)
    # swamp the single replica
    for i in range(30):
        engines[0].submit(Request(prompt=[1, 2, i % 100],
                                  max_new_tokens=4))
    for tick in range(6):
        d = ec.tick()
        if d:
            print(f"  tick {tick}: {d} "
                  f"(service nodes: "
                  f"{[n.name for n in cluster.nodes_in('service')]})")
    engines[0].run_until_idle()
    low = {"queue": 0.0, "active": 0.0, "capacity": 2}
    ec.load_fn = lambda: low
    for tick in range(8):
        d = ec.tick()
        if d:
            print(f"  drain tick {tick}: {d}")

    print("== active-active failover (§6.3) ==")
    lugano = Site("lugano", engines[:1])
    geneva = Site("geneva", [InferenceEngine(cfg, params, max_batch=2,
                                             capacity=96, name="eng-gva")])
    mesh = ClusterMesh([lugano, geneva])
    site, eng = mesh.route(prefer="lugano")
    print(f"  routed to {site.name}/{eng.name}")
    mesh.partition("lugano")
    site, eng = mesh.route(prefer="lugano")
    print(f"  after partition -> {site.name}/{eng.name}")
    try:
        mesh.propose_config("lugano")
    except Exception as e:
        print(f"  split-brain fenced: {e}")
    mesh.heal("lugano")
    print(f"  healed; epoch={mesh.epoch}; "
          f"config write ok -> epoch={mesh.propose_config('lugano')}")


if __name__ == "__main__":
    main()
