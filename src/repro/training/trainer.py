"""Fault-tolerant training loop.

Maps the paper's batch-plane semantics onto JAX:

- checkpoint/restart: async sharding-aware checkpoints; any failure
  (simulated or real) resumes from the latest published step — on a real
  cluster the BatchPlane requeues the job and this loop restores.
- straggler mitigation: per-step node timings feed a detector; persistent
  stragglers trigger the elastic callback (drop node -> reshard -> resume),
  the §6.2 "baseline + delta" mechanism in reverse.
- elastic resize: rebuild the jitted step under a new mesh/sharding and
  restore the same checkpoint into it (diskless-node semantics: node-local
  state is always disposable).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.async_ckpt import AsyncCheckpointer
from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.param import abstract_params, param_axes
from repro.parallel import sharding as sh
from repro.training.optimizer import OptConfig, opt_init, opt_state_axes
from repro.training.train_step import make_train_step


class SimulatedNodeFailure(RuntimeError):
    """Raised by failure injectors to model a node loss / preemption."""


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    keep_every: int = 0
    log_every: int = 10
    straggler_ratio: float = 2.0     # x median step time counts as slow
    straggler_patience: int = 3
    # give up after this many CONSECUTIVE failed restore-and-retry
    # cycles (a failure loop that never completes a step — bad node,
    # corrupt input — would otherwise requeue forever); the counter
    # resets on every completed step
    max_restarts: int = 8


class StragglerDetector:
    def __init__(self, ratio: float, patience: int):
        self.ratio = ratio
        self.patience = patience
        self.strikes: Dict[str, int] = collections.defaultdict(int)
        self.history: List[float] = []

    def observe(self, node_times: Dict[str, float]) -> List[str]:
        """Feed per-node step durations; returns nodes flagged persistent."""
        med = float(np.median(list(node_times.values())))
        self.history.append(med)
        flagged = []
        for node, t in node_times.items():
            if t > self.ratio * med:
                self.strikes[node] += 1
                if self.strikes[node] >= self.patience:
                    flagged.append(node)
            else:
                self.strikes[node] = 0
        return flagged


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: OptConfig, data,
                 tc: TrainerConfig, mesh=None, rules=None,
                 schedule_fn=None, seed: int = 0,
                 failure_injector: Optional[Callable[[int], None]] = None,
                 node_timer: Optional[Callable[[int], Dict[str, float]]] = None,
                 on_straggler: Optional[Callable[[str], None]] = None,
                 param_dtype=jnp.float32, obs=None,
                 peak_flops: float = 197e12):
        self.cfg, self.opt_cfg, self.tc = cfg, opt_cfg, tc
        self.data = data
        self.schedule_fn = schedule_fn
        self.failure_injector = failure_injector
        self.node_timer = node_timer
        self.on_straggler = on_straggler
        self.detector = StragglerDetector(tc.straggler_ratio,
                                          tc.straggler_patience)
        self.ckpt = AsyncCheckpointer(tc.ckpt_dir, tc.keep_last,
                                      tc.keep_every)
        self.metrics_log: List[Dict[str, Any]] = []
        self.restarts = 0
        self._consec_failures = 0
        self.param_dtype = param_dtype
        # observability: step-time/throughput/MFU series + lifecycle
        # events.  Host-side only — the timings below bracket dispatch
        # wall time exactly as the pre-existing `wall` log field did, so
        # attaching obs adds no device syncs to the step loop.
        self.obs = obs
        self.peak_flops = peak_flops
        try:
            self._n_active = cfg.param_count(active_only=True)
        except TypeError:
            self._n_active = cfg.param_count()
        if obs is not None:
            reg = obs.registry
            self._h_step = reg.histogram(
                "repro_train_step_seconds", "train step wall time")
            self._c_steps = reg.counter(
                "repro_train_steps_total", "optimizer steps completed")
            self._c_tokens = reg.counter(
                "repro_train_tokens_total", "training tokens consumed")
            self._c_failures = reg.counter(
                "repro_train_failures_total",
                "simulated/real node failures hit")
            self._c_restores = reg.counter(
                "repro_train_restores_total",
                "checkpoint restores after failure")
            self._c_abandoned = reg.counter(
                "repro_train_restarts_abandoned_total",
                "runs abandoned after max_restarts consecutive "
                "failures")
            self._c_stragglers = reg.counter(
                "repro_train_stragglers_total",
                "persistent-straggler flags raised")
            self._g_tps = reg.gauge(
                "repro_train_tokens_per_s",
                "training throughput, last step")
            self._g_mfu = reg.gauge(
                "repro_train_mfu_ratio",
                "est. model FLOPs utilisation (6*N*tokens / wall*peak)")
        self._build(mesh, rules)
        key = jax.random.PRNGKey(seed)
        self.params = M.init(cfg, key, param_dtype)
        self.opt_state = opt_init(opt_cfg, self.params)
        if mesh is not None:
            self.params = jax.device_put(self.params, self.p_sh)
            self.opt_state = jax.device_put(self.opt_state, self.o_sh)
        self.step = 0

    # ------------------------------------------------------------ build
    def _build(self, mesh, rules):
        self.mesh, self.rules = mesh, rules
        step_fn = make_train_step(self.cfg, self.opt_cfg, self.schedule_fn)
        if mesh is not None:
            axes = param_axes(M.model_specs(self.cfg))
            self.p_sh = sh.tree_shardings(axes, mesh, rules)
            self.o_sh = sh.tree_shardings(
                opt_state_axes(self.opt_cfg, axes), mesh, rules)

            def wrapped(params, opt_state, batch):
                with sh.use_rules(mesh, rules):
                    return step_fn(params, opt_state, batch)

            self._jit = jax.jit(wrapped,
                                in_shardings=(self.p_sh, self.o_sh, None),
                                out_shardings=(self.p_sh, self.o_sh, None),
                                donate_argnums=(0, 1))
        else:
            self.p_sh = self.o_sh = None
            self._jit = jax.jit(step_fn, donate_argnums=(0, 1))
        self.num_shards = mesh.shape.get("data", 1) if mesh else 1

    # ------------------------------------------------------------ ckpt
    def state_tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self, sync: bool = False):
        meta = {"step": self.step, "arch": self.cfg.name}
        if sync:
            self.ckpt.save_sync(self.step, self.state_tree(), meta)
        else:
            self.ckpt.save(self.step, self.state_tree(), meta)

    def restore_latest(self) -> bool:
        from repro.checkpoint import ckpt as C
        self.ckpt.wait()
        steps = C.list_steps(self.tc.ckpt_dir)
        if not steps:
            return False
        target = {"params": self.params, "opt": self.opt_state}
        shd = ({"params": self.p_sh, "opt": self.o_sh}
               if self.mesh is not None else None)
        state, manifest = C.restore(self.tc.ckpt_dir, target, shardings=shd)
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = manifest["meta"].get("step", manifest["step"])
        return True

    # ------------------------------------------------------------ elastic
    def resize(self, mesh, rules):
        """Elastic resize: checkpoint -> rebuild -> reshard-restore."""
        self.save(sync=True)
        self._build(mesh, rules)
        assert self.restore_latest(), "resize requires a checkpoint"

    # ------------------------------------------------------------ loop
    def run(self, num_steps: Optional[int] = None) -> Dict[str, Any]:
        end = self.tc.num_steps if num_steps is None else self.step + num_steps
        obs = self.obs
        while self.step < end:
            t0 = time.time()
            sp = (obs.tracer.begin("train", f"step {self.step}",
                                   cat="train") if obs is not None else None)
            try:
                if self.failure_injector is not None:
                    self.failure_injector(self.step)
                batch = self.data.batch(self.step, shard=0,
                                        num_shards=1)
                batch = {k: jnp.asarray(v) for k, v in batch.items()
                         if k != "source"}
                self.params, self.opt_state, metrics = self._jit(
                    self.params, self.opt_state, batch)
                self.step += 1
                self._consec_failures = 0
            except SimulatedNodeFailure:
                # batch-plane behaviour: job requeued, state restored from
                # the last published checkpoint
                self.restarts += 1
                self._consec_failures += 1
                if obs is not None:
                    self._c_failures.inc()
                    obs.tracer.instant("train", "failure", cat="train",
                                       step=self.step)
                if self._consec_failures > self.tc.max_restarts:
                    # a restart loop that never completes a step: stop
                    # requeueing and surface the failure to the operator
                    if obs is not None:
                        self._c_abandoned.inc()
                        obs.tracer.instant("train", "abandon", cat="train",
                                           step=self.step,
                                           restarts=self.restarts)
                    if sp is not None:
                        obs.tracer.end(sp, outcome="abandoned")
                    raise
                if self.restore_latest():
                    if obs is not None:
                        self._c_restores.inc()
                        obs.tracer.instant("train", "restore", cat="train",
                                           step=self.step)
                else:
                    # no checkpoint yet: restart from scratch
                    key = jax.random.PRNGKey(0)
                    self.params = M.init(self.cfg, key, self.param_dtype)
                    self.opt_state = opt_init(self.opt_cfg, self.params)
                    if self.mesh is not None:
                        self.params = jax.device_put(self.params, self.p_sh)
                        self.opt_state = jax.device_put(
                            self.opt_state, self.o_sh)
                    self.step = 0
                if sp is not None:
                    obs.tracer.end(sp, outcome="failure")
                continue

            wall = time.time() - t0
            if sp is not None:
                obs.tracer.end(sp, outcome="ok")
            if obs is not None:
                tok = batch.get("tokens")
                n_tok = (int(np.prod(tok.shape)) if tok is not None
                         else sum(int(np.prod(v.shape))
                                  for v in batch.values()))
                self._h_step.observe(wall)
                self._c_steps.inc()
                self._c_tokens.inc(n_tok)
                if wall > 0:
                    self._g_tps.set(n_tok / wall)
                    self._g_mfu.set(6.0 * self._n_active * n_tok
                                    / (wall * self.peak_flops))
            if self.node_timer is not None:
                for node in self.detector.observe(self.node_timer(self.step)):
                    if obs is not None:
                        self._c_stragglers.inc()
                        obs.tracer.instant("train", "straggler", cat="train",
                                           step=self.step, node=node)
                    if self.on_straggler is not None:
                        self.on_straggler(node)
            if self.step % self.tc.ckpt_every == 0:
                self.save()
                if obs is not None:
                    obs.tracer.instant("train", "checkpoint", cat="train",
                                       step=self.step)
            if self.step % self.tc.log_every == 0 or self.step == end:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=self.step, wall=wall)
                self.metrics_log.append(m)
        self.ckpt.wait()
        return {"final_step": self.step, "restarts": self.restarts,
                "log": self.metrics_log}
