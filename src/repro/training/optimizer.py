"""Optimizers in pure JAX: AdamW (default) and Adafactor (memory-lean).

Optimizer state mirrors parameter structure and inherits parameter
shardings (FSDP-sharded moments — ZeRO-style).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor | sgd
    lr: float = 3e-4               # peak lr (scheduled by training.schedule)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    eps_root: float = 1e-30        # adafactor


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


# ------------------------------------------------------------ adamw
def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptConfig, grads, state, params, lr):
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mu_hat = mu / c1
        nu_hat = nu / c2
        d = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if cfg.weight_decay:
            d = d + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * d).astype(p.dtype), mu, nu

    flat_g, td = jax.tree.flatten(grads)
    flat_mu = td.flatten_up_to(state["mu"])
    flat_nu = td.flatten_up_to(state["nu"])
    flat_p = td.flatten_up_to(params)
    out = [upd(g, m, n, p)
           for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = td.unflatten([o[0] for o in out])
    new_mu = td.unflatten([o[1] for o in out])
    new_nu = td.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}


# ------------------------------------------------------------ adafactor
def adafactor_init(params):
    def factored(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
    return {"v": jax.tree.map(factored, params,
                              is_leaf=lambda x: hasattr(x, "ndim")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptConfig, grads, state, params, lr):
    step = state["step"] + 1
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + cfg.eps_root
        if p.ndim >= 2:
            vr = decay * v["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * v["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            rfac = jax.lax.rsqrt(
                vr / jnp.maximum(jnp.mean(vr, -1, keepdims=True), 1e-30))
            cfac = jax.lax.rsqrt(vc)
            d = g * rfac[..., None] * cfac[..., None, :]
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": decay * v["v"] + (1 - decay) * g2}
            d = g * jax.lax.rsqrt(nv["v"])
        clip = jnp.maximum(1.0, global_norm([d]) / (jnp.sqrt(
            jnp.asarray(d.size, jnp.float32))))
        d = d / clip
        if cfg.weight_decay:
            d = d + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * d).astype(p.dtype), nv

    flat_g, td = jax.tree.flatten(grads)
    flat_v = td.flatten_up_to(state["v"])
    flat_p = td.flatten_up_to(params)
    out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    return (td.unflatten([o[0] for o in out]),
            {"v": td.unflatten([o[1] for o in out]), "step": step})


# ------------------------------------------------------------ facade
def opt_init(cfg: OptConfig, params):
    if cfg.name == "adamw":
        return adamw_init(params)
    if cfg.name == "adafactor":
        return adafactor_init(params)
    if cfg.name == "sgd":
        return {"step": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.name)


def opt_update(cfg: OptConfig, grads, state, params, lr):
    if cfg.name == "adamw":
        return adamw_update(cfg, grads, state, params, lr)
    if cfg.name == "adafactor":
        return adafactor_update(cfg, grads, state, params, lr)
    if cfg.name == "sgd":
        new_p = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_p, {"step": state["step"] + 1}
    raise ValueError(cfg.name)


def opt_state_axes(cfg: OptConfig, axes_tree):
    """Logical axes for the optimizer state (moments mirror params)."""
    if cfg.name == "adamw":
        return {"mu": axes_tree, "nu": axes_tree, "step": ()}
    if cfg.name == "adafactor":
        def fact(ax):
            ax = tuple(ax)
            if len(ax) >= 2:
                return {"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]}
            return {"v": ax}
        is_ax = lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x)
        return {"v": jax.tree.map(fact, axes_tree, is_leaf=is_ax),
                "step": ()}
    return {"step": ()}
