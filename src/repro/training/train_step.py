"""Jit-able training step: bf16 compute over fp32 master params, global-norm
clipping, optimizer update, metrics."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.param import cast_tree
from repro.training.optimizer import OptConfig, clip_by_global_norm, opt_update


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    schedule_fn: Optional[Callable] = None,
                    compute_dtype=jnp.bfloat16):
    def train_step(params, opt_state, batch):
        lr = (schedule_fn(opt_state["step"]) if schedule_fn
              else jnp.asarray(opt_cfg.lr, jnp.float32))

        def loss_fn(p):
            return M.train_loss(cfg, cast_tree(p, compute_dtype), batch)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        new_params, new_state = opt_update(opt_cfg, grads, opt_state,
                                           params, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr,
                       total_loss=loss)
        return new_params, new_state, metrics

    return train_step


def make_grad_accum_step(cfg: ModelConfig, opt_cfg: OptConfig,
                         accum_steps: int,
                         schedule_fn: Optional[Callable] = None,
                         compute_dtype=jnp.bfloat16):
    """Microbatched step: batch leading dim is (accum_steps, micro_batch, S)."""
    def train_step(params, opt_state, batch):
        lr = (schedule_fn(opt_state["step"]) if schedule_fn
              else jnp.asarray(opt_cfg.lr, jnp.float32))
        pc = cast_tree(params, compute_dtype)

        def loss_fn(p, micro):
            return M.train_loss(cfg, p, micro)

        def body(carry, micro):
            g_acc, m_acc = carry
            (_, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(pc, micro)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 g_acc, g)
            m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
            return (g_acc, m_acc), ()

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m0 = {"loss": 0.0, "z_loss": 0.0, "aux_loss": 0.0,
              "accuracy": 0.0, "tokens": 0.0}
        m0 = jax.tree.map(lambda x: jnp.zeros((), jnp.float32), m0)
        (grads, msum), _ = jax.lax.scan(body, (g0, m0), batch)
        grads = jax.tree.map(lambda g: g / accum_steps, grads)
        metrics = jax.tree.map(lambda x: x / accum_steps, msum)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        new_params, new_state = opt_update(opt_cfg, grads, opt_state,
                                           params, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return new_params, new_state, metrics

    return train_step
