"""Learning-rate schedules (pure functions of step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int,
                  total_steps: int, min_ratio: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps)
                    / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio)
                     * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup_steps, warm, cos)


def wsd(step, *, peak_lr: float, warmup_steps: int, total_steps: int,
        decay_frac: float = 0.1):
    """Warmup-stable-decay (used by several open pretraining runs)."""
    step = jnp.asarray(step, jnp.float32)
    decay_start = total_steps * (1 - decay_frac)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    dec = peak_lr * jnp.clip(
        1.0 - (step - decay_start) / jnp.maximum(
            total_steps - decay_start, 1), 0.0, 1.0)
    lr = jnp.where(step < warmup_steps, warm,
                   jnp.where(step >= decay_start, dec, peak_lr))
    return lr


def constant(step, *, peak_lr: float, **_):
    return jnp.full_like(jnp.asarray(step, jnp.float32), peak_lr)


SCHEDULES = {"warmup_cosine": warmup_cosine, "wsd": wsd,
             "constant": constant}
