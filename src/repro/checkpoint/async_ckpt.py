"""Async checkpointing: device->host copy happens synchronously (cheap),
serialization/IO happens on a background thread so the train loop keeps
stepping.  Double-buffered: at most one save in flight; a new save waits
for the previous one (bounds host memory at one checkpoint)."""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional

import jax

from repro.checkpoint import ckpt


class AsyncCheckpointer:
    def __init__(self, root: str, keep_last: int = 3, keep_every: int = 0):
        self.root = root
        self.keep_last = keep_last
        self.keep_every = keep_every
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.saved_steps = []

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree, extra_meta: Optional[Dict] = None):
        self.wait()
        # snapshot to host while the device keeps running the next steps
        host_tree = jax.tree.map(jax.device_get, tree)

        def work():
            try:
                ckpt.save(self.root, step, host_tree, extra_meta)
                self.saved_steps.append(step)
                ckpt.gc(self.root, self.keep_last, self.keep_every)
            except BaseException as e:  # noqa: BLE001 - surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, step: int, tree, extra_meta=None):
        self.wait()
        d = ckpt.save(self.root, step, jax.tree.map(jax.device_get, tree),
                      extra_meta)
        self.saved_steps.append(step)
        ckpt.gc(self.root, self.keep_last, self.keep_every)
        return d
