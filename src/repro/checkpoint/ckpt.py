"""Sharding-aware distributed checkpointing with elastic resharding.

Layout (one directory per step)::

    <root>/step_0000100/
      manifest.json       tree structure, per-leaf shape/dtype + shard files
      <leaf_id>.<k>.npy   one file per (leaf, shard) — written by the host
                          that owns the shard

On a real multi-host pod each process writes only its addressable shards
(shard files are keyed by their global index ranges, not by host), so
restore works under ANY new mesh/sharding: each host assembles its local
shards from the overlapping saved files (``jax.make_array_from_callback``).
This is what makes checkpoint/restart *elastic* — a 512-chip job can
restart on 256 chips after losing a pod.

Retention: ``keep_last`` + ``keep_every`` guard against the paper's
"checkpoint explosion" (§6.6); lineage metadata is recorded per save and
surfaced through ``repro.core.registry``.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro._compat import tree_flatten_with_path


def _leaf_id(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return ".".join(out) or "root"


def _index_to_ranges(index, shape) -> List[Tuple[int, int]]:
    rng = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = dim if sl.stop is None else int(sl.stop)
        rng.append((start, stop))
    return rng


def save(root: str, step: int, tree, extra_meta: Optional[Dict] = None,
         overwrite: bool = True) -> str:
    """Write every addressable shard of every leaf.  Returns the step dir."""
    d = os.path.join(root, f"step_{step:010d}")
    tmp = d + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = tree_flatten_with_path(tree)
    manifest: Dict[str, Any] = {
        "step": step, "time": time.time(),
        "treedef": jax.tree.unflatten(
            jax.tree.structure(tree),
            list(range(len(leaves)))).__repr__()[:10000],
        "meta": extra_meta or {}, "leaves": [],
    }
    for path, leaf in leaves:
        lid = _leaf_id(path)
        arr = leaf if isinstance(leaf, jax.Array) else jnp.asarray(leaf)
        entry = {"id": lid, "shape": list(arr.shape),
                 "dtype": str(arr.dtype), "files": []}
        seen = set()
        shards = (arr.addressable_shards
                  if hasattr(arr, "addressable_shards") else None)
        if shards:
            for k, sh in enumerate(shards):
                ranges = tuple(_index_to_ranges(sh.index, arr.shape))
                if ranges in seen:  # replicated copies: write once
                    continue
                seen.add(ranges)
                fn = f"{lid}.{k}.npy"
                data = np.asarray(sh.data)
                if data.dtype.name == "bfloat16":  # numpy can't store bf16
                    data = data.astype(np.float32)
                np.save(os.path.join(tmp, fn), data)
                entry["files"].append({"file": fn,
                                       "ranges": [list(r) for r in ranges]})
        else:
            fn = f"{lid}.0.npy"
            data = np.asarray(arr)
            if data.dtype.name == "bfloat16":
                data = data.astype(np.float32)
            np.save(os.path.join(tmp, fn), data)
            entry["files"].append({
                "file": fn,
                "ranges": [[0, s] for s in arr.shape]})
        manifest["leaves"].append(entry)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(d):
        if not overwrite:
            raise FileExistsError(d)
        shutil.rmtree(d)
    os.rename(tmp, d)  # atomic publish: partial saves never count
    return d


def _read_region(step_dir: str, entry: Dict, ranges) -> np.ndarray:
    """Assemble [start,stop) per dim from the saved shard files."""
    shape = [b - a for a, b in ranges]
    dtype = np.dtype(entry["dtype"]
                     .replace("bfloat16", "float32"))  # see below
    want_bf16 = entry["dtype"] == "bfloat16"
    out = np.zeros(shape, np.float32 if want_bf16 else dtype)
    for f in entry["files"]:
        fr = f["ranges"]
        inter = []
        ok = True
        for (a, b), (c, dd) in zip(ranges, fr):
            lo, hi = max(a, c), min(b, dd)
            if lo >= hi:
                ok = False
                break
            inter.append((lo, hi, a, c))
        if not ok:
            continue
        data = np.load(os.path.join(step_dir, f["file"]), mmap_mode="r")
        src = tuple(slice(lo - c, hi - c) for lo, hi, a, c in inter)
        dst = tuple(slice(lo - a, hi - a) for lo, hi, a, c in inter)
        out[dst] = np.asarray(data[src], out.dtype)
    return out


def restore(root: str, target, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``target`` (arrays or
    ShapeDtypeStructs).  ``shardings``: matching tree of Shardings (or None
    for single-device).  Resharding across topologies is automatic."""
    step_dir = (os.path.join(root, f"step_{step:010d}") if step is not None
                else latest_dir(root))
    if step_dir is None:
        raise FileNotFoundError(f"no checkpoint under {root}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    by_id = {e["id"]: e for e in manifest["leaves"]}
    leaves, treedef = tree_flatten_with_path(target)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for (path, leaf), shd in zip(leaves, shard_leaves):
        lid = _leaf_id(path)
        if lid not in by_id:
            raise KeyError(f"checkpoint missing leaf {lid}")
        entry = by_id[lid]
        shape = tuple(entry["shape"])
        dtype = jnp.dtype(entry["dtype"])
        if tuple(leaf.shape) != shape:
            raise ValueError(
                f"shape mismatch for {lid}: ckpt {shape} vs target "
                f"{tuple(leaf.shape)}")
        if shd is None:
            full = _read_region(step_dir, entry,
                                [(0, s) for s in shape])
            out.append(jnp.asarray(full).astype(dtype))
        else:
            arr = jax.make_array_from_callback(
                shape, shd, lambda idx, e=entry: jnp.asarray(
                    _read_region(step_dir, e, _index_to_ranges(idx, shape))
                ).astype(dtype))
            out.append(arr)
    return jax.tree.unflatten(treedef, out), manifest


def list_steps(root: str) -> List[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for n in os.listdir(root):
        m = re.fullmatch(r"step_(\d+)", n)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_dir(root: str) -> Optional[str]:
    steps = list_steps(root)
    if not steps:
        return None
    return os.path.join(root, f"step_{steps[-1]:010d}")


def gc(root: str, keep_last: int = 3, keep_every: int = 0) -> List[int]:
    """Retention policy (paper §6.6): keep the newest ``keep_last`` plus
    every ``keep_every``-th step.  Returns deleted steps."""
    steps = list_steps(root)
    keep = set(steps[-keep_last:]) if keep_last else set()
    if keep_every:
        keep |= {s for s in steps if s % keep_every == 0}
    deleted = []
    for s in steps:
        if s not in keep:
            shutil.rmtree(os.path.join(root, f"step_{s:010d}"))
            deleted.append(s)
    return deleted
