"""Pure-jnp oracle for paged flash-decode: materialize the gather the
kernel avoids, then run the dense decode oracle."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.ref import decode_ref


def paged_decode_ref(q, k_pool, v_pool, block_tables, lengths) -> jax.Array:
    """q: (B,H,D); k_pool/v_pool: (num_blocks, block_size, KV, D);
    block_tables: (B, max_blocks); lengths: (B,)."""
    B = q.shape[0]
    _, blk, KV, D = k_pool.shape
    W = block_tables.shape[1]
    k = k_pool[block_tables].reshape(B, W * blk, KV, D)
    v = v_pool[block_tables].reshape(B, W * blk, KV, D)
    return decode_ref(q, k, v, lengths)


def paged_verify_ref(q, k_pool, v_pool, block_tables, lengths) -> jax.Array:
    """Multi-query oracle: q (B,T,H,D), query t of row b at position
    ``lengths[b] - T + t``, causal over the gathered sequence."""
    import jax.numpy as jnp

    B, T, H, D = q.shape
    _, blk, KV, _ = k_pool.shape
    W = block_tables.shape[1]
    k = k_pool[block_tables].reshape(B, W * blk, KV, D)
    v = v_pool[block_tables].reshape(B, W * blk, KV, D)
    # one single-query decode per tail offset: query t sees lengths-T+t+1
    # valid positions
    outs = [decode_ref(q[:, t],
                       k, v, lengths - (T - 1 - t)) for t in range(T)]
    return jnp.stack(outs, axis=1)
