"""Pure-jnp oracle for paged flash-decode: materialize the gather the
kernel avoids, then run the dense decode oracle."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.ref import decode_ref


def paged_decode_ref(q, k_pool, v_pool, block_tables, lengths) -> jax.Array:
    """q: (B,H,D); k_pool/v_pool: (num_blocks, block_size, KV, D);
    block_tables: (B, max_blocks); lengths: (B,)."""
    B = q.shape[0]
    _, blk, KV, D = k_pool.shape
    W = block_tables.shape[1]
    k = k_pool[block_tables].reshape(B, W * blk, KV, D)
    v = v_pool[block_tables].reshape(B, W * blk, KV, D)
    return decode_ref(q, k, v, lengths)


def paged_verify_ref(q, k_pool, v_pool, block_tables, lengths) -> jax.Array:
    """Multi-query oracle: q (B,T,H,D), query t of row b at position
    ``lengths[b] - T + t``, causal over the gathered sequence."""
    import jax.numpy as jnp

    B, T, H, D = q.shape
    _, blk, KV, _ = k_pool.shape
    W = block_tables.shape[1]
    k = k_pool[block_tables].reshape(B, W * blk, KV, D)
    v = v_pool[block_tables].reshape(B, W * blk, KV, D)
    # one single-query decode per tail offset: query t sees lengths-T+t+1
    # valid positions
    outs = [decode_ref(q[:, t],
                       k, v, lengths - (T - 1 - t)) for t in range(T)]
    return jnp.stack(outs, axis=1)


def _dequant_pool(pool, scale):
    """int8 pool (nb, blk, KV, D) * per-block-per-head scale (nb, KV)."""
    import jax.numpy as jnp

    return pool.astype(jnp.float32) * scale[:, None, :, None]


def paged_decode_int8_ref(q, k_pool, v_pool, k_scale, v_scale,
                          block_tables, lengths) -> jax.Array:
    """Int8 oracle: dequantize the whole pool up front (the cost the
    fused kernel avoids), then delegate to the bf16 paged oracle."""
    return paged_decode_ref(q, _dequant_pool(k_pool, k_scale),
                            _dequant_pool(v_pool, v_scale),
                            block_tables, lengths)


def paged_verify_int8_ref(q, k_pool, v_pool, k_scale, v_scale,
                          block_tables, lengths) -> jax.Array:
    """Int8 multi-query oracle (dequantize pool, then verify oracle)."""
    return paged_verify_ref(q, _dequant_pool(k_pool, k_scale),
                            _dequant_pool(v_pool, v_scale),
                            block_tables, lengths)
