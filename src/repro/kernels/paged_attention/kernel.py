"""Pallas TPU paged flash-decode: one query token per sequence against a
*paged* KV cache, GQA.

The KV cache is a shared physical pool of fixed-size blocks —
``(num_blocks, block_size, KV, D)`` — and each sequence names its blocks
through a block table ``(B, max_blocks)`` of physical ids.  The grid is
(batch, table_column) with the table dimension sequential; both the block
table and the per-sequence valid lengths arrive via scalar prefetch
(SMEM), so the *index map itself* walks the table: the BlockSpec for K/V
resolves ``bt[b, j]`` before the kernel body runs and DMAs exactly that
physical block into VMEM.  No gathered per-sequence copy of the cache is
ever materialized in HBM — that gather is what the dense fallback and the
jnp oracle (``ref.py``) pay for.

Online-softmax state for all H heads is carried in VMEM scratch exactly
like the dense flash-decode kernel (``kernels/decode_attention``), whose
outputs this kernel must match bit-for-bit on equal pool layouts (the
parity tests permute tables to prove layout independence).

Physical block 0 is reserved as a null block: table entries past a
sequence's length point at it, the ``k_start < length`` guard skips their
compute, and the tail-block mask covers a partially-filled last block.

The ``*_int8`` variants read an int8 pool with per-block-per-head f32
scales (symmetric: ``x ≈ q * scale``).  The scale arrays
``(num_blocks, KV)`` ride the same scalar-prefetch path as the block
table, so the kernel resolves ``scale[bt[b, j]]`` from SMEM and
dequantizes the int8 tile *in-register* inside the online-softmax loop —
the pool's HBM traffic stays int8 end to end, which is the entire win
(paged decode is bandwidth-bound on the KV read).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _paged_decode_kernel(len_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, blk: int, G: int):
    b = pl.program_id(0)
    j = pl.program_id(1)          # logical block index within the sequence

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    k_start = j * blk

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (H, D)
        k = k_ref[0].astype(jnp.float32)                  # (blk, KV*D)
        H, D = q.shape
        KV = k.shape[-1] // D
        k = k.reshape(blk, KV, D)
        v = v_ref[0].astype(jnp.float32).reshape(blk, KV, D)
        scale = 1.0 / (D ** 0.5)
        qg = q.reshape(KV, G, D)
        s = jnp.einsum("kgd,skd->kgs", qg * scale, k,
                       preferred_element_type=jnp.float32)  # (KV,G,blk)
        s = s.reshape(H, blk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_scr[...]                               # (H, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, -1, keepdims=True)
        pv = jnp.einsum("kgs,skd->kgd", p.reshape(KV, G, blk), v,
                        preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv.reshape(H, D)
        m_scr[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _paged_verify_kernel(len_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, blk: int, G: int,
                         T: int):
    """Multi-query (speculative verify) variant: T tail queries per
    sequence, query t at absolute position ``length - T + t``.  The T
    queries are folded into the head axis — row ``i`` of the (T*H, ...)
    score/accumulator tensors is query ``i // H``, head ``i % H`` — so
    the online-softmax state layout matches the single-query kernel with
    H replaced by T*H.  Masking adds the causal tail constraint
    ``kpos <= qpos`` on top of the validity guard."""
    b = pl.program_id(0)
    j = pl.program_id(1)          # logical block index within the sequence

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    k_start = j * blk

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (T, H, D)
        k = k_ref[0].astype(jnp.float32)                  # (blk, KV*D)
        _, H, D = q.shape
        KV = k.shape[-1] // D
        k = k.reshape(blk, KV, D)
        v = v_ref[0].astype(jnp.float32).reshape(blk, KV, D)
        scale = 1.0 / (D ** 0.5)
        qg = q.reshape(T, KV, G, D)
        s = jnp.einsum("tkgd,skd->tkgs", qg * scale, k,
                       preferred_element_type=jnp.float32)
        s = s.reshape(T * H, blk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qpos = (length - T
                + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // H)
        s = jnp.where((kpos <= qpos) & (kpos < length), s, NEG_INF)
        m_prev = m_scr[...]                               # (T*H, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, -1, keepdims=True)
        pv = jnp.einsum("tkgs,skd->tkgd", p.reshape(T, KV, G, blk), v,
                        preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv.reshape(T * H, D)
        m_scr[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).reshape(o_ref.shape[1:]).astype(
            o_ref.dtype)


def _paged_decode_kernel_int8(len_ref, bt_ref, ks_ref, vs_ref, q_ref,
                              k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                              *, blk: int, G: int):
    """Int8 variant of :func:`_paged_decode_kernel`: K/V tiles arrive as
    int8 and are dequantized in-register with the block's per-head scale
    (``ks_ref``/``vs_ref``, (num_blocks, KV) f32 in SMEM, indexed through
    the same prefetched block table the K/V index maps walk)."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    k_start = j * blk

    @pl.when(k_start < length)
    def _compute():
        pid = bt_ref[b, j]
        q = q_ref[0].astype(jnp.float32)                  # (H, D)
        k = k_ref[0].astype(jnp.float32)                  # (blk, KV*D) int8
        H, D = q.shape
        KV = k.shape[-1] // D
        k_sc = ks_ref[pid]                                # (KV,) f32
        v_sc = vs_ref[pid]
        k = k.reshape(blk, KV, D) * k_sc[None, :, None]
        v = (v_ref[0].astype(jnp.float32).reshape(blk, KV, D)
             * v_sc[None, :, None])
        scale = 1.0 / (D ** 0.5)
        qg = q.reshape(KV, G, D)
        s = jnp.einsum("kgd,skd->kgs", qg * scale, k,
                       preferred_element_type=jnp.float32)  # (KV,G,blk)
        s = s.reshape(H, blk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_scr[...]                               # (H, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, -1, keepdims=True)
        pv = jnp.einsum("kgs,skd->kgd", p.reshape(KV, G, blk), v,
                        preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv.reshape(H, D)
        m_scr[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _paged_verify_kernel_int8(len_ref, bt_ref, ks_ref, vs_ref, q_ref,
                              k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                              *, blk: int, G: int, T: int):
    """Int8 variant of :func:`_paged_verify_kernel` (same T-queries-folded
    -into-heads layout), K/V dequantized in-register per block."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    k_start = j * blk

    @pl.when(k_start < length)
    def _compute():
        pid = bt_ref[b, j]
        q = q_ref[0].astype(jnp.float32)                  # (T, H, D)
        k = k_ref[0].astype(jnp.float32)                  # (blk, KV*D) int8
        _, H, D = q.shape
        KV = k.shape[-1] // D
        k_sc = ks_ref[pid]                                # (KV,) f32
        v_sc = vs_ref[pid]
        k = k.reshape(blk, KV, D) * k_sc[None, :, None]
        v = (v_ref[0].astype(jnp.float32).reshape(blk, KV, D)
             * v_sc[None, :, None])
        scale = 1.0 / (D ** 0.5)
        qg = q.reshape(T, KV, G, D)
        s = jnp.einsum("tkgd,skd->tkgs", qg * scale, k,
                       preferred_element_type=jnp.float32)
        s = s.reshape(T * H, blk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qpos = (length - T
                + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // H)
        s = jnp.where((kpos <= qpos) & (kpos < length), s, NEG_INF)
        m_prev = m_scr[...]                               # (T*H, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, -1, keepdims=True)
        pv = jnp.einsum("tkgs,skd->tkgd", p.reshape(T, KV, G, blk), v,
                        preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv.reshape(T * H, D)
        m_scr[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).reshape(o_ref.shape[1:]).astype(
            o_ref.dtype)


def paged_verify_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           interpret: bool = False) -> jax.Array:
    """Multi-query paged flash-decode for speculative verify.

    q: (B, T, H, D) — the T newest tokens of each sequence (their KV
    already scattered into the pool); k_pool/v_pool: (num_blocks,
    block_size, KV, D); block_tables: (B, max_blocks) int32; lengths:
    (B,) valid tokens including the T tail tokens.  Query t of row b
    sits at position ``lengths[b] - T + t`` and attends causally.
    Returns (B, T, H, D).  T == 1 reduces to
    :func:`paged_decode_attention` (parity-tested)."""
    B, T, H, D = q.shape
    nb, blk, KV, _ = k_pool.shape
    G = H // KV
    W = block_tables.shape[1]
    kr = k_pool.reshape(nb, blk, KV * D)
    vr = v_pool.reshape(nb, blk, KV * D)

    grid = (B, W)
    kernel = functools.partial(_paged_verify_kernel, blk=blk, G=G, T=T)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, T, H, D),
                             lambda b, j, lens, bt: (b, 0, 0, 0)),
                pl.BlockSpec((1, blk, KV * D),
                             lambda b, j, lens, bt: (bt[b, j], 0, 0)),
                pl.BlockSpec((1, blk, KV * D),
                             lambda b, j, lens, bt: (bt[b, j], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, T, H, D),
                                   lambda b, j, lens, bt: (b, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((T * H, 1), jnp.float32),
                pltpu.VMEM((T * H, 1), jnp.float32),
                pltpu.VMEM((T * H, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, T, H, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32), q, kr, vr)
    return out


def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, D); k_pool/v_pool: (num_blocks, block_size, KV, D);
    block_tables: (B, max_blocks) int32 physical block ids; lengths: (B,)
    valid tokens per sequence.  Returns (B, H, D).

    Table entries at or past ``ceil(length / block_size)`` are never read
    (their grid steps are skipped), so callers may pad rows with any valid
    id — the serving layer uses the reserved null block 0.
    """
    B, H, D = q.shape
    nb, blk, KV, _ = k_pool.shape
    G = H // KV
    W = block_tables.shape[1]
    kr = k_pool.reshape(nb, blk, KV * D)
    vr = v_pool.reshape(nb, blk, KV * D)

    grid = (B, W)
    kernel = functools.partial(_paged_decode_kernel, blk=blk, G=G)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, D), lambda b, j, lens, bt: (b, 0, 0)),
                pl.BlockSpec((1, blk, KV * D),
                             lambda b, j, lens, bt: (bt[b, j], 0, 0)),
                pl.BlockSpec((1, blk, KV * D),
                             lambda b, j, lens, bt: (bt[b, j], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, D),
                                   lambda b, j, lens, bt: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32), q, kr, vr)
    return out


def paged_decode_attention_int8(q: jax.Array, k_pool: jax.Array,
                                v_pool: jax.Array, k_scale: jax.Array,
                                v_scale: jax.Array,
                                block_tables: jax.Array,
                                lengths: jax.Array, *,
                                interpret: bool = False) -> jax.Array:
    """Int8-pool variant of :func:`paged_decode_attention`.

    k_pool/v_pool: (num_blocks, block_size, KV, D) int8; k_scale/v_scale:
    (num_blocks, KV) f32 symmetric per-block-per-head scales (``x ≈ q *
    scale``).  Scales ride scalar prefetch into SMEM next to the block
    table, so dequantization happens in-register per tile and the HBM
    read stays int8.  Returns (B, H, D) in q.dtype.
    """
    B, H, D = q.shape
    nb, blk, KV, _ = k_pool.shape
    G = H // KV
    W = block_tables.shape[1]
    kr = k_pool.reshape(nb, blk, KV * D)
    vr = v_pool.reshape(nb, blk, KV * D)

    grid = (B, W)
    kernel = functools.partial(_paged_decode_kernel_int8, blk=blk, G=G)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, D),
                             lambda b, j, lens, bt, ks, vs: (b, 0, 0)),
                pl.BlockSpec((1, blk, KV * D),
                             lambda b, j, lens, bt, ks, vs:
                             (bt[b, j], 0, 0)),
                pl.BlockSpec((1, blk, KV * D),
                             lambda b, j, lens, bt, ks, vs:
                             (bt[b, j], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, D),
                                   lambda b, j, lens, bt, ks, vs:
                                   (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32),
      k_scale.astype(jnp.float32), v_scale.astype(jnp.float32),
      q, kr, vr)
    return out


def paged_verify_attention_int8(q: jax.Array, k_pool: jax.Array,
                                v_pool: jax.Array, k_scale: jax.Array,
                                v_scale: jax.Array,
                                block_tables: jax.Array,
                                lengths: jax.Array, *,
                                interpret: bool = False) -> jax.Array:
    """Int8-pool variant of :func:`paged_verify_attention`: q is
    (B, T, H, D), pools are int8 with (num_blocks, KV) f32 scales, and
    the causal-tail verify semantics match the bf16 kernel exactly."""
    B, T, H, D = q.shape
    nb, blk, KV, _ = k_pool.shape
    G = H // KV
    W = block_tables.shape[1]
    kr = k_pool.reshape(nb, blk, KV * D)
    vr = v_pool.reshape(nb, blk, KV * D)

    grid = (B, W)
    kernel = functools.partial(_paged_verify_kernel_int8, blk=blk, G=G,
                               T=T)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, T, H, D),
                             lambda b, j, lens, bt, ks, vs: (b, 0, 0, 0)),
                pl.BlockSpec((1, blk, KV * D),
                             lambda b, j, lens, bt, ks, vs:
                             (bt[b, j], 0, 0)),
                pl.BlockSpec((1, blk, KV * D),
                             lambda b, j, lens, bt, ks, vs:
                             (bt[b, j], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, T, H, D),
                                   lambda b, j, lens, bt, ks, vs:
                                   (b, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((T * H, 1), jnp.float32),
                pltpu.VMEM((T * H, 1), jnp.float32),
                pltpu.VMEM((T * H, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, T, H, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32),
      k_scale.astype(jnp.float32), v_scale.astype(jnp.float32),
      q, kr, vr)
    return out
