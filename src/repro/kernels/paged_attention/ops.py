"""Jit'd wrapper for paged flash-decode (model layout, CPU interpret
fallback)."""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.kernel import (
    paged_decode_attention, paged_decode_attention_int8,
    paged_verify_attention, paged_verify_attention_int8)


@jax.jit
def paged_decode(q, k_pool, v_pool, block_tables, lengths):
    """q: (B,1,H,D); pools: (num_blocks, block_size, KV, D);
    block_tables: (B, max_blocks); lengths: (B,) -> (B,1,H,D)."""
    o = paged_decode_attention(q[:, 0], k_pool, v_pool, block_tables,
                               lengths,
                               interpret=jax.default_backend() == "cpu")
    return o[:, None]


@jax.jit
def paged_verify(q, k_pool, v_pool, block_tables, lengths):
    """Speculative multi-token verify: q (B,T,H,D) tail queries, query t
    at position ``lengths - T + t`` -> (B,T,H,D)."""
    return paged_verify_attention(q, k_pool, v_pool, block_tables, lengths,
                                  interpret=jax.default_backend() == "cpu")


@jax.jit
def paged_decode_int8(q, k_pool, v_pool, k_scale, v_scale, block_tables,
                      lengths):
    """Int8-pool decode: q (B,1,H,D); pools int8 with (num_blocks, KV)
    f32 scales -> (B,1,H,D)."""
    o = paged_decode_attention_int8(
        q[:, 0], k_pool, v_pool, k_scale, v_scale, block_tables, lengths,
        interpret=jax.default_backend() == "cpu")
    return o[:, None]


@jax.jit
def paged_verify_int8(q, k_pool, v_pool, k_scale, v_scale, block_tables,
                      lengths):
    """Int8-pool multi-token verify: q (B,T,H,D) -> (B,T,H,D)."""
    return paged_verify_attention_int8(
        q, k_pool, v_pool, k_scale, v_scale, block_tables, lengths,
        interpret=jax.default_backend() == "cpu")
