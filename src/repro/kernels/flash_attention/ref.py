"""Pure-jnp oracle for flash attention (fp32 softmax, GQA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True) -> jax.Array:
    """q: (B,H,Sq,D); k/v: (B,KV,Skv,D)."""
    B, H, Sq, D = q.shape
    _, KV, Skv, _ = k.shape
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, Sq, D)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, k.astype(jnp.float32))
    s = s / (D ** 0.5)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)
