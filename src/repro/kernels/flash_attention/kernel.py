"""Pallas TPU flash attention (causal/full, GQA).

Grid: (batch*heads, q_blocks, kv_blocks) — kv innermost and sequential so
the online-softmax state (m, l, acc) lives in VMEM scratch across kv
iterations.  BlockSpecs tile Q/K/V into (blk_q x D) / (blk_k x D) VMEM
windows; D is the full head dim (hardware-aligned 64/128 for every
assigned arch).  Causal masking skips whole KV blocks above the diagonal
(`pl.when`), recovering the ~2x the XLA blockwise path wastes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, blk_q: int, blk_k: int, causal: bool,
                  kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * blk_q
    k_start = ki * blk_k
    # causal block skip: block strictly above the diagonal contributes 0
    run = (not causal) or (k_start <= q_start + blk_q - 1)
    if causal:
        run = k_start <= q_start + blk_q - 1  # traced predicate

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (blk_q, D)
        k = k_ref[0].astype(jnp.float32)                  # (blk_k, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (blk_q, blk_k)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len
        if causal:
            mask &= qpos >= kpos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                               # (blk_q, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, blk_q: int = 128,
                    blk_k: int = 128, interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, KV, Skv, D); H % KV == 0.
    Returns (B, H, Sq, D) in q.dtype."""
    B, H, Sq, D = q.shape
    _, KV, Skv, _ = k.shape
    assert H % KV == 0
    G = H // KV
    scale = 1.0 / (D ** 0.5)

    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Skv)
    pad_q = (-Sq) % blk_q
    pad_k = (-Skv) % blk_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_k

    qr = q.reshape(B * H, Sq_p, D)
    kr = k.reshape(B * KV, Skv_p, D)
    vr = v.reshape(B * KV, Skv_p, D)

    grid = (B * H, Sq_p // blk_q, Skv_p // blk_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, blk_q=blk_q, blk_k=blk_k,
        causal=causal, kv_len=Skv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, blk_k, D),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, blk_k, D),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(B, H, Sq_p, D)
    return out[:, :, :Sq]
