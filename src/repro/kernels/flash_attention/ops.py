"""Jit'd public wrapper: layout conversion + interpret-mode fallback on CPU
(the TPU target compiles the same kernel natively)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k"))
def mha(q, k, v, *, causal: bool = True, blk_q: int = 128,
        blk_k: int = 128):
    """Model-layout entry point: q (B,Sq,H,D), k/v (B,Skv,KV,D) ->
    (B,Sq,H,D)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = flash_attention(qt, kt, vt, causal=causal, blk_q=blk_q,
                        blk_k=blk_k, interpret=_on_cpu())
    return jnp.swapaxes(o, 1, 2)
