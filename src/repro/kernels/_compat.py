"""Version shim for Pallas TPU API renames.

``pltpu.TPUCompilerParams`` became ``pltpu.CompilerParams`` in newer JAX;
kernels import the name from here so they run on both (the container pins
an older jaxlib than CI).
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
