"""Pure-jnp oracle for flash-decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_ref(q, k, v, lengths) -> jax.Array:
    """q: (B,H,D); k/v: (B,S,KV,D); lengths: (B,)."""
    B, H, D = q.shape
    _, S, KV, _ = k.shape
    G = H // KV
    qg = q.astype(jnp.float32).reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    s = s / (D ** 0.5)
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)
