"""Jit'd wrapper for flash-decode (model layout, CPU interpret fallback)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention


@functools.partial(jax.jit, static_argnames=("blk_k",))
def decode(q, k_cache, v_cache, lengths, *, blk_k: int = 256):
    """q: (B,1,H,D); caches: (B,S,KV,D); lengths: (B,) -> (B,1,H,D)."""
    o = decode_attention(q[:, 0], k_cache, v_cache, lengths, blk_k=blk_k,
                         interpret=jax.default_backend() == "cpu")
    return o[:, None]
