"""Pallas TPU flash-decode: one query token per sequence against a long
KV cache, GQA.

Grid: (batch, kv_blocks) with the kv dimension sequential; online-softmax
state for ALL H heads of the sequence is carried in VMEM scratch (H x D
fits comfortably: 64 heads x 128 = 32 KB fp32).  Per-sequence valid
length arrives via scalar prefetch (SMEM), masking both the tail block
and recovering variable-length batches without recompilation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, blk_k: int, G: int):
    b = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    k_start = ki * blk_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (H, D)
        k = k_ref[0].astype(jnp.float32)                  # (blk_k, KV*D)
        H, D = q.shape
        KV = k.shape[-1] // D
        k = k.reshape(blk_k, KV, D)
        v = v_ref[0].astype(jnp.float32).reshape(blk_k, KV, D)
        scale = 1.0 / (D ** 0.5)
        # scores for all H heads: head h reads kv head h // G
        qg = q.reshape(KV, G, D)
        s = jnp.einsum("kgd,skd->kgs", qg * scale, k,
                       preferred_element_type=jnp.float32)  # (KV,G,blk)
        s = s.reshape(H, blk_k)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_scr[...]                               # (H, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)                            # (H, blk)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, -1, keepdims=True)
        pv = jnp.einsum("kgs,skd->kgd", p.reshape(KV, G, blk_k), v,
                        preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv.reshape(H, D)
        m_scr[...] = m_new

    @pl.when(ki == pl.num_programs(1) - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, blk_k: int = 256,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, D); k/v: (B, S, KV, D); lengths: (B,) valid entries.
    Returns (B, H, D)."""
    B, H, D = q.shape
    _, S, KV, _ = k.shape
    G = H // KV
    blk_k = min(blk_k, S)
    pad = (-S) % blk_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    kr = k.reshape(B, Sp, KV * D)
    vr = v.reshape(B, Sp, KV * D)

    grid = (B, Sp // blk_k)
    kernel = functools.partial(_decode_kernel, blk_k=blk_k, G=G)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, D), lambda b, ki, lens: (b, 0, 0)),
                pl.BlockSpec((1, blk_k, KV * D),
                             lambda b, ki, lens: (b, ki, 0)),
                pl.BlockSpec((1, blk_k, KV * D),
                             lambda b, ki, lens: (b, ki, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, D), lambda b, ki, lens: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, kr, vr)
    return out
