"""Pallas TPU fused RMSNorm (row-blocked, fp32 accumulation in VMEM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)              # (blk, d)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
            blk_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., d); w: (d,)."""
    shape = x.shape
    d = shape[-1]
    xr = x.reshape(-1, d)
    R = xr.shape[0]
    blk = min(blk_rows, R)
    pad = (-R) % blk
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((R + pad) // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R + pad, d), x.dtype),
        interpret=interpret,
    )(xr, w)
    return out[:R].reshape(shape)
