"""Jit'd wrapper for the fused RMSNorm kernel."""
import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm as _rmsnorm


@functools.partial(jax.jit, static_argnames=("eps", "blk_rows"))
def rmsnorm(x, w, *, eps: float = 1e-5, blk_rows: int = 256):
    return _rmsnorm(x, w, eps=eps, blk_rows=blk_rows,
                    interpret=jax.default_backend() == "cpu")
