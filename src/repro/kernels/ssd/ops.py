"""Jit'd wrapper: model layout (B,L,H,P) -> kernel layout, padding, CPU
interpret fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_scan


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt, A, Bm, Cm, *, chunk: int = 256):
    """x: (B,L,H,P); dt: (B,L,H); A: (H,) negative; Bm/Cm: (B,L,N).
    Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    chunk_eff = min(chunk, L)
    pad = (-L) % chunk_eff
    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    dA = dt.astype(jnp.float32) * A.astype(jnp.float32)
    xdt = jnp.moveaxis(xdt, 2, 1).reshape(B * H, L, P)
    dAr = jnp.moveaxis(dA, 2, 1).reshape(B * H, L)
    Br = jnp.broadcast_to(Bm[:, None], (B, H, L, N)).reshape(B * H, L, N)
    Cr = jnp.broadcast_to(Cm[:, None], (B, H, L, N)).reshape(B * H, L, N)
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0)))
        dAr = jnp.pad(dAr, ((0, 0), (0, pad)))   # exp(0)=1 decay, x=0: no-op
        Br = jnp.pad(Br, ((0, 0), (0, pad), (0, 0)))
        Cr = jnp.pad(Cr, ((0, 0), (0, pad), (0, 0)))
    y, h = ssd_scan(xdt, dAr, Br, Cr, chunk=chunk_eff,
                    interpret=jax.default_backend() == "cpu")
    y = y[:, :L].reshape(B, H, L, P)
    y = jnp.moveaxis(y, 1, 2)
    h = h.reshape(B, H, N, P).swapaxes(-1, -2)  # (B,H,P,N)
    return y, h
