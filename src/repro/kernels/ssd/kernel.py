"""Pallas TPU kernel for the Mamba2 SSD chunk recurrence.

Hardware adaptation (DESIGN.md §2): SSD's chunked "state-space duality"
form is chosen over Mamba-1's elementwise selective scan precisely
because each chunk is matmul-shaped (MXU) instead of a length-L diagonal
recurrence (VPU-serial).

Grid: (batch*heads, chunks) with chunks sequential; the running state
(P x N) lives in VMEM scratch.  Per chunk (all fp32 in-VMEM):
    L        = exp(segsum(dA))           (Q x Q lower-triangular decay)
    y_diag   = ((C B^T) . L) x
    y_off    = (C h^T) . exp(cumsum dA)
    h        = h * exp(sum dA) + (B * decay_to_end)^T x
Inputs are pre-arranged by ops.py as x*(dt), dA = A*dt.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _ssd_kernel(x_ref, da_ref, b_ref, c_ref, y_ref, hout_ref, h_scr, *,
                Q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)         # (Q, P)
    da = da_ref[0].astype(jnp.float32)       # (Q, 1) -> (Q,)
    da = da[:, 0]
    Bc = b_ref[0].astype(jnp.float32)        # (Q, N)
    Cc = c_ref[0].astype(jnp.float32)        # (Q, N)

    da_cs = jnp.cumsum(da)                   # (Q,)
    seg = da_cs[:, None] - da_cs[None, :]    # (Q, Q)
    causal = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    Lmat = jnp.where(causal, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(
        Cc, Bc, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (Q, Q)
    y_diag = jax.lax.dot((scores * Lmat).astype(jnp.float32), x,
                         preferred_element_type=jnp.float32)

    h = h_scr[...]                           # (N, P)
    y_off = jax.lax.dot(Cc * jnp.exp(da_cs)[:, None], h,
                        preferred_element_type=jnp.float32)  # (Q, P)

    decay_to_end = jnp.exp(da_cs[-1] - da_cs)               # (Q,)
    upd = jax.lax.dot_general(
        Bc * decay_to_end[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (N, P)
    h_scr[...] = h * jnp.exp(da_cs[-1]) + upd

    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _done():
        hout_ref[0] = h_scr[...].astype(hout_ref.dtype)


def ssd_scan(xdt: jax.Array, dA: jax.Array, Bm: jax.Array, Cm: jax.Array,
             *, chunk: int = 256, interpret: bool = False):
    """xdt: (BH, L, P) inputs pre-multiplied by dt; dA: (BH, L) decay
    exponents (A*dt, negative); Bm/Cm: (BH, L, N).
    Returns (y (BH, L, P), final_state (BH, N, P))."""
    BH, L, P = xdt.shape
    N = Bm.shape[-1]
    chunk = min(chunk, L)
    assert L % chunk == 0, "ops.py pads L to a chunk multiple"
    nc = L // chunk

    grid = (BH, nc)
    kernel = functools.partial(_ssd_kernel, Q=chunk)
    y, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, P), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, L, P), xdt.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xdt, dA[..., None], Bm, Cm)
    return y, hout
