"""Pure-jnp oracle for the SSD scan: direct sequential state recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(xdt, dA, Bm, Cm):
    """Sequential reference: h_t = h_{t-1} e^{dA_t} + B_t (x_t dt_t)^T.

    xdt: (BH, L, P); dA: (BH, L); Bm/Cm: (BH, L, N).
    Returns (y (BH,L,P), final_state (BH,N,P))."""
    BH, L, P = xdt.shape
    N = Bm.shape[-1]

    def step(h, inp):
        x_t, da_t, b_t, c_t = inp
        h = h * jnp.exp(da_t)[:, None, None] \
            + jnp.einsum("bn,bp->bnp", b_t, x_t)
        y_t = jnp.einsum("bn,bnp->bp", c_t, h)
        return h, y_t

    h0 = jnp.zeros((BH, N, P), jnp.float32)
    xs = (jnp.moveaxis(xdt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dA.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cm.astype(jnp.float32), 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(xdt.dtype), h
