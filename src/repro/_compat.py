"""Version shims for jax API moves (non-Pallas; Pallas renames live in
``repro.kernels._compat``).

``jax.tree.flatten_with_path`` / ``jax.tree.map_with_path`` appeared in
jax 0.4.35+ as aliases of the long-standing ``jax.tree_util`` functions;
the container pins an older jaxlib than CI, so checkpointing and LoRA
import the names from here and run on both.
"""
from __future__ import annotations

import jax


def tree_flatten_with_path(tree, is_leaf=None):
    """``jax.tree.flatten_with_path`` with a ``jax.tree_util`` fallback.

    Returns ``(leaves, treedef)`` where leaves are ``(key_path, leaf)``
    pairs, identical on both jax versions.
    """
    fn = getattr(jax.tree, "flatten_with_path", None)
    if fn is None:
        fn = jax.tree_util.tree_flatten_with_path
    return fn(tree, is_leaf=is_leaf)
