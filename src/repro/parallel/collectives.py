"""Gradient-reduction schedules (the Table-1 'network path' lever as a
library).  All operate inside shard_map over a data-parallel axis.

- per_tensor_psum: one all-reduce per tensor (NCCL-naive; message-count
  bound — the "eth0" failure mode).
- bucketed_psum: flatten into one buffer, single all-reduce (bandwidth
  bound — the "hsn0" fix).
- rs_ag: reduce-scatter + all-gather on one buffer (the "RDMA"-class
  schedule; each device reduces only its shard — FSDP's native form).

``benchmarks/table1_ddp.py`` wall-clocks these on a host mesh.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def per_tensor_psum(grads: List[jax.Array], axis: str):
    return [jax.lax.psum(g, axis) for g in grads]


def _flatten(grads):
    sizes = [g.size for g in grads]
    flat = jnp.concatenate([g.reshape(-1) for g in grads])
    return flat, sizes


def _unflatten(flat, grads, sizes):
    out, off = [], 0
    for g, s in zip(grads, sizes):
        out.append(flat[off:off + s].reshape(g.shape))
        off += s
    return out


def bucketed_psum(grads: List[jax.Array], axis: str):
    flat, sizes = _flatten(grads)
    flat = jax.lax.psum(flat, axis)
    return _unflatten(flat, grads, sizes)


def rs_ag(grads: List[jax.Array], axis: str, pad_to: int):
    flat, sizes = _flatten(grads)
    pad = (-flat.size) % pad_to
    flat = jnp.pad(flat, (0, pad))
    red = jax.lax.psum_scatter(flat, axis, scatter_dimension=0, tiled=True)
    flat = jax.lax.all_gather(red, axis, tiled=True)
    if pad:
        flat = flat[:-pad]
    return _unflatten(flat, grads, sizes)
