"""Gradient compression with error feedback for cross-pod reduction.

The paper's Table 1 lesson — the network path dominates small/medium DDP —
motivates shrinking cross-pod gradient bytes.  We compress the pod-axis
all-reduce to bf16 or int8 (per-tensor absmax scale) and carry the
quantization residual in an error-feedback buffer so compression noise
does not accumulate (Karimireddy et al., 2019 semantics).

Usage (trainer-level)::

    state = ef_init(grads)
    grads_c, state = compress_with_feedback(grads, state, bits=8)
    # cross-pod all-reduce runs on grads_c (2-4x fewer wire bytes)
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def ef_init(tree):
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), tree)


def _quant_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, ef_state, bits: int = 8):
    """Returns (compressed-then-decompressed grads, new ef_state).

    The returned grads are what the *receiving* side reconstructs; the
    residual (exact - reconstructed) is fed back into the next step.  On
    the wire the payload is int8+scale (4x) or bf16 (2x) vs f32."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        if bits == 8:
            q, s = _quant_int8(x)
            r = _dequant_int8(q, s)
        elif bits == 16:
            r = x.astype(jnp.bfloat16).astype(jnp.float32)
        else:
            raise ValueError(bits)
        return r, x - r

    flat, td = jax.tree.flatten(grads)
    ef_flat = td.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat, ef_flat)]
    return (td.unflatten([o[0] for o in out]),
            td.unflatten([o[1] for o in out]))


def wire_bytes(tree, bits: int) -> int:
    n = sum(x.size for x in jax.tree.leaves(tree))
    return n * bits // 8 + len(jax.tree.leaves(tree)) * 4  # + scales
