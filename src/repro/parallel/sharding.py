"""Logical-axis sharding rules.

Model code annotates parameters and activations with *logical* axis names;
a per-(shape-kind) rule table maps them onto the production mesh axes
("pod", "data", "model").  This keeps every architecture's model code
mesh-agnostic while the launcher picks DP/FSDP/TP/SP/EP layouts per shape.

Scheme (see DESIGN.md §5) — chosen so that every assigned arch divides
evenly (head counts 12..64 do not divide 16; d_model/d_ff always do):

- train/prefill: batch→data(+pod), FSDP over "data" on each param's fsdp
  dim, TP over "model" for mlp/vocab/experts, and *context-parallel*
  attention (q-sequence over "model", KV all-gathered).
- decode: batch→data(+pod), params TP over "model" replicated over "data"
  (vLLM-style replica×TP), KV-cache sequence over "model".
- long (batch=1): KV/state over ("data","model") combined, SSM heads over
  "model".
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class RuleSet:
    name: str
    rules: Dict[str, AxisVal]

    def resolve(self, logical: Optional[str]) -> AxisVal:
        if logical is None:
            return None
        return self.rules.get(logical, None)

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        parts = []
        used = set()
        for a in axes:
            r = self.resolve(a)
            if isinstance(r, str):
                r = (r,)
            if r:
                r = tuple(x for x in r if x not in used)
                used.update(r)
                parts.append(r if len(r) > 1 else (r[0] if r else None))
                if not r:
                    parts[-1] = None
            else:
                parts.append(None)
        return P(*parts)

    def replace(self, **kw) -> "RuleSet":
        new = dict(self.rules)
        new.update(kw)
        return RuleSet(self.name, new)


_BASE = {
    # parameters
    "fsdp": "data",
    "tensor": "model",
    "expert": "model",
    "layers": None,
    # activations
    "act_batch": ("data",),
    "act_qseq": "model",
    "act_kvseq": None,
    "act_heads": None,
    "act_ff": "model",
    "act_vocab": "model",
    "act_expert": "model",
    "act_ssm_heads": "model",
    "act_embed": None,
}


def make_rules(kind: str, multi_pod: bool = False, **overrides) -> RuleSet:
    """kind: train | prefill | decode | long."""
    r = dict(_BASE)
    batch = ("pod", "data") if multi_pod else ("data",)
    if kind in ("train", "prefill"):
        r["act_batch"] = batch
    elif kind == "decode":
        r.update(
            fsdp=None,
            act_batch=batch,
            act_qseq=None,
            act_kvseq="model",
        )
    elif kind == "long":
        kv = ("pod", "data", "model") if multi_pod else ("data", "model")
        r.update(
            fsdp=None,
            act_batch=None,
            act_qseq=None,
            act_kvseq=kv,
        )
    else:
        raise ValueError(kind)
    r.update(overrides)
    return RuleSet(kind, r)


# ---------------------------------------------------------------------
# context: active (mesh, rules)
class _Ctx(threading.local):
    def __init__(self):
        self.stack = []


_CTX = _Ctx()


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: RuleSet):
    _CTX.stack.append((mesh, rules))
    try:
        with mesh:
            yield
    finally:
        _CTX.stack.pop()


def active() -> Optional[Tuple[Mesh, RuleSet]]:
    return _CTX.stack[-1] if _CTX.stack else None


def current_rules() -> Optional[RuleSet]:
    a = active()
    return a[1] if a else None


def current_mesh() -> Optional[Mesh]:
    a = active()
    return a[0] if a else None


def constrain(x, axes: Sequence[Optional[str]]):
    """with_sharding_constraint against the active rules; no-op otherwise."""
    a = active()
    if a is None:
        return x
    mesh, rules = a
    spec = rules.spec(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named(axes: Sequence[Optional[str]], mesh=None, rules=None) -> NamedSharding:
    a = active()
    mesh = mesh or (a[0] if a else None)
    rules = rules or (a[1] if a else None)
    assert mesh is not None and rules is not None, "no active sharding rules"
    return NamedSharding(mesh, rules.spec(axes))


def tree_shardings(axes_tree, mesh: Mesh, rules: RuleSet):
    """Map a tree of logical-axis tuples to NamedShardings."""
    def _one(axes):
        return NamedSharding(mesh, rules.spec(axes))
    return jax.tree.map(_one, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


def mesh_axis_size(axis: AxisVal) -> int:
    mesh = current_mesh()
    if mesh is None or axis is None:
        return 1
    if isinstance(axis, str):
        axis = (axis,)
    n = 1
    for a in axis:
        n *= mesh.shape[a]
    return n
