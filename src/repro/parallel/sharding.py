"""Logical-axis sharding rules.

Model code annotates parameters and activations with *logical* axis names;
a per-(shape-kind) rule table maps them onto the production mesh axes
("pod", "data", "model").  This keeps every architecture's model code
mesh-agnostic while the launcher picks DP/FSDP/TP/SP/EP layouts per shape.

Scheme (see DESIGN.md §5) — chosen so that every assigned arch divides
evenly (head counts 12..64 do not divide 16; d_model/d_ff always do):

- train/prefill: batch→data(+pod), FSDP over "data" on each param's fsdp
  dim, TP over "model" for mlp/vocab/experts, and *context-parallel*
  attention (q-sequence over "model", KV all-gathered).
- decode: batch→data(+pod), params TP over "model" replicated over "data"
  (vLLM-style replica×TP), KV-cache sequence over "model".
- long (batch=1): KV/state over ("data","model") combined, SSM heads over
  "model".
- serving_tp: single-replica tensor parallelism for the inference engine
  (serving/README.md "Sharded serving"): params TP over "model" with NO
  fsdp (weights replicated along their fsdp dim), attention head-sharded
  (act_heads -> "model", so the paged KV pool shards on its KV-head axis
  and block tables stay host-side), MLPs row/col-sharded (act_ff ->
  "model"), embeddings and logits replicated (act_vocab -> None: the
  unembed output is all-gathered once per step so sampling runs
  replicated and token-identical on every device).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class RuleSet:
    name: str
    rules: Dict[str, AxisVal]

    def resolve(self, logical: Optional[str]) -> AxisVal:
        if logical is None:
            return None
        return self.rules.get(logical, None)

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        parts = []
        used = set()
        for a in axes:
            r = self.resolve(a)
            if isinstance(r, str):
                r = (r,)
            if r:
                r = tuple(x for x in r if x not in used)
                used.update(r)
                parts.append(r if len(r) > 1 else (r[0] if r else None))
                if not r:
                    parts[-1] = None
            else:
                parts.append(None)
        return P(*parts)

    def replace(self, **kw) -> "RuleSet":
        new = dict(self.rules)
        new.update(kw)
        return RuleSet(self.name, new)


_BASE = {
    # parameters
    "fsdp": "data",
    "tensor": "model",
    "expert": "model",
    "layers": None,
    # activations
    "act_batch": ("data",),
    "act_qseq": "model",
    "act_kvseq": None,
    "act_heads": None,
    "act_ff": "model",
    "act_vocab": "model",
    "act_expert": "model",
    "act_ssm_heads": "model",
    "act_embed": None,
}


def make_rules(kind: str, multi_pod: bool = False, **overrides) -> RuleSet:
    """kind: train | prefill | decode | long | serving_tp."""
    r = dict(_BASE)
    batch = ("pod", "data") if multi_pod else ("data",)
    if kind in ("train", "prefill"):
        r["act_batch"] = batch
    elif kind == "decode":
        r.update(
            fsdp=None,
            act_batch=batch,
            act_qseq=None,
            act_kvseq="model",
        )
    elif kind == "long":
        kv = ("pod", "data", "model") if multi_pod else ("data", "model")
        r.update(
            fsdp=None,
            act_batch=None,
            act_qseq=None,
            act_kvseq=kv,
        )
    elif kind == "serving_tp":
        # one sharded replica: every batch/sequence axis stays local (the
        # engine's continuous batch is one replica's traffic), parameters
        # are pure-TP over "model" (no fsdp — a serving replica gains
        # nothing from gather-per-layer), attention is head-sharded so a
        # paged pool leaf (num_blocks, block_size, KV, hd) shards on its
        # KV-head axis and the host-side block tables are untouched, and
        # logits are replicated (one all-gather per step) so sampling is
        # identical on every device.
        r.update(
            fsdp=None,
            # expert=None routes moe_block's "auto" dispatch to the exact
            # dense impl with replicated routed experts (shared experts
            # stay TP-sharded via "tensor"/act_ff): decode tokens-per-
            # step is tiny, so EP's per-step all-to-all costs more than
            # it saves — and the dense impl is the jax<0.5-safe oracle
            expert=None,
            act_batch=None,
            act_qseq=None,
            act_kvseq=None,
            act_heads="model",
            act_ssm_heads=None,
            act_vocab=None,
            act_expert=None,
        )
    else:
        raise ValueError(kind)
    r.update(overrides)
    return RuleSet(kind, r)


# ---------------------------------------------------------------------
# context: active (mesh, rules)
class _Ctx(threading.local):
    def __init__(self):
        self.stack = []


_CTX = _Ctx()


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: RuleSet):
    _CTX.stack.append((mesh, rules))
    try:
        with mesh:
            yield
    finally:
        _CTX.stack.pop()


def active() -> Optional[Tuple[Mesh, RuleSet]]:
    return _CTX.stack[-1] if _CTX.stack else None


def current_rules() -> Optional[RuleSet]:
    a = active()
    return a[1] if a else None


def current_mesh() -> Optional[Mesh]:
    a = active()
    return a[0] if a else None


def constrain(x, axes: Sequence[Optional[str]]):
    """with_sharding_constraint against the active rules; no-op otherwise."""
    a = active()
    if a is None:
        return x
    mesh, rules = a
    spec = rules.spec(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named(axes: Sequence[Optional[str]], mesh=None, rules=None) -> NamedSharding:
    a = active()
    mesh = mesh or (a[0] if a else None)
    rules = rules or (a[1] if a else None)
    assert mesh is not None and rules is not None, "no active sharding rules"
    return NamedSharding(mesh, rules.spec(axes))


def tree_shardings(axes_tree, mesh: Mesh, rules: RuleSet):
    """Map a tree of logical-axis tuples to NamedShardings."""
    def _one(axes):
        return NamedSharding(mesh, rules.spec(axes))
    return jax.tree.map(_one, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


def sharded_jit(fn, mesh: Optional[Mesh] = None,
                rules: Optional[RuleSet] = None, **jit_kw):
    """``jax.jit(fn)`` whose trace (and every retrace) runs under
    ``use_rules(mesh, rules)`` so the ``constrain`` calls inside model
    code bind to real NamedShardings.  With ``mesh=None`` this is plain
    ``jax.jit`` — the single-device path compiles the identical jaxpr it
    always did (``constrain`` is a no-op without an active context)."""
    if mesh is None:
        return jax.jit(fn, **jit_kw)

    def wrapped(*args):
        with use_rules(mesh, rules):
            return fn(*args)

    return jax.jit(wrapped, **jit_kw)


def mesh_axis_size(axis: AxisVal) -> int:
    mesh = current_mesh()
    if mesh is None or axis is None:
        return 1
    if isinstance(axis, str):
        axis = (axis,)
    n = 1
    for a in axis:
        n *= mesh.shape[a]
    return n
