"""Metrics registry: counters, gauges, fixed-bucket histograms.

The operational half of lifecycle observability (paper §5): every
subsystem — scheduler, KV pool, prefix cache, adapter pool, gateway,
trainer — registers its series here, and one registry snapshot answers
the paper's platform questions ("is the KV pool thrashing?", "which
tenant is burning GPU-seconds?") that the end-of-run
``MetricsCollector.summary()`` dict never could.

Design constraints (mirrors ``serving/metrics.py``):

- **Host-side only.**  No jax import, no device syncs — instruments are
  plain Python objects safe to touch from any scheduler/trainer hot
  path; expensive state (pool occupancy, usage aggregates) is *pulled*
  into gauges at snapshot time by each subsystem's ``collect`` hook,
  not pushed per mutation.
- **Fixed buckets.**  Histograms take their bucket upper bounds at
  registration; observation is a bisect + two adds, never a resize.
- **Naming convention** (enforced at registration and by
  ``tools/check_metric_names.py``): ``repro_<subsystem>_<name>_<unit>``
  with the unit suffix drawn from :data:`UNIT_SUFFIXES`; counters end
  in ``_total`` (Prometheus convention).
- **Two export surfaces**: Prometheus text exposition
  (:meth:`MetricsRegistry.to_prometheus`) for scrape-style consumers
  and JSON (:meth:`MetricsRegistry.to_json`) for build artifacts;
  :meth:`MetricsRegistry.snapshot` is the in-process dict view tests
  assert on.
"""
from __future__ import annotations

import bisect
import json
import math
import re
from typing import Dict, List, Optional, Sequence, Tuple

# Allowed metric-name unit suffixes.  ``_total`` marks a counter; the
# rest are gauge/histogram units.  ``_tokens_per_s`` is a composite
# throughput unit (checked before the plain ``_tokens`` suffix).
UNIT_SUFFIXES: Tuple[str, ...] = (
    "_tokens_per_s", "_total", "_seconds", "_tokens", "_blocks", "_bytes",
    "_ratio", "_requests", "_slots", "_nodes", "_count", "_usd", "_steps",
    "_state",
)

_NAME_RE = re.compile(r"^repro_[a-z][a-z0-9]*(_[a-z0-9]+)+$")

# default latency buckets (seconds): micro-benchmarks on a virtual
# clock land in the top bucket; real TTFT/ITL distributions spread
DEFAULT_TIME_BUCKETS: Tuple[float, ...] = (
    0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
    10.0, 30.0, 60.0)


def validate_metric_name(name: str, kind: str = "") -> Optional[str]:
    """Return an error string if ``name`` violates the
    ``repro_<subsystem>_<name>_<unit>`` convention, else ``None``.

    ``kind`` (``counter``/``gauge``/``histogram``) tightens the check:
    counters must end ``_total``, non-counters must not."""
    if not _NAME_RE.match(name):
        return (f"{name!r}: must match repro_<subsystem>_<name>_<unit> "
                "(lowercase, underscore-separated)")
    if name.count("_") < 2:
        return f"{name!r}: needs at least <subsystem> and <unit> parts"
    if not any(name.endswith(s) for s in UNIT_SUFFIXES):
        return (f"{name!r}: unit suffix must be one of "
                f"{sorted(UNIT_SUFFIXES)}")
    if kind == "counter" and not name.endswith("_total"):
        return f"{name!r}: counters must end in _total"
    if kind in ("gauge", "histogram") and name.endswith("_total"):
        return f"{name!r}: _total is reserved for counters"
    return None


def _label_key(labelnames: Sequence[str], labelvalues: Sequence[str]) -> str:
    if not labelnames:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"'
                     for k, v in zip(labelnames, labelvalues))
    return "{" + inner + "}"


def _escape(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace(
        "\n", r"\n")


def _fmt(v: float) -> str:
    """Prometheus number formatting: integers without the trailing .0."""
    if isinstance(v, float) and math.isnan(v):
        return "NaN"
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class _Child:
    """One (metric, label-set) time series."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0):
        if n < 0:
            raise ValueError("counters only go up; use a gauge")
        self.value += n

    def set(self, v: float):
        self.value = float(v)

    def dec(self, n: float = 1.0):
        self.value -= n


class _HistChild:
    """One histogram series: cumulative fixed buckets + sum + count."""
    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Sequence[float]):
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)   # +1 for +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float):
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def cumulative(self) -> List[int]:
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out


class Metric:
    """A metric family: name + kind + optional label names; unlabeled
    families proxy straight to their single child, so
    ``reg.counter("repro_kv_hits_total").inc()`` works without
    ``.labels()``."""

    def __init__(self, name: str, kind: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Optional[Sequence[float]] = None):
        err = validate_metric_name(name, kind)
        if err:
            raise ValueError(f"bad metric name {err}")
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = tuple(labelnames)
        if kind == "histogram":
            bounds = tuple(buckets if buckets is not None
                           else DEFAULT_TIME_BUCKETS)
            if list(bounds) != sorted(bounds) or len(set(bounds)) != len(
                    bounds):
                raise ValueError(f"{name}: buckets must be strictly "
                                 "increasing")
            self.buckets = bounds
        else:
            self.buckets = None
        self._children: Dict[Tuple[str, ...], object] = {}
        if not self.labelnames:
            self._default = self._make()
            self._children[()] = self._default

    def _make(self):
        return (_HistChild(self.buckets) if self.kind == "histogram"
                else _Child())

    def labels(self, **kv):
        if set(kv) != set(self.labelnames):
            raise ValueError(f"{self.name}: expected labels "
                             f"{self.labelnames}, got {sorted(kv)}")
        key = tuple(str(kv[k]) for k in self.labelnames)
        child = self._children.get(key)
        if child is None:
            child = self._make()
            self._children[key] = child
        return child

    # unlabeled proxies
    def inc(self, n: float = 1.0):
        self._default.inc(n)

    def dec(self, n: float = 1.0):
        self._default.dec(n)

    def set(self, v: float):
        self._default.set(v)

    def observe(self, v: float):
        self._default.observe(v)

    @property
    def value(self) -> float:
        return self._default.value


class MetricsRegistry:
    """Get-or-create registry of metric families.

    Re-registering a name returns the existing family (so ``collect``
    hooks can run every snapshot without bookkeeping) but raises if the
    kind or label names changed — a name means one thing, forever."""

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def _get(self, name: str, kind: str, help: str,
             labelnames: Sequence[str],
             buckets: Optional[Sequence[float]] = None) -> Metric:
        m = self._metrics.get(name)
        if m is not None:
            if m.kind != kind or m.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} re-registered as {kind}"
                    f"{tuple(labelnames)} (was {m.kind}{m.labelnames})")
            return m
        m = Metric(name, kind, help, labelnames, buckets)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Metric:
        return self._get(name, "counter", help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Metric:
        return self._get(name, "gauge", help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Optional[Sequence[float]] = None) -> Metric:
        return self._get(name, "histogram", help, labelnames, buckets)

    @property
    def names(self) -> List[str]:
        return sorted(self._metrics)

    def kinds(self) -> Dict[str, str]:
        """``name -> kind`` for every registered metric (lets callers
        split counters from gauges when diffing snapshots)."""
        return {n: self._metrics[n].kind for n in self.names}

    # ------------------------------------------------------------ export
    def snapshot(self) -> Dict[str, object]:
        """Flat dict view: ``name{labels}`` -> value, or for histograms
        -> ``{"sum", "count", "buckets": [(le, cumulative), ...]}``."""
        out: Dict[str, object] = {}
        for m in self._metrics.values():
            for key, child in sorted(m._children.items()):
                series = m.name + _label_key(m.labelnames, key)
                if m.kind == "histogram":
                    cum = child.cumulative()
                    out[series] = {
                        "sum": child.sum, "count": child.count,
                        "buckets": [(le, c) for le, c in
                                    zip(list(m.buckets) + ["+Inf"], cum)]}
                else:
                    out[series] = child.value
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key, child in sorted(m._children.items()):
                if m.kind == "histogram":
                    cum = child.cumulative()
                    for le, c in zip(list(m.buckets) + ["+Inf"], cum):
                        ln = list(zip(m.labelnames, key)) + [
                            ("le", le if le == "+Inf" else _fmt(le))]
                        lk = _label_key([k for k, _ in ln],
                                        [v for _, v in ln])
                        lines.append(f"{name}_bucket{lk} {c}")
                    lk = _label_key(m.labelnames, key)
                    lines.append(f"{name}_sum{lk} {_fmt(child.sum)}")
                    lines.append(f"{name}_count{lk} {child.count}")
                else:
                    lk = _label_key(m.labelnames, key)
                    lines.append(f"{name}{lk} {_fmt(child.value)}")
        return "\n".join(lines) + "\n"

    def to_json(self, indent: Optional[int] = None) -> str:
        doc = {"metrics": [
            {"name": m.name, "kind": m.kind, "help": m.help,
             "series": [
                 {"labels": dict(zip(m.labelnames, key)),
                  **({"sum": ch.sum, "count": ch.count,
                      "buckets": [[le, c] for le, c in
                                  zip(list(m.buckets) + ["+Inf"],
                                      ch.cumulative())]}
                     if m.kind == "histogram" else {"value": ch.value})}
                 for key, ch in sorted(m._children.items())]}
            for m in (self._metrics[n] for n in sorted(self._metrics))]}
        return json.dumps(doc, indent=indent)
