"""Span-based tracer with Chrome/Perfetto ``trace_event`` export.

Records the per-request serving lifecycle (``queued -> prefill ->
decode -> finish``, with ``preempt``/``resume`` excursions), per-tick
scheduler phases, speculative verify launches, and trainer steps as
*spans* — named intervals on named tracks — plus point-in-time instant
events.  The export (:meth:`Tracer.to_perfetto`) is the Chrome
``trace_event`` JSON array format, so a run's timeline opens directly
in https://ui.perfetto.dev or ``chrome://tracing``.

Like ``serving/metrics.py``, the clock is injected: tests and the
benchmark harness drive a virtual clock and get deterministic
timestamps.  All bookkeeping is host-side Python (list appends); there
is no jax import and no device sync anywhere near a jit boundary.

Track model: one Perfetto *thread* per track (``track()`` get-or-
creates a tid and emits the ``thread_name`` metadata event).  Spans on
the same track nest by containment — Perfetto stacks an ``X`` event
inside any enclosing one — which is exactly the scheduler's
``tick > micro_step`` shape and the request's sequential
``queued > prefill > decode`` phases.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, List, Optional


class Span:
    """An open interval on a track; closed by :meth:`Tracer.end`."""
    __slots__ = ("track", "name", "cat", "t0", "args", "closed")

    def __init__(self, track: int, name: str, cat: str, t0: float,
                 args: Optional[Dict[str, Any]]):
        self.track = track
        self.name = name
        self.cat = cat
        self.t0 = t0
        # ``begin`` hands us its own fresh **kwargs dict, so aliasing
        # (not copying) keeps the per-span cost at object construction
        self.args = args if args else {}
        self.closed = False


class Tracer:
    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 process: str = "repro", max_events: int = 500_000):
        self.clock = clock
        self.process = process
        self.max_events = max_events
        self.dropped = 0
        self._t0 = clock()
        self._events: List[Dict[str, Any]] = []
        self._tracks: Dict[str, int] = {}

    # ------------------------------------------------------------ tracks
    def track(self, name: str) -> int:
        """Get-or-create the track (Perfetto thread) named ``name``."""
        tid = self._tracks.get(name)
        if tid is None:
            tid = len(self._tracks) + 1
            self._tracks[name] = tid
        return tid

    # ------------------------------------------------------------ events
    def _us(self, t: float) -> int:
        return int(round((t - self._t0) * 1e6))

    def _emit(self, ev: Dict[str, Any]):
        if len(self._events) >= self.max_events:
            self.dropped += 1
            return
        self._events.append(ev)

    def begin(self, track: str, name: str, cat: str = "",
              **args) -> Span:
        """Open a span on ``track`` (close it with :meth:`end`).  Used
        for non-lexical intervals — a request's ``decode`` phase opens
        at its first token and closes ticks later at finish."""
        return Span(self.track(track), name, cat, self.clock(), args)

    def end(self, span: Span, **more_args):
        """Close ``span`` (idempotent: a double-end is ignored so
        lifecycle teardown paths — finish vs preempt — can both try)."""
        if span.closed:
            return
        span.closed = True
        t1 = self.clock()
        if more_args:
            span.args.update(more_args)
        ts0 = int(round((span.t0 - self._t0) * 1e6))
        dur = int(round((t1 - self._t0) * 1e6)) - ts0
        ev = {"ph": "X", "name": span.name, "pid": 1, "tid": span.track,
              "ts": ts0, "dur": dur if dur > 0 else 0}
        if span.cat:
            ev["cat"] = span.cat
        if span.args:
            ev["args"] = span.args
        self._emit(ev)

    @contextmanager
    def span(self, track: str, name: str, cat: str = "", **args):
        s = self.begin(track, name, cat, **args)
        try:
            yield s
        finally:
            self.end(s)

    def instant(self, track: str, name: str, cat: str = "", **args):
        ev = {"ph": "i", "name": name, "pid": 1, "tid": self.track(track),
              "ts": self._us(self.clock()), "s": "t"}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self._emit(ev)

    def counter(self, track: str, name: str, **values):
        """A Perfetto counter sample (rendered as a track graph)."""
        self._emit({"ph": "C", "name": name, "pid": 1,
                    "tid": self.track(track),
                    "ts": self._us(self.clock()), "args": dict(values)})

    # ------------------------------------------------------------ export
    @property
    def n_events(self) -> int:
        return len(self._events)

    def events_for(self, track: str) -> List[Dict[str, Any]]:
        """All closed events on ``track`` in emission order (tests and
        lifecycle-reconstruction assertions)."""
        tid = self._tracks.get(track)
        return [e for e in self._events if e["tid"] == tid]

    def to_perfetto(self) -> Dict[str, Any]:
        """Chrome ``trace_event`` JSON object: metadata (process/thread
        names) + every recorded event.  ``json.dumps`` of the return
        value is a file Perfetto opens as-is."""
        meta: List[Dict[str, Any]] = [{
            "ph": "M", "name": "process_name", "pid": 1, "tid": 0,
            "args": {"name": self.process}}]
        for name, tid in sorted(self._tracks.items(), key=lambda kv: kv[1]):
            meta.append({"ph": "M", "name": "thread_name", "pid": 1,
                         "tid": tid, "args": {"name": name}})
            # sort_index keeps track order stable (scheduler first,
            # then requests in arrival order)
            meta.append({"ph": "M", "name": "thread_sort_index", "pid": 1,
                         "tid": tid, "args": {"sort_index": tid}})
        return {"traceEvents": meta + list(self._events),
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_perfetto(), indent=indent)
