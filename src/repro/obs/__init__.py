"""Lifecycle observability: metrics registry + trace spans + exporters.

One :class:`Observability` handle bundles the two surfaces every
lifecycle phase instruments against:

- :class:`~repro.obs.registry.MetricsRegistry` — counters, gauges,
  fixed-bucket histograms; snapshot to a dict, Prometheus text, or
  JSON.
- :class:`~repro.obs.tracer.Tracer` — per-request lifecycle spans,
  per-tick scheduler spans, trainer step spans; exports
  Chrome/Perfetto ``trace_event`` JSON.

Wiring: pass ``obs=Observability(clock=...)`` to
``serving.InferenceEngine``, ``core.Gateway``, or
``training.Trainer`` (all default to ``obs=None`` — zero overhead when
off).  Components *push* cheap events (span begin/end, histogram
observations) on their host-side paths and *pull* expensive state
(pool occupancy, usage aggregates) via their ``collect_metrics``
hooks at snapshot time.  Nothing here imports jax and nothing ever
touches a device — instrumentation stays off the jit hot path by
construction.  See docs/observability.md for the metric catalog and
how to open a trace.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

from repro.obs.registry import (DEFAULT_TIME_BUCKETS, MetricsRegistry,
                                UNIT_SUFFIXES, validate_metric_name)
from repro.obs.tracer import Span, Tracer

__all__ = ["Observability", "MetricsRegistry", "Tracer", "Span",
           "validate_metric_name", "UNIT_SUFFIXES",
           "DEFAULT_TIME_BUCKETS"]


class Observability:
    """Registry + tracer pair sharing one (injectable) clock."""

    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 process: str = "repro"):
        self.clock = clock
        self.registry = registry if registry is not None else \
            MetricsRegistry()
        self.tracer = tracer if tracer is not None else \
            Tracer(clock=clock, process=process)

    # ------------------------------------------------------------ dumps
    def write_metrics(self, path: str, fmt: str = "prometheus") -> str:
        """Write the registry snapshot to ``path`` (``prometheus`` text
        or ``json``); returns the path."""
        text = (self.registry.to_json(indent=2) if fmt == "json"
                else self.registry.to_prometheus())
        with open(path, "w", encoding="utf-8") as f:
            f.write(text)
        return path

    def write_trace(self, path: str) -> str:
        """Write the Perfetto ``trace_event`` JSON to ``path``."""
        with open(path, "w", encoding="utf-8") as f:
            f.write(self.tracer.to_json())
        return path
