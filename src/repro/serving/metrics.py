"""LLM serving metrics (paper §5.2): QPS, TTFT, ITL, E2EL.

Timestamps are injected (``clock``) so tests and the benchmark harness can
run against a virtual clock; summaries report the same quantiles the paper
quotes (P50/P99 TTFT, mean ITL, mean E2EL), plus prefix-cache accounting
(hit rate, prefill tokens saved, TTFT split by cache hit/miss — see
serving/README.md) and explicit rejections (a request the engine can
never fit is *rejected*, not silently "finished").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

STATUS_ACTIVE = "active"
STATUS_FINISHED = "finished"
STATUS_REJECTED = "rejected"
STATUS_HANDED_OFF = "handed_off"


@dataclasses.dataclass
class RequestMetrics:
    request_id: str
    arrival: float
    n_prompt: int = 0
    prefill_start: Optional[float] = None
    first_token: Optional[float] = None
    finish: Optional[float] = None
    status: str = STATUS_ACTIVE
    n_cached: int = 0       # prompt tokens served from the prefix cache
    n_preempted: int = 0    # times this request was preempted + requeued
    token_times: List[float] = dataclasses.field(default_factory=list)
    # preemption timeline: preempt_times[i] pairs with resume_times[i]
    # (the next prefill_start); a trailing unpaired preempt_time is a
    # request that never got re-admitted
    preempt_times: List[float] = dataclasses.field(default_factory=list)
    resume_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        return (self.first_token - self.arrival
                if self.first_token is not None else None)

    @property
    def e2el(self) -> Optional[float]:
        return self.finish - self.arrival if self.finish is not None else None

    @property
    def itl(self) -> List[float]:
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]

    @property
    def n_generated(self) -> int:
        return len(self.token_times)

    @property
    def resume_delays(self) -> List[float]:
        """Per-preemption time-to-resume (preempt -> next admission)."""
        return [b - a for a, b in zip(self.preempt_times,
                                      self.resume_times)]


class MetricsCollector:
    def __init__(self):
        self.requests: Dict[str, RequestMetrics] = {}
        # speculative-decoding counters, aggregated per engine: one
        # "row-launch" = one running decode slot scored by one verify
        # launch (so tokens-per-launch is per-sequence, comparable to
        # the baseline's fixed 1.0)
        self.spec_rows = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_emitted = 0

    def arrival(self, rid: str, t: float, n_prompt: int):
        self.requests[rid] = RequestMetrics(rid, t, n_prompt)

    def prefill_start(self, rid: str, t: float):
        r = self.requests[rid]
        r.prefill_start = t
        if len(r.preempt_times) > len(r.resume_times):
            # re-admission after preemption: close the preempt interval
            r.resume_times.append(t)

    def prefix_hit(self, rid: str, n_cached: int):
        """Record that ``n_cached`` prompt tokens were reused from the
        prefix cache (prefill compute the engine did NOT spend).  Clamped
        to the originally submitted prompt length: a preemption-resumed
        request re-prefills its own generated tokens via the cache, and
        counting those would push prefix_hit_rate past 1.0."""
        r = self.requests[rid]
        r.n_cached = max(r.n_cached, min(n_cached, r.n_prompt))

    def token(self, rid: str, t: float):
        r = self.requests[rid]
        if r.first_token is None:
            r.first_token = t
        r.token_times.append(t)

    def finish(self, rid: str, t: float):
        r = self.requests[rid]
        r.finish = t
        r.status = STATUS_FINISHED

    def preempt(self, rid: str, t: float):
        """The paged scheduler reclaimed this request's KV blocks and
        returned it to the queue (it resumes by re-prefilling its prompt
        plus already-generated tokens — usually a prefix-cache hit).
        ``t`` timestamps the preemption; the next ``prefill_start`` for
        this rid closes the interval, and ``summary()`` reports the
        mean time-to-resume."""
        r = self.requests[rid]
        r.n_preempted += 1
        r.preempt_times.append(t)

    def speculative(self, n_drafted: int, n_accepted: int,
                    n_emitted: int):
        """One decode slot went through one speculative verify launch:
        ``n_drafted`` tokens proposed, ``n_accepted`` of them accepted
        by rejection sampling, ``n_emitted`` actually emitted —
        normally ``n_accepted + 1`` (the correction or bonus token
        rides along for free), but fewer when EOS or the generation
        budget truncates the burst mid-way."""
        self.spec_rows += 1
        self.spec_drafted += n_drafted
        self.spec_accepted += n_accepted
        self.spec_emitted += n_emitted

    def reject(self, rid: str, t: float):
        """The request was refused admission (e.g. prompt + generation
        budget exceeds slot capacity) — it never prefilled and must not
        pollute latency quantiles."""
        r = self.requests[rid]
        r.finish = t
        r.status = STATUS_REJECTED

    def handoff(self, rid: str, t: float):
        """Prefill-role terminal event: the request's finished KV was
        exported to the engine's outbox.  Like :meth:`reject` it must
        not pollute this engine's latency quantiles — the request emits
        every token on a *decode* engine whose own collector owns its
        TTFT/ITL/E2EL."""
        r = self.requests[rid]
        r.finish = t
        r.status = STATUS_HANDED_OFF

    @staticmethod
    def _pct(xs, q):
        return float(np.percentile(xs, q)) if xs else float("nan")

    def summary(self) -> Dict[str, float]:
        vals = self.requests.values()
        done = [r for r in vals if r.status == STATUS_FINISHED]
        rejected = [r for r in vals if r.status == STATUS_REJECTED]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        ttfts_hit = [r.ttft for r in done
                     if r.ttft is not None and r.n_cached > 0]
        ttfts_miss = [r.ttft for r in done
                      if r.ttft is not None and r.n_cached == 0]
        itls = [x for r in done for x in r.itl]
        e2els = [r.e2el for r in done if r.e2el is not None]
        gen = sum(r.n_generated for r in done)
        prompt_tokens = sum(r.n_prompt for r in done)
        saved = sum(r.n_cached for r in done)
        span = (max(r.finish for r in done) - min(r.arrival for r in done)
                if done else float("nan"))
        resumes = [d for r in vals for d in r.resume_delays]
        return {
            "completed": len(done),
            "rejected": len(rejected),
            "handed_off": sum(1 for r in vals
                              if r.status == STATUS_HANDED_OFF),
            "preempted": sum(r.n_preempted for r in vals),
            "preempt_to_resume_mean_s": (float(np.mean(resumes))
                                         if resumes else float("nan")),
            "qps": len(done) / span if done and span > 0 else float("nan"),
            "ttft_p50_s": self._pct(ttfts, 50),
            "ttft_p99_s": self._pct(ttfts, 99),
            "ttft_cached_p50_s": self._pct(ttfts_hit, 50),
            "ttft_uncached_p50_s": self._pct(ttfts_miss, 50),
            "itl_mean_s": float(np.mean(itls)) if itls else float("nan"),
            "itl_p99_s": self._pct(itls, 99),
            "e2el_mean_s": float(np.mean(e2els)) if e2els else float("nan"),
            "generated_tokens": gen,
            "prompt_tokens": prompt_tokens,
            "prefill_tokens_saved": saved,
            "prefix_hit_rate": (saved / prompt_tokens
                                if prompt_tokens else 0.0),
            "tokens_per_s": gen / span if done and span > 0 else float("nan"),
            "spec_acceptance_rate": (self.spec_accepted / self.spec_drafted
                                     if self.spec_drafted else float("nan")),
            "spec_tokens_per_launch": (self.spec_emitted / self.spec_rows
                                       if self.spec_rows else float("nan")),
        }

    def collect(self, reg) -> None:
        """Pull aggregate request/speculative accounting into a
        :class:`~repro.obs.registry.MetricsRegistry` (absolute sets —
        safe to call on every snapshot)."""
        vals = self.requests.values()
        done = [r for r in vals if r.status == STATUS_FINISHED]
        prompt = sum(r.n_prompt for r in done)
        saved = sum(r.n_cached for r in done)
        reg.counter("repro_serving_finished_requests_total",
                    "requests that ran to completion").set(len(done))
        reg.counter("repro_serving_generated_tokens_total",
                    "tokens emitted by finished requests").set(
            sum(r.n_generated for r in done))
        reg.counter("repro_serving_prompt_tokens_total",
                    "prompt tokens of finished requests").set(prompt)
        reg.counter("repro_serving_prefill_saved_tokens_total",
                    "prompt tokens served from the prefix cache").set(
            saved)
        reg.gauge("repro_serving_prefix_hit_ratio",
                  "prefix-cache share of finished prompt tokens").set(
            saved / prompt if prompt else 0.0)
        reg.counter("repro_serving_spec_launches_total",
                    "speculative verify row-launches").set(self.spec_rows)
        reg.counter("repro_serving_spec_drafted_tokens_total",
                    "tokens proposed by the drafter").set(self.spec_drafted)
        reg.counter("repro_serving_spec_accepted_tokens_total",
                    "drafted tokens accepted by verify").set(
            self.spec_accepted)
        reg.counter("repro_serving_spec_emitted_tokens_total",
                    "tokens emitted by speculative bursts").set(
            self.spec_emitted)


class TracingMetricsCollector(MetricsCollector):
    """Drop-in :class:`MetricsCollector` that *additionally* streams
    every lifecycle event into an :class:`~repro.obs.Observability`
    handle — per-request trace spans (``queued -> prefill -> decode``
    with ``preempted`` excursions) on one Perfetto track per request,
    and push-style registry series (admission outcome counters,
    TTFT/ITL/E2EL histograms).

    The engine swaps this in when constructed with ``obs=``; every
    existing call site (scheduler, tests) keeps the plain-collector
    timestamps and ``summary()`` behaviour bit-for-bit.
    """

    def __init__(self, obs):
        super().__init__()
        self.obs = obs
        reg = obs.registry
        self._spans = {}           # rid -> open lifecycle Span
        self._admitted = reg.counter(
            "repro_sched_admitted_requests_total",
            "requests that reached prefill (incl. preemption resumes)")
        self._rejected = reg.counter(
            "repro_sched_rejected_requests_total",
            "requests refused admission (can never fit / bad adapter)")
        self._preempted = reg.counter(
            "repro_sched_preemptions_total",
            "running requests preempted back to the queue")
        self._ttft = reg.histogram(
            "repro_serving_ttft_seconds", "time to first token")
        self._itl = reg.histogram(
            "repro_serving_itl_seconds", "inter-token latency")
        self._e2el = reg.histogram(
            "repro_serving_e2el_seconds", "end-to-end request latency")
        self._resume = reg.histogram(
            "repro_serving_preempt_resume_seconds",
            "preemption to re-admission delay")
        self._handoffs = reg.counter(
            "repro_serving_handoff_requests_total",
            "requests handed off to a decode engine after prefill")

    def _track(self, rid: str) -> str:
        return f"req {rid}"

    def _switch(self, rid: str, name: str, **args):
        """End the request's open span (if any) and begin ``name``."""
        tr = self.obs.tracer
        old = self._spans.pop(rid, None)
        if old is not None:
            tr.end(old)
        if name:
            self._spans[rid] = tr.begin(self._track(rid), name,
                                        cat="request", **args)

    # ------------------------------------------------------- overrides
    def arrival(self, rid: str, t: float, n_prompt: int):
        super().arrival(rid, t, n_prompt)
        self._switch(rid, "queued", n_prompt=n_prompt)

    def prefill_start(self, rid: str, t: float):
        r = self.requests[rid]
        resuming = len(r.preempt_times) > len(r.resume_times)
        super().prefill_start(rid, t)
        self._admitted.inc()
        if resuming:
            self._resume.observe(r.resume_delays[-1])
        self._switch(rid, "prefill", resumed=resuming)

    def prefix_hit(self, rid: str, n_cached: int):
        super().prefix_hit(rid, n_cached)
        self.obs.tracer.instant(self._track(rid), "prefix_hit",
                                cat="request", n_cached=n_cached)

    def token(self, rid: str, t: float):
        r = self.requests[rid]
        if r.first_token is None:
            super().token(rid, t)
            self._ttft.observe(r.ttft)
            self._switch(rid, "decode")
        else:
            # steady-state decode is the hottest lifecycle call; ITL
            # observations are batched from token_times at finish()
            super().token(rid, t)

    def finish(self, rid: str, t: float):
        super().finish(rid, t)
        r = self.requests[rid]
        self._e2el.observe(r.e2el)
        tt = r.token_times
        observe = self._itl.observe
        for i in range(1, len(tt)):
            observe(tt[i] - tt[i - 1])
        self._switch(rid, "", )
        self.obs.tracer.instant(self._track(rid), "finish",
                                cat="request", n_generated=r.n_generated)

    def preempt(self, rid: str, t: float):
        super().preempt(rid, t)
        self._preempted.inc()
        self._switch(rid, "preempted")

    def reject(self, rid: str, t: float):
        super().reject(rid, t)
        self._rejected.inc()
        self._switch(rid, "")
        self.obs.tracer.instant(self._track(rid), "reject",
                                cat="request")

    def handoff(self, rid: str, t: float):
        super().handoff(rid, t)
        self._handoffs.inc()
        self._switch(rid, "")
        self.obs.tracer.instant(self._track(rid), "handoff",
                                cat="request")
