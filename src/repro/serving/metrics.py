"""LLM serving metrics (paper §5.2): QPS, TTFT, ITL, E2EL.

Timestamps are injected (``clock``) so tests and the benchmark harness can
run against a virtual clock; summaries report the same quantiles the paper
quotes (P50/P99 TTFT, mean ITL, mean E2EL).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RequestMetrics:
    request_id: str
    arrival: float
    n_prompt: int = 0
    prefill_start: Optional[float] = None
    first_token: Optional[float] = None
    finish: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        return (self.first_token - self.arrival
                if self.first_token is not None else None)

    @property
    def e2el(self) -> Optional[float]:
        return self.finish - self.arrival if self.finish is not None else None

    @property
    def itl(self) -> List[float]:
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]

    @property
    def n_generated(self) -> int:
        return len(self.token_times)


class MetricsCollector:
    def __init__(self):
        self.requests: Dict[str, RequestMetrics] = {}

    def arrival(self, rid: str, t: float, n_prompt: int):
        self.requests[rid] = RequestMetrics(rid, t, n_prompt)

    def prefill_start(self, rid: str, t: float):
        self.requests[rid].prefill_start = t

    def token(self, rid: str, t: float):
        r = self.requests[rid]
        if r.first_token is None:
            r.first_token = t
        r.token_times.append(t)

    def finish(self, rid: str, t: float):
        self.requests[rid].finish = t

    @staticmethod
    def _pct(xs, q):
        return float(np.percentile(xs, q)) if xs else float("nan")

    def summary(self) -> Dict[str, float]:
        done = [r for r in self.requests.values() if r.finish is not None]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        itls = [x for r in done for x in r.itl]
        e2els = [r.e2el for r in done]
        gen = sum(r.n_generated for r in done)
        span = (max(r.finish for r in done) - min(r.arrival for r in done)
                if done else float("nan"))
        return {
            "completed": len(done),
            "qps": len(done) / span if done and span > 0 else float("nan"),
            "ttft_p50_s": self._pct(ttfts, 50),
            "ttft_p99_s": self._pct(ttfts, 99),
            "itl_mean_s": float(np.mean(itls)) if itls else float("nan"),
            "itl_p99_s": self._pct(itls, 99),
            "e2el_mean_s": float(np.mean(e2els)) if e2els else float("nan"),
            "generated_tokens": gen,
            "tokens_per_s": gen / span if done and span > 0 else float("nan"),
        }
