"""KV-cache storage + block accounting for the serving engine.

Two storage layouts (README.md "Paged KV" section):

- ``CacheSlots`` — the original *dense* per-slot layout: ``max_batch``
  preallocated rows of ``capacity`` positions each, length-masked.  Kept
  as the fallback for architectures without position-sliceable KV
  (SSM/hybrid state, encoder-decoder, vision-prefixed).
- ``BlockPool`` + ``PagedCacheSlots`` — vLLM-style paged layout: one
  shared physical pool of ``block_size``-token blocks
  (``M.make_paged_pool``) plus per-slot block tables.  Blocks are
  allocated on demand and ref-counted, so memory tracks *actual* sequence
  lengths (not worst-case capacity) and the radix prefix cache shares
  physical blocks with running requests instead of copying KV segments.

``BlockLedger`` is the admission-control account for the dense path (and
the node budget of the prefix cache); the paged path accounts in real
pool blocks instead.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.parallel import sharding


def tree_walk(fn, tree, axes):
    """Apply ``fn(leaf, axes_tuple)`` over a cache pytree with its axes
    (the single recursion every cache-shaped traversal shares)."""
    if isinstance(tree, dict):
        return {k: tree_walk(fn, tree[k], axes[k]) for k in tree}
    if isinstance(tree, list):
        return [tree_walk(fn, t, a) for t, a in zip(tree, axes)]
    return fn(tree, axes)


def tree_multi(fn, trees, axes):
    """Like :func:`tree_walk` over N structurally-identical pytrees:
    ``fn([leaf0, .., leafN], axes_tuple)``."""
    head = trees[0]
    if isinstance(head, dict):
        return {k: tree_multi(fn, [t[k] for t in trees], axes[k])
                for k in head}
    if isinstance(head, list):
        return [tree_multi(fn, [t[i] for t in trees], axes[i])
                for i in range(len(head))]
    return fn(trees, axes)


def constrain_cache(tree, axes):
    """Re-assert each cache leaf's sharding (inside a jit, under active
    rules) so donated caches/pools keep a *stable* NamedSharding across
    steps instead of whatever layout the partitioner picked last.  A
    no-op without an active rules context — the single-device jaxpr is
    untouched."""
    if sharding.active() is None:
        return tree
    return tree_walk(lambda a, ax: sharding.constrain(a, ax), tree, axes)


class BlockLedger:
    """Admission-control accounting in ``block_size``-token blocks.

    A pure bookkeeping object — it reserves *budget*, not storage: the
    dense engine charges each request's worst case (prompt + generation
    budget) here before touching a slot, and the prefix cache uses a
    dedicated ledger as its node budget.  Invariants:

    - Reservations are **rid-keyed and idempotent**: ``can_admit``/
      ``admit`` count blocks ``rid`` already holds toward its allowance,
      so re-admitting a retried request never double-charges.
    - **Never over-commits**: ``admit``/``grow`` raise once the pool is
      exhausted rather than silently handing out blocks that do not
      exist — the caller must preempt or reject (the PR-2 fix; the old
      ``grow`` silently over-committed).
    - ``release`` is unconditional and forgets the rid entirely;
      ``peak_blocks`` tracks the high-water mark for ``kv_stats``.
    """

    def __init__(self, capacity_tokens: int, block_size: int = 128):
        self.block_size = block_size
        self.total_blocks = capacity_tokens // block_size
        self.used: Dict[str, int] = {}
        self.peak_blocks = 0

    def blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    @property
    def free_blocks(self) -> int:
        return self.total_blocks - sum(self.used.values())

    def _note_peak(self):
        self.peak_blocks = max(self.peak_blocks,
                               self.total_blocks - self.free_blocks)

    def can_admit(self, rid: str, tokens: int) -> bool:
        """Admission check for ``rid``.  Blocks ``rid`` already holds count
        toward its allowance, so re-admission (e.g. a retried request that
        never released) is idempotent rather than double-charged."""
        return (self.blocks_for(tokens)
                <= self.free_blocks + self.used.get(rid, 0))

    def admit(self, rid: str, tokens: int):
        need = self.blocks_for(tokens)
        if need > self.free_blocks + self.used.get(rid, 0):
            raise RuntimeError("KV cache exhausted")
        self.used[rid] = need
        self._note_peak()

    def grow(self, rid: str, tokens: int):
        """Grow ``rid``'s reservation to cover ``tokens``.

        Never over-commits: growth past the pool raises so the caller can
        preempt a running request (or reject) instead of silently handing
        out blocks that do not exist.
        """
        need = self.blocks_for(tokens)
        held = self.used.get(rid, 0)
        if need <= held:
            return
        if need - held > self.free_blocks:
            raise RuntimeError(
                f"KV cache exhausted: {rid} needs {need - held} more "
                f"block(s), {self.free_blocks} free — preempt or reject")
        self.used[rid] = need
        self._note_peak()

    def release(self, rid: str):
        self.used.pop(rid, None)

    def collect_metrics(self, reg) -> None:
        """Pull ledger occupancy into a metrics registry (the dense
        engine's ``repro_kv_*`` series — same names as the paged
        pool's, so dashboards are layout-agnostic)."""
        used = self.total_blocks - self.free_blocks
        reg.gauge("repro_kv_used_blocks",
                  "KV blocks currently reserved").set(used)
        reg.gauge("repro_kv_free_blocks",
                  "KV blocks available for admission").set(
            self.free_blocks)
        reg.gauge("repro_kv_peak_blocks",
                  "high-water mark of reserved KV blocks").set(
            self.peak_blocks)
        reg.gauge("repro_kv_capacity_blocks",
                  "total allocatable KV blocks").set(self.total_blocks)
        reg.gauge("repro_kv_block_size_tokens",
                  "tokens per KV block").set(self.block_size)


class CacheSlots:
    """Fixed decode batch of B slots, each with ``capacity`` positions.

    With ``mesh`` + ``rules`` the cache leaves are laid out as
    NamedShardings resolved from their logical axes (under
    ``serving_tp``: head-sharded for GQA, replicated for the MLA
    latent) and the insert jit traces under those rules, so a sharded
    engine's dense fallback keeps KV distributed too."""

    def __init__(self, cfg: ModelConfig, max_batch: int, capacity: int,
                 dtype=jnp.bfloat16, mesh=None, rules=None):
        self.cfg = cfg
        self.B = max_batch
        self.capacity = capacity
        self.mesh, self.rules = mesh, rules
        self.cache = M.make_cache(cfg, max_batch, capacity, dtype)
        self._axes = M.cache_axes(cfg)
        if mesh is not None:
            self.cache = jax.device_put(
                self.cache,
                sharding.tree_shardings(self._axes, mesh, rules))
        self.lengths = jnp.ones((max_batch,), jnp.int32)  # 1 = inert slot
        # deque: allocate() pops the head, release() appends — O(1) FIFO
        self.free: Deque[int] = deque(range(max_batch))
        self.slot_owner: Dict[int, str] = {}
        self._insert = sharding.sharded_jit(self._insert_impl, mesh, rules,
                                            donate_argnums=(0,))

    def _insert_impl(self, cache, prefill_cache, slot):
        """Write a single-sequence prefill cache (1, S, ...) into slot."""
        def one(leaves, ax):
            dst, src = leaves
            bi = ax.index("act_batch")
            src = src.astype(dst.dtype)
            start = [jnp.asarray(0, jnp.int32)] * dst.ndim
            start[bi] = slot
            # pad the seq dim of src up to dst (already <= capacity)
            pads = []
            for i, (ds, ss) in enumerate(zip(dst.shape, src.shape)):
                pads.append((0, (ds - ss) if i != bi else 0))
            src = jnp.pad(src, pads)
            return jax.lax.dynamic_update_slice(dst, src, start)

        out = tree_multi(one, [cache, prefill_cache], self._axes)
        return constrain_cache(out, self._axes)

    def allocate(self, rid: str) -> Optional[int]:
        if not self.free:
            return None
        slot = self.free.popleft()
        self.slot_owner[slot] = rid
        return slot

    def insert(self, slot: int, prefill_cache, length: int):
        self.cache = self._insert(self.cache, prefill_cache,
                                  jnp.asarray(slot, jnp.int32))
        self.lengths = self.lengths.at[slot].set(length)

    def extract(self, slot: int, start: int, end: int):
        """Copy KV for positions ``[start, end)`` out of ``slot``.

        Returns a pytree shaped like a single-sequence prefill cache
        (``act_batch == 1``, ``act_kvseq == end - start``) — the segment
        format the prefix cache stores.  Only meaningful for caches whose
        leaves all carry an ``act_kvseq`` axis (pure attention)."""
        def one(arr, ax):
            if "act_kvseq" not in ax:
                raise ValueError(
                    "extract() needs position-sliceable cache leaves "
                    f"(axes {ax} has no act_kvseq)")
            idx = [slice(None)] * arr.ndim
            idx[ax.index("act_batch")] = slice(slot, slot + 1)
            idx[ax.index("act_kvseq")] = slice(start, end)
            return arr[tuple(idx)]

        return tree_walk(one, self.cache, self._axes)

    def release(self, slot: int):
        self.slot_owner.pop(slot, None)
        self.lengths = self.lengths.at[slot].set(1)
        self.free.append(slot)

    @property
    def active_slots(self) -> List[int]:
        return sorted(self.slot_owner)


# ------------------------------------------------------------------ paged
NULL_BLOCK = 0   # reserved physical block: writes from inert slots and
                 # reads past a sequence's length land here, never on data


@dataclasses.dataclass
class KVHandoff:
    """Host-side KV migration payload (prefill engine -> decode engine).

    Produced by :meth:`PagedCacheSlots.export_kv` on the prefill side:
    the finished prefill's physical blocks gathered to host memory in
    block-major layout (each cache leaf becomes ``(n_blocks, ...)`` with
    the pool's block axis moved to the front).  Consumed by
    :meth:`PagedCacheSlots.import_kv` on the decode side, which
    allocates fresh pool blocks and scatters the payload back — or, for
    a prefix the decode-side radix tree already holds, splices the
    shared blocks in place of re-uploading them.

    The payload is plain host data: it survives the death of either
    engine, so a crash mid-handoff is recovered by re-importing the same
    object elsewhere (token-exact at temperature 0).  ``prompt_tokens``
    doubles as the prefix-cache key on the decode side; ``adapter``
    names the LoRA adapter whose pin must transfer with the request
    (the adapter *weights* must already be registered on the decode
    pool — the handoff moves KV, not parameters).
    """
    request_id: str
    length: int              # prompt tokens materialised in the blocks
    block_size: int
    n_blocks: int
    blocks: Any              # host pytree; leaf (n_blocks, ...) block-major
    prompt_tokens: List[int] = dataclasses.field(default_factory=list)
    adapter: str = ""
    exported_at: float = 0.0  # engine-clock export timestamp

    @property
    def payload_bytes(self) -> int:
        import jax as _jax
        return sum(leaf.nbytes for leaf in _jax.tree.leaves(self.blocks))


class BlockPool:
    """Ref-counted allocator over the physical blocks of a paged pool.

    One block id spans every layer leaf of the pool (see
    ``M.make_paged_pool``), so allocation is accounted in token blocks,
    not per-layer bytes.  Invariants:

    - **Null block**: block 0 is reserved and never allocated.  Inert
      decode slots scatter their (masked) writes there and block-table
      tails point there; the attention length mask guarantees it is
      never *read*, so no live KV can be corrupted by an idle slot.
    - **Refcount lifecycle**: ``alloc`` hands out ids at refcount 1
      (all-or-nothing for multi-block requests); ``incref`` adds holders
      — the radix prefix tree (one ref per stored node) and every
      running request that adopted the block via a prefix hit;
      ``decref`` frees a block only at refcount 0.  Consequence: tree
      eviction never invalidates a running request, and slot release
      never invalidates the tree.
    - **Shared blocks are read-only by construction**: the tree stores
      only whole prompt blocks, and a sequence writes strictly after its
      adopted prefix, in blocks it allocated privately — so a refcount
      > 1 block is never written.
    - ``incref``/``decref`` on an unallocated id raise — refcount bugs
      surface immediately instead of corrupting the free list.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("pool needs at least one allocatable block")
        self.num_blocks = num_blocks
        self.free: Deque[int] = deque(range(1, num_blocks))
        self.refs: Dict[int, int] = {}
        self.peak_used = 0

    @property
    def num_free(self) -> int:
        return len(self.free)

    @property
    def num_used(self) -> int:
        return (self.num_blocks - 1) - len(self.free)

    def alloc(self, n: int = 1) -> Optional[List[int]]:
        """Allocate ``n`` blocks (refcount 1 each), all-or-nothing."""
        if n > len(self.free):
            return None
        ids = [self.free.popleft() for _ in range(n)]
        for b in ids:
            self.refs[b] = 1
        self.peak_used = max(self.peak_used, self.num_used)
        return ids

    def incref(self, ids: Sequence[int]):
        for b in ids:
            if b not in self.refs:
                raise ValueError(f"incref on unallocated block {b}")
            self.refs[b] += 1

    def decref(self, ids: Sequence[int]) -> int:
        """Drop one reference per id; returns how many blocks were freed."""
        freed = 0
        for b in ids:
            r = self.refs.get(b)
            if r is None:
                raise ValueError(f"decref on unallocated block {b}")
            if r > 1:
                self.refs[b] = r - 1
            else:
                del self.refs[b]
                self.free.append(b)
                freed += 1
        return freed

    def collect_metrics(self, reg, block_size: int = 0) -> None:
        """Pull pool occupancy into a metrics registry.  Gauges track
        the live pool state ("is the KV pool thrashing?"); shared
        (refcount > 1) blocks — prefix-cache hits adopted by running
        requests — are reported separately so the copy-free sharing win
        is visible as a series, not just a benchmark row."""
        reg.gauge("repro_kv_used_blocks",
                  "physical KV blocks allocated").set(self.num_used)
        reg.gauge("repro_kv_free_blocks",
                  "physical KV blocks on the free list").set(
            self.num_free)
        reg.gauge("repro_kv_peak_blocks",
                  "high-water mark of allocated KV blocks").set(
            self.peak_used)
        reg.gauge("repro_kv_capacity_blocks",
                  "total allocatable KV blocks (excl. null)").set(
            self.num_blocks - 1)
        reg.gauge("repro_kv_shared_blocks",
                  "blocks referenced by more than one holder").set(
            sum(1 for r in self.refs.values() if r > 1))
        if block_size:
            reg.gauge("repro_kv_block_size_tokens",
                      "tokens per KV block").set(block_size)


class PagedCacheSlots:
    """Paged counterpart of :class:`CacheSlots`.

    ``max_batch`` block-table rows (one per decode slot) over a shared
    :class:`BlockPool` of ``pool_tokens // block_size`` physical blocks.
    A slot's KV lives wherever its table points, so

    - memory tracks actual lengths: short sequences hold few blocks, and
      more than ``pool_tokens / capacity`` sequences can run concurrently
      whenever their live lengths fit (the dense layout pins
      ``max_batch × capacity`` up front);
    - a prefix-cache hit is a table splice + refcount bump
      (``adopt_prefix``) — no KV bytes move in either direction;
    - growth is a real allocation (``ensure_capacity``), so running out
      of blocks is an explicit event the scheduler answers with tree
      eviction or preemption, never a silent over-commit.

    Shared (adopted) blocks are read-only by construction: the prefix
    cache stores only *whole* prompt blocks, and a sequence writes
    strictly after its adopted prefix, in blocks it allocated privately.

    Slot invariants: an inert slot has ``lengths[slot] == 1`` and a
    table full of ``NULL_BLOCK`` entries, so its decode-step writes land
    in the null block and its reads are masked out; ``release`` decrefs
    exactly the blocks in ``seq_blocks[slot]`` (the slot's own + adopted
    ids) and resets the table row.  ``tables_device`` caches the device
    copy of the table matrix and every table mutation invalidates it
    (``_touch_tables``), so a micro-step uploads tables at most once.
    """

    def __init__(self, cfg: ModelConfig, max_batch: int, capacity: int,
                 dtype=jnp.bfloat16, block_size: int = 16,
                 pool_tokens: Optional[int] = None, mesh=None, rules=None,
                 kv_dtype: str = "bf16"):
        if kv_dtype not in ("bf16", "int8"):
            raise ValueError(f"kv_dtype must be 'bf16' or 'int8', "
                             f"got {kv_dtype!r}")
        self.cfg = cfg
        self.B = max_batch
        self.capacity = capacity
        self.block_size = block_size
        self.kv_dtype = kv_dtype
        self.mesh, self.rules = mesh, rules
        self.blocks_per_seq = -(-capacity // block_size)
        pool_tokens = (max_batch * capacity if pool_tokens is None
                       else pool_tokens)
        if kv_dtype == "int8":
            # ``pool_tokens`` is a bf16-byte-equivalent budget: int8
            # blocks cost half the bytes, so the same budget buys twice
            # the physical blocks — admission capacity doubles for free
            num_blocks = 1 + max((pool_tokens * 2) // block_size,
                                 self.blocks_per_seq)
            self.pool = M.make_quantized_paged_pool(cfg, num_blocks,
                                                    block_size)
            self._axes = M.paged_pool_axes(cfg, "int8")
        else:
            num_blocks = 1 + max(pool_tokens // block_size,
                                 self.blocks_per_seq)
            self.pool = M.make_paged_pool(cfg, num_blocks, block_size,
                                          dtype)
            self._axes = M.cache_axes(cfg)
        if mesh is not None:
            # a pool leaf is (num_blocks, block_size, ...) in the cache's
            # (act_batch, act_kvseq, ...) axis slots; under serving_tp
            # both map to None, so the pool shards exactly on the KV-head
            # axis (GQA) or stays replicated (MLA latent) — block ids,
            # tables, and all host-side accounting are layout-invariant
            self.pool = jax.device_put(
                self.pool,
                sharding.tree_shardings(self._axes, mesh, rules))
        self.bp = BlockPool(num_blocks)
        self.tables = np.full((max_batch, self.blocks_per_seq), NULL_BLOCK,
                              np.int32)
        self.lengths = np.ones((max_batch,), np.int32)  # 1 = inert slot
        self.seq_blocks: Dict[int, List[int]] = {}
        self.free: Deque[int] = deque(range(max_batch))
        self.slot_owner: Dict[int, str] = {}
        self._tables_dev = None
        scatter_impl = (self._scatter_impl_q if kv_dtype == "int8"
                        else self._scatter_impl)
        self._scatter = sharding.sharded_jit(scatter_impl, mesh, rules,
                                             donate_argnums=(0,))
        # KV handoff (disaggregated prefill/decode): gather reads block
        # contents out (no donation — the pool stays live), the block
        # scatter writes an imported payload into freshly allocated ids
        self._gather = sharding.sharded_jit(self._gather_impl, mesh, rules)
        self._scatter_blocks = sharding.sharded_jit(
            self._scatter_blocks_impl, mesh, rules, donate_argnums=(0,))

    # ------------------------------------------------------------ tables
    def tables_device(self) -> jax.Array:
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self.tables)
        return self._tables_dev

    def _touch_tables(self):
        self._tables_dev = None

    # ------------------------------------------------------------ slots
    def allocate(self, rid: str) -> Optional[int]:
        if not self.free:
            return None
        slot = self.free.popleft()
        self.slot_owner[slot] = rid
        return slot

    def release(self, slot: int):
        self.slot_owner.pop(slot, None)
        ids = self.seq_blocks.pop(slot, [])
        if ids:
            self.bp.decref(ids)
        self.tables[slot, :] = NULL_BLOCK
        self._touch_tables()
        self.lengths[slot] = 1
        self.free.append(slot)

    @property
    def active_slots(self) -> List[int]:
        return sorted(self.slot_owner)

    # ------------------------------------------------------------ blocks
    def blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    def ensure_capacity(self, slot: int, new_len: int) -> bool:
        """Allocate blocks so positions ``[0, new_len)`` are addressable.
        False when the pool cannot supply them (caller reclaims/preempts)."""
        have = self.seq_blocks.setdefault(slot, [])
        need = self.blocks_for(new_len)
        if need <= len(have):
            return True
        if need > self.blocks_per_seq:
            return False
        ids = self.bp.alloc(need - len(have))
        if ids is None:
            return False
        self.tables[slot, len(have):need] = ids
        have.extend(ids)
        self._touch_tables()
        return True

    def adopt_prefix(self, slot: int, ids: Sequence[int], length: int):
        """Copy-free prefix hit: splice shared physical blocks into this
        slot's table (refcount bump — the blocks themselves never move)."""
        assert length == len(ids) * self.block_size, "whole blocks only"
        assert not self.seq_blocks.get(slot), "adopt into a fresh slot"
        self.bp.incref(ids)
        self.seq_blocks[slot] = list(ids)
        self.tables[slot, :len(ids)] = ids
        self._touch_tables()
        self.lengths[slot] = length

    def block_ids(self, slot: int) -> List[int]:
        return list(self.seq_blocks.get(slot, []))

    def trim(self, slot: int, length: int):
        """Roll back a speculative over-allocation: decref blocks past
        ``blocks_for(length)`` and null their table entries.

        The speculative verify step writes k+1 tail positions before
        knowing how many survive accept/reject, so the scheduler grows
        every slot to ``len + k + 1`` up front and trims back to the
        accepted length here.  Only *privately allocated* tail blocks
        can be freed: a slot's length never shrinks below its adopted
        prefix (whole blocks, written strictly before any speculation),
        so shared blocks are never decref'd past their adoption."""
        have = self.seq_blocks.get(slot)
        if not have:
            return
        keep = self.blocks_for(max(int(length), 1))
        if keep >= len(have):
            return
        extra = have[keep:]
        del have[keep:]
        self.bp.decref(extra)
        self.tables[slot, keep:keep + len(extra)] = NULL_BLOCK
        self._touch_tables()

    # ------------------------------------------------------------ prefill
    def _scatter_impl(self, pool, prefill_cache, ids):
        """Write a single-sequence prefill cache (1, S, ...) into the
        ``len(ids)`` physical blocks named by ``ids``."""
        nblk = ids.shape[0]
        blk = self.block_size

        def one(leaves, ax):
            dst, src = leaves
            bi = ax.index("act_batch")
            ki = ax.index("act_kvseq")
            src = src.astype(dst.dtype)
            span = nblk * blk
            if src.shape[ki] < span:
                pads = [(0, 0)] * src.ndim
                pads[ki] = (0, span - src.shape[ki])
                src = jnp.pad(src, pads)
            idx = [slice(None)] * src.ndim
            idx[ki] = slice(0, span)
            src = src[tuple(idx)]
            shape = list(src.shape)
            shape[bi:ki + 1] = [nblk, blk]
            src = src.reshape(shape)
            d = jnp.moveaxis(dst, bi, 0)
            s = jnp.moveaxis(src, bi, 0)
            return jnp.moveaxis(d.at[ids].set(s), 0, bi)

        out = tree_multi(one, [pool, prefill_cache], self._axes)
        return constrain_cache(out, self._axes)

    def _scatter_impl_q(self, pool, prefill_cache, ids):
        """Int8 variant of :meth:`_scatter_impl`: quantize the dense
        (bf16) prefill cache into the int8 pool at write time, computing
        each block's symmetric scale over everything the scale leaf does
        not index (in-block positions and feature dims; per KV head when
        the leaf has a head axis).  The prefill cache carries no scale
        leaves — they are derived here."""
        nblk = ids.shape[0]
        blk = self.block_size

        def qone(dst, sc, src, ax, sc_ax):
            bi = ax.index("act_batch")
            ki = ax.index("act_kvseq")
            span = nblk * blk
            src = src.astype(jnp.float32)
            if src.shape[ki] < span:
                pads = [(0, 0)] * src.ndim
                pads[ki] = (0, span - src.shape[ki])
                src = jnp.pad(src, pads)
            idx = [slice(None)] * src.ndim
            idx[ki] = slice(0, span)
            src = src[tuple(idx)]
            shape = list(src.shape)
            shape[bi:ki + 1] = [nblk, blk]
            src = src.reshape(shape)
            # after the reshape the act_batch slot is the block axis and
            # the act_kvseq slot the in-block position; reduce the scale
            # over every axis the scale leaf does not keep
            labels = list(ax)
            labels[bi] = "act_batch"
            labels[ki] = None
            red = tuple(i for i, a in enumerate(labels)
                        if a not in ("layers", "act_batch", "act_heads"))
            s_kd = jnp.max(jnp.abs(src), axis=red, keepdims=True) / 127.0
            q = jnp.clip(jnp.round(src / jnp.maximum(s_kd, 1e-12)),
                         -127, 127).astype(dst.dtype)
            scale = jnp.squeeze(s_kd, axis=red)
            d_new = jnp.moveaxis(
                jnp.moveaxis(dst, bi, 0).at[ids].set(
                    jnp.moveaxis(q, bi, 0)), 0, bi)
            sbi = sc_ax.index("act_batch")
            s_new = jnp.moveaxis(
                jnp.moveaxis(sc, sbi, 0).at[ids].set(
                    jnp.moveaxis(scale, sbi, 0)), 0, sbi)
            return d_new, s_new

        def walk(pl, pc, ax):
            if isinstance(pl, dict):
                if any(k.endswith("_scale") for k in pl):
                    out: Dict[str, Any] = {}
                    for k in pl:
                        if k.endswith("_scale"):
                            continue
                        d_new, s_new = qone(pl[k], pl[k + "_scale"],
                                            pc[k], ax[k],
                                            ax[k + "_scale"])
                        out[k] = d_new
                        out[k + "_scale"] = s_new
                    return out
                return {k: walk(pl[k], pc[k], ax[k]) for k in pl}
            if isinstance(pl, list):
                return [walk(p, c, a) for p, c, a in zip(pl, pc, ax)]
            raise TypeError("int8 pool leaf without a scale sibling")

        out = walk(pool, prefill_cache, self._axes)
        return constrain_cache(out, self._axes)

    def insert_prefill(self, slot: int, prefill_cache, length: int):
        """Scatter a prefill cache for positions ``[0, length)`` into the
        slot's (already allocated) blocks.  Positions past ``length``
        inside the last block hold padding until decode overwrites them;
        attention masks them via ``lengths``."""
        nblk = self.blocks_for(length)
        ids = self.seq_blocks.get(slot, [])
        assert len(ids) >= nblk, "ensure_capacity() before insert_prefill()"
        self.pool = self._scatter(self.pool, prefill_cache,
                                  jnp.asarray(ids[:nblk], jnp.int32))
        self.lengths[slot] = length

    # ------------------------------------------------------------ handoff
    def _gather_impl(self, pool, ids):
        """Read the ``len(ids)`` physical blocks named by ``ids`` out of
        the pool, block axis first — the exact inverse layout of
        :meth:`_scatter_blocks_impl`."""
        def one(arr, ax):
            bi = ax.index("act_batch")
            return jnp.moveaxis(arr, bi, 0)[ids]

        return tree_walk(one, pool, self._axes)

    def _scatter_blocks_impl(self, pool, blocks, ids):
        """Write block-major payloads (leaf ``(len(ids), ...)``) into the
        physical blocks named by ``ids``."""
        def one(leaves, ax):
            dst, src = leaves
            bi = ax.index("act_batch")
            d = jnp.moveaxis(dst, bi, 0)
            return jnp.moveaxis(d.at[ids].set(src.astype(dst.dtype)), 0, bi)

        out = tree_multi(one, [pool, blocks], self._axes)
        return constrain_cache(out, self._axes)

    def export_kv(self, rid: str) -> KVHandoff:
        """Export request ``rid``'s finished-prefill KV as a host-side
        :class:`KVHandoff` (block contents + length).  The slot keeps
        its blocks — the caller releases it after the export so a failed
        handoff never loses the KV mid-flight."""
        slot = next((s for s, r in self.slot_owner.items() if r == rid),
                    None)
        if slot is None:
            raise KeyError(f"export_kv: no slot owned by {rid!r}")
        length = int(self.lengths[slot])
        nblk = self.blocks_for(length)
        ids = self.seq_blocks.get(slot, [])[:nblk]
        assert len(ids) == nblk, "slot blocks do not cover its length"
        blocks = jax.device_get(
            self._gather(self.pool, jnp.asarray(ids, jnp.int32)))
        return KVHandoff(request_id=rid, length=length,
                         block_size=self.block_size, n_blocks=nblk,
                         blocks=blocks)

    def import_kv(self, slot: int, handoff: KVHandoff,
                  adopted_ids: Sequence[int] = (),
                  adopted_tokens: int = 0) -> bool:
        """Import a :class:`KVHandoff` into a fresh slot: allocate pool
        blocks for the payload (through :meth:`BlockPool.alloc`, so
        imported blocks are charged to the pool's peak accounting like
        any other allocation), scatter the block contents, and splice
        the table.  ``adopted_ids`` names shared-prefix blocks the
        decode-side radix tree already holds — those are refcount-spliced
        (:meth:`adopt_prefix`) instead of re-uploaded, and only the
        payload tail past ``adopted_tokens`` moves.

        Returns False when the pool cannot supply the private blocks;
        the caller must then roll back by releasing the slot (which
        decrefs any adopted prefix) and defer the handoff."""
        if handoff.block_size != self.block_size:
            raise ValueError(
                f"handoff block size {handoff.block_size} != pool block "
                f"size {self.block_size} — repack before migrating")
        if adopted_ids:
            self.adopt_prefix(slot, adopted_ids, adopted_tokens)
        if not self.ensure_capacity(slot, handoff.length):
            return False
        k0 = len(adopted_ids)
        if handoff.n_blocks > k0:
            ids = self.seq_blocks[slot][k0:handoff.n_blocks]
            tail = tree_walk(lambda a, ax: jnp.asarray(a[k0:]),
                             handoff.blocks, self._axes)
            self.pool = self._scatter_blocks(
                self.pool, tail, jnp.asarray(ids, jnp.int32))
        self.lengths[slot] = handoff.length
        return True
