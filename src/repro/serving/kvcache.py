"""KV-cache slot management + block-ledger admission control.

TPU-idiomatic adaptation of vLLM's paged KV cache (DESIGN.md §2): TPU
serving stacks keep *dense per-slot* KV buffers with length masking (GPU
paged-attention's random block gathers defeat the MXU/VMEM layout), while
capacity accounting still happens in fixed-size blocks so the scheduler
admits requests exactly like vLLM does (no admission -> request waits,
preventing cache OOM).  The radix prefix cache reuses both pieces:
``CacheSlots.extract`` slices stored KV segments out of a slot and a
dedicated ``BlockLedger`` accounts cached blocks (see README.md).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


def tree_walk(fn, tree, axes):
    """Apply ``fn(leaf, axes_tuple)`` over a cache pytree with its axes
    (the single recursion every cache-shaped traversal shares)."""
    if isinstance(tree, dict):
        return {k: tree_walk(fn, tree[k], axes[k]) for k in tree}
    if isinstance(tree, list):
        return [tree_walk(fn, t, a) for t, a in zip(tree, axes)]
    return fn(tree, axes)


def tree_multi(fn, trees, axes):
    """Like :func:`tree_walk` over N structurally-identical pytrees:
    ``fn([leaf0, .., leafN], axes_tuple)``."""
    head = trees[0]
    if isinstance(head, dict):
        return {k: tree_multi(fn, [t[k] for t in trees], axes[k])
                for k in head}
    if isinstance(head, list):
        return [tree_multi(fn, [t[i] for t in trees], axes[i])
                for i in range(len(head))]
    return fn(trees, axes)


class BlockLedger:
    """Block accounting (block_size tokens per block) for admission."""

    def __init__(self, capacity_tokens: int, block_size: int = 128):
        self.block_size = block_size
        self.total_blocks = capacity_tokens // block_size
        self.used: Dict[str, int] = {}

    def blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    @property
    def free_blocks(self) -> int:
        return self.total_blocks - sum(self.used.values())

    def can_admit(self, rid: str, tokens: int) -> bool:
        """Admission check for ``rid``.  Blocks ``rid`` already holds count
        toward its allowance, so re-admission (e.g. a retried request that
        never released) is idempotent rather than double-charged."""
        return (self.blocks_for(tokens)
                <= self.free_blocks + self.used.get(rid, 0))

    def admit(self, rid: str, tokens: int):
        need = self.blocks_for(tokens)
        if need > self.free_blocks + self.used.get(rid, 0):
            raise RuntimeError("KV cache exhausted")
        self.used[rid] = need

    def grow(self, rid: str, tokens: int):
        self.used[rid] = max(self.used.get(rid, 0),
                             self.blocks_for(tokens))

    def release(self, rid: str):
        self.used.pop(rid, None)


class CacheSlots:
    """Fixed decode batch of B slots, each with ``capacity`` positions."""

    def __init__(self, cfg: ModelConfig, max_batch: int, capacity: int,
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.B = max_batch
        self.capacity = capacity
        self.cache = M.make_cache(cfg, max_batch, capacity, dtype)
        self.lengths = jnp.ones((max_batch,), jnp.int32)  # 1 = inert slot
        self.free: List[int] = list(range(max_batch))
        self.slot_owner: Dict[int, str] = {}
        self._axes = M.cache_axes(cfg)
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))

    def _insert_impl(self, cache, prefill_cache, slot):
        """Write a single-sequence prefill cache (1, S, ...) into slot."""
        def one(leaves, ax):
            dst, src = leaves
            bi = ax.index("act_batch")
            src = src.astype(dst.dtype)
            start = [jnp.asarray(0, jnp.int32)] * dst.ndim
            start[bi] = slot
            # pad the seq dim of src up to dst (already <= capacity)
            pads = []
            for i, (ds, ss) in enumerate(zip(dst.shape, src.shape)):
                pads.append((0, (ds - ss) if i != bi else 0))
            src = jnp.pad(src, pads)
            return jax.lax.dynamic_update_slice(dst, src, start)

        return tree_multi(one, [cache, prefill_cache], self._axes)

    def allocate(self, rid: str) -> Optional[int]:
        if not self.free:
            return None
        slot = self.free.pop(0)
        self.slot_owner[slot] = rid
        return slot

    def insert(self, slot: int, prefill_cache, length: int):
        self.cache = self._insert(self.cache, prefill_cache,
                                  jnp.asarray(slot, jnp.int32))
        self.lengths = self.lengths.at[slot].set(length)

    def extract(self, slot: int, start: int, end: int):
        """Copy KV for positions ``[start, end)`` out of ``slot``.

        Returns a pytree shaped like a single-sequence prefill cache
        (``act_batch == 1``, ``act_kvseq == end - start``) — the segment
        format the prefix cache stores.  Only meaningful for caches whose
        leaves all carry an ``act_kvseq`` axis (pure attention)."""
        def one(arr, ax):
            if "act_kvseq" not in ax:
                raise ValueError(
                    "extract() needs position-sliceable cache leaves "
                    f"(axes {ax} has no act_kvseq)")
            idx = [slice(None)] * arr.ndim
            idx[ax.index("act_batch")] = slice(slot, slot + 1)
            idx[ax.index("act_kvseq")] = slice(start, end)
            return arr[tuple(idx)]

        return tree_walk(one, self.cache, self._axes)

    def release(self, slot: int):
        self.slot_owner.pop(slot, None)
        self.lengths = self.lengths.at[slot].set(1)
        self.free.append(slot)

    @property
    def active_slots(self) -> List[int]:
        return sorted(self.slot_owner)
