"""Continuous-batching inference engine (the vLLM analogue, §4.4/§6.5).

One engine = one model replica: a fixed decode batch of ``max_batch``
slots over a dense KV cache, a waiting queue with block-ledger admission,
bucketed prefill (pow2 buckets bound recompilation), and per-request
TTFT/ITL/E2EL metrics.  The gateway (repro.core.gateway) routes requests
across replicas; HA (repro.core.ha) runs replicas active-active.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving.kvcache import BlockLedger, CacheSlots
from repro.serving.metrics import MetricsCollector
from repro.serving.sampling import sample


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    eos_id: int = -1
    request_id: str = ""
    extras: Optional[Dict[str, Any]] = None   # vision_embeds / frames
    # filled by the engine:
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return -(-n // 4096) * 4096


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 capacity: int = 512, block_size: int = 64,
                 clock: Callable[[], float] = time.monotonic,
                 seed: int = 0, name: str = "engine0"):
        self.cfg, self.params = cfg, params
        self.name = name
        self.clock = clock
        self.slots = CacheSlots(cfg, max_batch, capacity)
        self.ledger = BlockLedger(capacity * max_batch, block_size)
        self.capacity = capacity
        self.queue: deque[Request] = deque()
        self.running: Dict[int, Request] = {}
        self.metrics = MetricsCollector()
        self.key = jax.random.PRNGKey(seed)
        self._ids = itertools.count()
        self.healthy = True
        self.steps = 0

        self._prefill = jax.jit(
            lambda p, b: M.prefill(cfg, p, b))
        self._decode = jax.jit(
            lambda p, t, c, l: M.decode_step(cfg, p, t, c, l))

    # ------------------------------------------------------------ API
    def submit(self, req: Request) -> str:
        if not req.request_id:
            req.request_id = f"{self.name}-r{next(self._ids)}"
        self.metrics.arrival(req.request_id, self.clock(), len(req.prompt))
        self.queue.append(req)
        return req.request_id

    @property
    def num_active(self) -> int:
        return len(self.running) + len(self.queue)

    # ------------------------------------------------------------ steps
    def _admit_one(self) -> bool:
        if not self.queue or not self.slots.free:
            return False
        req = self.queue[0]
        need = len(req.prompt) + req.max_new_tokens
        if need > self.capacity:
            req.done = True
            self.queue.popleft()
            self.metrics.finish(req.request_id, self.clock())
            return False
        if not self.ledger.can_admit(req.request_id, need):
            return False
        self.queue.popleft()
        self.ledger.admit(req.request_id, need)
        slot = self.slots.allocate(req.request_id)
        self.metrics.prefill_start(req.request_id, self.clock())

        n = len(req.prompt)
        pad = _bucket(n)
        toks = np.zeros((1, pad), np.int32)
        toks[0, :n] = req.prompt
        n_front = self.cfg.frontend_tokens if self.cfg.frontend == "vision" \
            else 0
        batch = {"tokens": jnp.asarray(toks),
                 "prompt_lengths": jnp.asarray([n + n_front], jnp.int32)}
        if req.extras:
            batch.update({k: jnp.asarray(v) for k, v in req.extras.items()})
        logits, cache, _ = self._prefill(self.params, batch)
        cache = M.pad_cache(self.cfg, cache, self.capacity)
        self.slots.insert(slot, cache, n + n_front)
        self.running[slot] = req

        tok = self._sample(logits, req)
        self._emit(slot, req, int(tok[0]))
        return True

    def _sample(self, logits, req: Request):
        self.key, k = jax.random.split(self.key)
        return sample(logits, k, temperature=req.temperature,
                      top_k=req.top_k, top_p=req.top_p)

    def _emit(self, slot: int, req: Request, token: int):
        req.generated.append(token)
        self.metrics.token(req.request_id, self.clock())
        if (token == req.eos_id
                or len(req.generated) >= req.max_new_tokens):
            req.done = True
            self.metrics.finish(req.request_id, self.clock())
            self.ledger.release(req.request_id)
            self.slots.release(slot)
            self.running.pop(slot, None)

    def _decode_all(self):
        if not self.running:
            return
        B = self.slots.B
        toks = np.zeros((B, 1), np.int32)
        for slot, req in self.running.items():
            toks[slot, 0] = req.generated[-1]
        lengths = self.slots.lengths
        active = np.zeros((B,), bool)
        for slot in self.running:
            active[slot] = True
        lengths = jnp.where(jnp.asarray(active), lengths + 1, lengths)
        logits, new_cache = self._decode(
            self.params, jnp.asarray(toks), self.slots.cache, lengths)
        self.slots.cache = new_cache
        self.slots.lengths = lengths
        # per-slot sampling (batched greedy, per-request params honored)
        for slot, req in list(self.running.items()):
            tok = self._sample(logits[slot:slot + 1], req)
            self._emit(slot, req, int(tok[0]))

    def step(self):
        """One scheduler tick: admit (prefill) if possible, else decode."""
        if not self._admit_one():
            self._decode_all()
        self.steps += 1

    def run_until_idle(self, max_steps: int = 100_000):
        while self.num_active and max_steps:
            self.step()
            max_steps -= 1
        return self.metrics.summary()
