"""Continuous-batching inference engine (the vLLM analogue, §4.4/§6.5).

One engine = one model replica: a fixed decode batch of ``max_batch``
slots, a waiting queue, bucketed prefill (pow2 buckets bound
recompilation), and per-request TTFT/ITL/E2EL metrics.  KV storage is
*paged* by default on architectures with position-sliceable caches
(GQA/MLA): a shared block pool + per-slot block tables
(:class:`~repro.serving.kvcache.PagedCacheSlots`), with copy-free prefix
sharing and preemption instead of over-commit.  SSM/hybrid,
encoder-decoder, and vision-prefixed models fall back to the dense
per-slot layout with block-ledger admission.  Decode and sampling are
fused in one jitted step (per-slot temperature/top-k/top-p vectors), so
a micro-step costs one device round-trip for the whole batch — and with
speculative decoding enabled (``speculative="ngram"|"draft"``) that one
round-trip emits up to ``spec_k + 1`` tokens per sequence via a
multi-token verify launch with in-jit accept/reject.

Scheduling policy — admission, chunked prefill, automatic radix-tree
prefix reuse, preemption — lives in
:class:`repro.serving.scheduler.ChunkedPrefillScheduler` (design notes in
serving/README.md).  The gateway (repro.core.gateway) routes requests
across replicas with prefix affinity; HA (repro.core.ha) runs replicas
active-active.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.finetune.quantize import dequantize_tree
from repro.models import model as M
from repro.parallel import sharding
from repro.serving.adapters import AdapterPool, supports_multi_lora
from repro.serving.faults import EngineFailure, EngineTimeout
from repro.serving.kvcache import (BlockLedger, CacheSlots, PagedCacheSlots,
                                   constrain_cache)
from repro.serving.metrics import MetricsCollector, TracingMetricsCollector
from repro.serving.sampling import (sample, sample_batched,
                                    spec_accept_batched)
from repro.serving.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.serving.speculative import make_drafter


def _is_quantized_params(tree) -> bool:
    """True when ``tree`` is a ``finetune.quantize.quantize_tree``
    artifact: its leaves are ``{"q", "scale"}`` / ``{"raw"}`` dicts
    (the same leaf test ``dequantize_tree`` keys on)."""
    found = False

    def chk(x):
        nonlocal found
        if isinstance(x, dict) and ("raw" in x or "q" in x):
            found = True
            return True
        return False

    jax.tree.leaves(tree, is_leaf=chk)
    return found


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    eos_id: int = -1
    request_id: str = ""
    namespace: str = ""      # prefix-cache isolation domain (tenant/project)
    adapter: str = ""        # LoRA adapter name ("" = base model)
    extras: Optional[Dict[str, Any]] = None   # vision_embeds / frames
    # filled by the engine:
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # generated tokens already folded into the prompt by preemption —
    # repeated preemption must fold only the tokens emitted since
    n_folded: int = 0


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 capacity: int = 512, block_size: int = 64,
                 clock: Callable[[], float] = time.monotonic,
                 seed: int = 0, name: str = "engine0",
                 sched: Optional[SchedulerConfig] = None,
                 paged: Optional[bool] = None,
                 pool_tokens: Optional[int] = None,
                 adapter_slots: int = 0,
                 adapter_rank_bucket: int = 8,
                 speculative: Optional[str] = None,
                 spec_k: int = 4,
                 draft_cfg=None, draft_params=None,
                 obs=None, faults=None,
                 mesh=None, rules=None,
                 role: str = "unified",
                 kv_dtype: str = "bf16"):
        """``paged=None`` auto-selects the paged KV path when the
        architecture supports it.  ``pool_tokens`` sizes the shared block
        pool (default ``max_batch * capacity`` — the dense footprint);
        because paged blocks are allocated on demand, a pool smaller than
        ``max_batch * capacity`` still serves ``max_batch`` concurrent
        sequences whenever their live lengths fit.  The paged pool's
        token-block size is the scheduler's ``prefix_block`` so radix
        nodes map 1:1 onto physical blocks (copy-free sharing).

        ``adapter_slots > 0`` enables multi-tenant LoRA serving: an
        :class:`~repro.serving.adapters.AdapterPool` with that many
        device-resident adapter slots (ranks padded to
        ``adapter_rank_bucket``).  Requests name an adapter via
        ``Request.adapter``; base and adapter'd requests share every
        fused decode step.

        ``speculative`` turns on speculative decoding: ``"ngram"``
        (prompt-lookup, model-free) or ``"draft"`` (a small compatible
        model — pass ``draft_cfg``/``draft_params``).  Each decode
        micro-step then drafts up to ``spec_k`` tokens per running
        sequence and scores them in ONE multi-token verify launch;
        accepted tokens are emitted in a burst, rejected ones rolled
        back.  Greedy outputs are token-identical to the
        non-speculative engine; sampled outputs follow the same
        distribution.  Requires position-sliceable KV
        (``M.supports_speculative`` — uniform GQA/MLA stacks, either KV
        layout).

        ``faults`` (a :class:`~repro.serving.faults.FaultInjector`,
        default off) arms deterministic fault injection: the engine
        checks it at admission, at every decode micro-step, and at
        every token emission, realising crash / hang / reject faults
        (see faults.py and docs/robustness.md).

        ``obs`` (an :class:`repro.obs.Observability`, default off)
        turns on lifecycle observability: per-request trace spans and
        push-style latency histograms stream through a
        :class:`TracingMetricsCollector`, the scheduler emits per-tick
        spans and queue/occupancy gauges, and
        :meth:`collect_metrics` pulls KV-pool / prefix-cache /
        adapter-pool state into ``obs.registry`` on demand.  All
        instrumentation is host-side Python — nothing crosses the jit
        boundary or syncs the device.

        ``mesh`` (a ``jax.sharding.Mesh`` with a ``"model"`` axis, default
        None) makes the replica *tensor-parallel*: parameters are loaded
        as NamedShardings under ``rules`` (default
        ``make_rules("serving_tp")`` — head-sharded attention, row/col
        MLPs, replicated embeddings), the KV pool/cache shards on its
        head axis (MLA's latent stays replicated), and every fused jit
        traces under those rules so prefill, paged decode, multi-LoRA,
        and speculative verify all run SPMD without host round-trips.
        Block tables, lengths, and the whole scheduler stay host-side
        and layout-invariant.  ``mesh=None`` leaves the single-device
        code path bit-for-bit untouched.

        ``role`` selects the engine's place in a *disaggregated*
        serving pair (serving/README.md "Disaggregated serving"):
        ``"unified"`` (default — prefill and decode on one engine,
        byte-identical to the pre-role behaviour), ``"prefill"``
        (accepts raw prompts, runs prefill only, and emits a
        :class:`~repro.serving.kvcache.KVHandoff` into :attr:`outbox`
        instead of streaming tokens), or ``"decode"`` (rejects raw
        prompts; admits requests from :meth:`submit_handoff`, importing
        the migrated KV with zero re-prefill).  Both non-unified roles
        need the paged KV layout — the handoff is a block-table
        export/import.

        ``kv_dtype`` selects the paged pool's storage precision:
        ``"bf16"`` (default — byte-identical to the pre-option engine)
        or ``"int8"`` (symmetric per-block quantized KV with f32
        scales; the same ``pool_tokens`` budget buys ~2x the physical
        blocks, at a small accuracy-guarded decode error — see
        serving/README.md "Quantized serving").  Requires the paged
        layout.

        ``params`` may also be a release artifact from
        ``finetune.quantize.quantize_tree`` — the engine detects the
        quantized leaf layout and dequantizes at load, closing the
        lifecycle's quantize -> publish -> deploy loop."""
        if _is_quantized_params(params):
            # a published int8 weight artifact: restore serving dtypes
            # before sharding/jit so every downstream jaxpr sees plain
            # tensors (f32 matches the lifecycle release path)
            params = dequantize_tree(params, jnp.float32)
        self.cfg, self.params = cfg, params
        self.name = name
        self.clock = clock
        self.obs = obs
        self.mesh = mesh
        self.rules = None
        if mesh is not None:
            if "model" not in mesh.axis_names:
                raise ValueError(
                    f"serving mesh needs a 'model' axis, got "
                    f"{mesh.axis_names}")
            self.rules = rules or sharding.make_rules("serving_tp")
            self.params = jax.device_put(
                params, sharding.tree_shardings(
                    M.model_param_axes(cfg), mesh, self.rules))
        self.tp = 1 if mesh is None else int(mesh.devices.size)
        self.paged = M.supports_paged_cache(cfg) if paged is None else paged
        if role not in ("unified", "prefill", "decode"):
            raise ValueError(f"unknown engine role {role!r}")
        if role != "unified" and not self.paged:
            raise ValueError(
                f"role={role!r} needs the paged KV layout (handoffs are "
                f"block exports); {cfg.name} resolved to dense")
        self.role = role
        # prefill role: completed (req, KVHandoff) pairs for the router
        self.outbox: deque = deque()
        # decode role: (req, KVHandoff) pairs waiting for admission
        self.handoffs: deque = deque()
        self.adapters: Optional[AdapterPool] = None
        if adapter_slots > 0:
            self.adapters = AdapterPool(cfg, params, slots=adapter_slots,
                                        rank_bucket=adapter_rank_bucket)
        if kv_dtype not in ("bf16", "int8"):
            raise ValueError(f"kv_dtype must be 'bf16' or 'int8', "
                             f"got {kv_dtype!r}")
        if kv_dtype == "int8" and not self.paged:
            raise ValueError(
                "kv_dtype='int8' needs the paged KV layout (per-block "
                f"scales live in the block pool); {cfg.name} resolved "
                "to dense")
        self.kv_dtype = kv_dtype
        sched = sched or SchedulerConfig()
        if self.paged:
            self.slots = PagedCacheSlots(
                cfg, max_batch, capacity, block_size=sched.prefix_block,
                pool_tokens=pool_tokens, mesh=mesh, rules=self.rules,
                kv_dtype=kv_dtype)
        else:
            self.slots = CacheSlots(cfg, max_batch, capacity,
                                    mesh=mesh, rules=self.rules)
        self.ledger = BlockLedger(capacity * max_batch, block_size)
        self.capacity = capacity
        self.queue: deque[Request] = deque()
        self.running: Dict[int, Request] = {}
        self.metrics = (TracingMetricsCollector(obs) if obs is not None
                        else MetricsCollector())
        self.key = jax.random.PRNGKey(seed)
        self._ids = itertools.count()
        self.healthy = True
        self.draining = False
        self.faults = faults
        self.steps = 0

        # every fused step traces under the engine's (mesh, rules) via
        # sharded_jit — with mesh=None that is plain jax.jit and the
        # constrain/constrain_cache calls are no-ops, so the
        # single-device jaxprs are byte-identical to the unsharded
        # engine's.  Cache/pool outputs are re-constrained before
        # returning so the donated buffers keep a stable NamedSharding
        # across micro-steps (no per-step resharding, no recompiles).
        # two axes trees: the *dense* cache axes for prefill and the
        # dense decode/verify steps (their cache trees never carry scale
        # leaves), and the slots' axes for the paged steps (identical to
        # the dense tree for bf16 pools; int8 pools add ``*_scale``
        # leaves)
        dense_axes = M.cache_axes(cfg)
        cache_axes = self.slots._axes
        mk_jit = lambda f, **kw: sharding.sharded_jit(  # noqa: E731
            f, mesh, self.rules, **kw)

        def _prefill_fn(p, b, lo, ai):
            logits, cache, aux = M.prefill(cfg, p, b, lora=lo,
                                           adapter_ids=ai)
            return logits, constrain_cache(cache, dense_axes), aux

        self._prefill = mk_jit(_prefill_fn)

        # decode + batched sampling fused in one jitted step: per-slot
        # temperature/top-k/top-p vectors in, sampled tokens out — the
        # scheduler does a single coalesced device_get per micro-step.
        # ``greedy`` is static: the all-greedy batch (the common case)
        # skips the two full-vocab sorts of the filtered sampler.
        # ``lo``/``ai`` are the stacked adapter tree + per-slot adapter
        # ids (both None on engines without an adapter pool) — multi-LoRA
        # rides the same micro-step, no extra launches.
        def _fused(p, t, c, l, key, temps, tks, tps, lo, ai, greedy):
            logits, nc = M.decode_step(cfg, p, t, c, l, lora=lo,
                                       adapter_ids=ai)
            nc = constrain_cache(nc, dense_axes)
            if greedy:
                return jnp.argmax(logits, -1).astype(jnp.int32), nc
            return sample_batched(logits, key, temps, tks, tps), nc

        def _fused_paged(p, t, pool, bt, l, key, temps, tks, tps, lo, ai,
                         greedy):
            logits, np_ = M.decode_step_paged(cfg, p, t, pool, bt, l,
                                              lora=lo, adapter_ids=ai)
            np_ = constrain_cache(np_, cache_axes)
            if greedy:
                return jnp.argmax(logits, -1).astype(jnp.int32), np_
            return sample_batched(logits, key, temps, tks, tps), np_

        self._decode_sample = mk_jit(_fused, static_argnums=(10,))
        self._decode_sample_paged = mk_jit(_fused_paged,
                                           donate_argnums=(2,),
                                           static_argnums=(11,))

        # speculative decoding: draft up to spec_k tokens per sequence,
        # score them in ONE multi-token verify launch, accept/reject
        # inside the jit (the whole batch still costs one device_get).
        self.spec_k = spec_k
        self.drafter = None
        if speculative:
            if not M.supports_speculative(cfg):
                raise ValueError(
                    "speculative decoding needs position-sliceable KV "
                    "(uniform GQA/MLA stacks) — rejected tokens cannot "
                    f"be rolled back on {cfg.name}")
            self.drafter = make_drafter(speculative, cfg, spec_k=spec_k,
                                        capacity=capacity,
                                        draft_cfg=draft_cfg,
                                        draft_params=draft_params)

        def _verify_fused(p, t, c, l, key, temps, tks, tps, dprobs, nd,
                          lo, ai, greedy):
            logits, nc = M.verify_step(cfg, p, t, c, l, lora=lo,
                                       adapter_ids=ai)
            nc = constrain_cache(nc, dense_axes)
            out, nem = spec_accept_batched(logits, t, dprobs, nd, key,
                                           temps, tks, tps, greedy)
            return out, nem, nc

        def _verify_fused_paged(p, t, pool, bt, l, key, temps, tks, tps,
                                dprobs, nd, lo, ai, greedy):
            logits, np_ = M.verify_step_paged(cfg, p, t, pool, bt, l,
                                              lora=lo, adapter_ids=ai)
            np_ = constrain_cache(np_, cache_axes)
            out, nem = spec_accept_batched(logits, t, dprobs, nd, key,
                                           temps, tks, tps, greedy)
            return out, nem, np_

        self._verify = mk_jit(_verify_fused, static_argnums=(12,))
        self._verify_paged = mk_jit(_verify_fused_paged,
                                    donate_argnums=(2,),
                                    static_argnums=(13,))
        self.scheduler = ChunkedPrefillScheduler(self, sched)

    # ------------------------------------------------------------ API
    def register_adapter(self, name: str, adapters, lcfg) -> None:
        """Publish a trained LoRA adapter to this engine's pool so
        requests can name it via ``Request.adapter`` (or the gateway's
        ``model@adapter``)."""
        if self.adapters is None:
            raise RuntimeError(
                "engine has no adapter pool (construct with "
                "adapter_slots > 0)")
        self.adapters.register(name, adapters, lcfg)

    def adapter_stats(self) -> Dict[str, int]:
        """Adapter-pool counters (zeros when multi-LoRA is disabled)."""
        if self.adapters is None:
            return {"registered": 0, "resident": 0, "pinned": 0,
                    "slots": 0, "loads": 0, "evictions": 0,
                    "acquire_waits": 0}
        return self.adapters.stats()

    def submit(self, req: Request) -> str:
        st = self.health()
        if st != "ok":
            raise EngineFailure(f"{self.name} is {st}", point="submit",
                                kind=st)
        if self.role == "decode":
            # decode-only admission: raw prompts have no KV to import —
            # route them through a prefill engine (or a unified one)
            raise EngineFailure(
                f"{self.name} is decode-role: submit_handoff() a "
                f"prefilled request, not a raw prompt", point="submit",
                kind="role")
        self._fault("admission")
        if not req.request_id:
            req.request_id = f"{self.name}-r{next(self._ids)}"
        self.metrics.arrival(req.request_id, self.clock(), len(req.prompt))
        self.queue.append(req)
        return req.request_id

    def submit_handoff(self, req: Request, handoff) -> str:
        """Submit a prefilled request plus its exported KV to a
        decode-role engine.  The request resumes with zero re-prefill:
        admission imports the handoff's blocks (adopting any prefix the
        local radix tree already holds) and streams only tokens past
        the handoff's coverage (none, unless a preemption fold grew the
        prompt)."""
        if self.role == "prefill":
            raise EngineFailure(
                f"{self.name} is prefill-role: it exports handoffs, it "
                f"does not import them", point="submit", kind="role")
        st = self.health()
        if st != "ok":
            raise EngineFailure(f"{self.name} is {st}", point="submit",
                                kind=st)
        self._fault("admission")
        if not req.request_id:
            req.request_id = handoff.request_id or \
                f"{self.name}-r{next(self._ids)}"
        self.metrics.arrival(req.request_id, self.clock(), len(req.prompt))
        self.handoffs.append((req, handoff))
        return req.request_id

    # -------------------------------------------------------- lifecycle
    def health(self) -> str:
        """``"ok"`` / ``"draining"`` (finishing in-flight work, not
        accepting new) / ``"down"`` (crashed; needs :meth:`recover`)."""
        if not self.healthy:
            return "down"
        if self.draining:
            return "draining"
        return "ok"

    def crash(self, reason: str = "") -> List[Request]:
        """Simulate the replica process dying: mark the engine down,
        evacuate every in-flight request (committed tokens folded into
        the prompt via the scheduler's preemption path — resubmission
        elsewhere is token-exact at temperature 0), and drop the prefix
        cache (its KV died with the process).  Returns the evacuated
        requests, oldest first, for the caller to reroute."""
        self.healthy = False
        reqs = self.scheduler.evacuate()
        self.scheduler.reset_cache()
        return reqs

    def recover(self) -> None:
        """Bring a crashed (or draining) engine back into rotation.
        State was already cleaned by :meth:`crash`, so recovery is just
        re-admitting traffic — the serving analogue of the trainer's
        restore-and-retry."""
        self.healthy = True
        self.draining = False

    def drain(self, max_steps: int = 100_000):
        """Stop accepting new requests but finish the in-flight ones —
        the graceful half of node reclamation.  Returns the metrics
        summary; call :meth:`recover` to re-enter rotation."""
        self.draining = True
        return self.run_until_idle(max_steps)

    def _fault(self, point: str) -> None:
        """Consult the bound injector at a fault point and realise
        whatever it schedules (crash / hang / reject).  No injector, or
        nothing scheduled: free."""
        inj = self.faults
        if inj is None:
            return
        spec = inj.check(point)
        if spec is None:
            return
        if spec.kind == "hang":
            if inj.clock_advance is not None:
                inj.clock_advance(spec.hang_s)
            return
        if spec.kind == "reject":
            raise EngineFailure(
                f"{self.name}: injected reject at {point}",
                point=point, kind="reject")
        self.crash(reason=f"injected crash at {point}")
        raise EngineFailure(
            f"{self.name}: injected crash at {point}",
            point=point, kind="crash")

    @property
    def num_active(self) -> int:
        # outbox is excluded: an exported handoff is the *router's* work
        # now, and counting it would wedge run_until_idle
        return len(self.running) + len(self.queue) + len(self.handoffs)

    @property
    def prefix_cache(self):
        return self.scheduler.prefix_cache

    def prefix_match_len(self, namespace: str, tokens) -> int:
        """Longest cached prefix for this prompt (0 when caching is off or
        the architecture is unsupported) — used for affinity routing."""
        return self.scheduler.match_len(namespace, tokens)

    def kv_stats(self) -> Dict[str, int]:
        """KV-memory accounting in blocks: live + peak usage, and total.
        Paged engines report real pool blocks (shared prefix blocks count
        once) plus per-device byte figures (on a TP mesh a GQA pool
        block is split across devices on its head axis, so per-device
        peak KV shrinks ~1/tp); dense engines report ledger
        reservations."""
        if self.paged:
            bp = self.slots.bp
            # bytes one device holds for the whole pool: shard size for
            # TP-sharded leaves, full size for replicated ones (MLA)
            dev_pool = sum(
                leaf.addressable_shards[0].data.nbytes
                for leaf in jax.tree.leaves(self.slots.pool))
            per_block = dev_pool // bp.num_blocks
            return {"kv_blocks_used": bp.num_used,
                    "kv_blocks_peak": bp.peak_used,
                    "kv_blocks_total": bp.num_blocks - 1,
                    "kv_block_size": self.slots.block_size,
                    "kv_tp_degree": self.tp,
                    "kv_block_bytes_per_device": per_block,
                    "kv_peak_bytes_per_device": per_block * bp.peak_used}
        return {"kv_blocks_used": self.ledger.total_blocks
                - self.ledger.free_blocks,
                "kv_blocks_peak": self.ledger.peak_blocks,
                "kv_blocks_total": self.ledger.total_blocks,
                "kv_block_size": self.ledger.block_size,
                "kv_tp_degree": self.tp}

    def collect_metrics(self, registry=None):
        """Pull every serving subsystem's state into a metrics registry
        (default: ``obs.registry``): scheduler queue/batch gauges, KV
        pool occupancy, prefix-cache hit/miss/evict, adapter-pool
        residency, and the request/speculative aggregates.  Returns the
        registry — call right before snapshotting/exporting."""
        reg = registry
        if reg is None:
            if self.obs is None:
                raise ValueError("engine has no obs handle; pass a "
                                 "registry explicitly")
            reg = self.obs.registry
        reg.gauge("repro_sched_queue_depth_requests",
                  "requests waiting for admission").set(len(self.queue))
        reg.gauge("repro_sched_running_requests",
                  "requests holding a decode slot").set(
            len(self.running))
        reg.gauge("repro_sched_batch_capacity_slots",
                  "decode slots in the fixed batch").set(self.slots.B)
        if self.paged:
            self.slots.bp.collect_metrics(
                reg, block_size=self.slots.block_size)
        else:
            self.ledger.collect_metrics(reg)
        if self.prefix_cache is not None:
            self.prefix_cache.collect_metrics(reg)
        if self.adapters is not None:
            self.adapters.collect_metrics(reg)
        self.metrics.collect(reg)
        return reg

    # ------------------------------------------------------------ steps
    def _sample(self, logits, req: Request):
        self.key, k = jax.random.split(self.key)
        return sample(logits, k, temperature=req.temperature,
                      top_k=req.top_k, top_p=req.top_p)

    def step(self):
        """One scheduler tick: admit up to N requests, then decode (and
        stream pending prefill chunks).  Decode runs every tick, so a
        deep queue can no longer starve running requests."""
        self.scheduler.tick()
        self.steps += 1

    def run_until_idle(self, max_steps: int = 100_000,
                       deadline: Optional[float] = None):
        """Drive the engine until no request is active.  ``deadline``
        (absolute, on the engine's clock) bounds the wall budget: when
        it passes with work still in flight, the remaining requests are
        evacuated (committed tokens folded, so they resume token-exact
        elsewhere) and :class:`EngineTimeout` carries them out."""
        while self.num_active and max_steps:
            if deadline is not None and self.clock() >= deadline:
                reqs = self.scheduler.evacuate()
                raise EngineTimeout(
                    f"{self.name}: deadline exceeded with "
                    f"{len(reqs)} request(s) in flight", requests=reqs)
            self.step()
            max_steps -= 1
        return self.metrics.summary()
