"""Continuous-batching inference engine (the vLLM analogue, §4.4/§6.5).

One engine = one model replica: a fixed decode batch of ``max_batch``
slots over a dense KV cache, a waiting queue with block-ledger admission,
bucketed prefill (pow2 buckets bound recompilation), and per-request
TTFT/ITL/E2EL metrics.  Scheduling policy — admission, chunked prefill,
and automatic radix-tree prefix reuse — lives in
:class:`repro.serving.scheduler.ChunkedPrefillScheduler` (design notes in
serving/README.md).  The gateway (repro.core.gateway) routes requests
across replicas with prefix affinity; HA (repro.core.ha) runs replicas
active-active.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving.kvcache import BlockLedger, CacheSlots
from repro.serving.metrics import MetricsCollector
from repro.serving.sampling import sample
from repro.serving.scheduler import ChunkedPrefillScheduler, SchedulerConfig


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    eos_id: int = -1
    request_id: str = ""
    namespace: str = ""      # prefix-cache isolation domain (tenant/project)
    extras: Optional[Dict[str, Any]] = None   # vision_embeds / frames
    # filled by the engine:
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 capacity: int = 512, block_size: int = 64,
                 clock: Callable[[], float] = time.monotonic,
                 seed: int = 0, name: str = "engine0",
                 sched: Optional[SchedulerConfig] = None):
        self.cfg, self.params = cfg, params
        self.name = name
        self.clock = clock
        self.slots = CacheSlots(cfg, max_batch, capacity)
        self.ledger = BlockLedger(capacity * max_batch, block_size)
        self.capacity = capacity
        self.queue: deque[Request] = deque()
        self.running: Dict[int, Request] = {}
        self.metrics = MetricsCollector()
        self.key = jax.random.PRNGKey(seed)
        self._ids = itertools.count()
        self.healthy = True
        self.steps = 0

        self._prefill = jax.jit(
            lambda p, b: M.prefill(cfg, p, b))
        self._decode = jax.jit(
            lambda p, t, c, l: M.decode_step(cfg, p, t, c, l))
        self.scheduler = ChunkedPrefillScheduler(self, sched)

    # ------------------------------------------------------------ API
    def submit(self, req: Request) -> str:
        if not req.request_id:
            req.request_id = f"{self.name}-r{next(self._ids)}"
        self.metrics.arrival(req.request_id, self.clock(), len(req.prompt))
        self.queue.append(req)
        return req.request_id

    @property
    def num_active(self) -> int:
        return len(self.running) + len(self.queue)

    @property
    def prefix_cache(self):
        return self.scheduler.prefix_cache

    def prefix_match_len(self, namespace: str, tokens) -> int:
        """Longest cached prefix for this prompt (0 when caching is off or
        the architecture is unsupported) — used for affinity routing."""
        return self.scheduler.match_len(namespace, tokens)

    # ------------------------------------------------------------ steps
    def _sample(self, logits, req: Request):
        self.key, k = jax.random.split(self.key)
        return sample(logits, k, temperature=req.temperature,
                      top_k=req.top_k, top_p=req.top_p)

    def step(self):
        """One scheduler tick: admit up to N requests, then decode (and
        stream pending prefill chunks).  Decode runs every tick, so a
        deep queue can no longer starve running requests."""
        self.scheduler.tick()
        self.steps += 1

    def run_until_idle(self, max_steps: int = 100_000):
        while self.num_active and max_steps:
            self.step()
            max_steps -= 1
        return self.metrics.summary()
