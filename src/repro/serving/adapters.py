"""Multi-tenant LoRA adapter pool for the serving engine (S-LoRA/Punica
style, the missing link between ``finetune/`` and ``serving/``).

The paper's platform serves *one* base model to many tenants, each with
their own fine-tuned adapter, on one GPU pool.  ``lora_merge`` forfeits
that: it bakes a single tenant's adapter into the weights, so every
tenant needs a full model replica.  The :class:`AdapterPool` instead
keeps the base weights shared and holds up to ``slots`` adapters
*stacked* on device:

- Per target weight (``wq``/``wk``/``wv``/``wo``, MLA's ``wuq``/
  ``wuk``/``wuv``) the pool owns one pair of stacked tensors
  ``A: (L, K, d_in, r_bucket)`` / ``B: (L, K, r_bucket, d_out)`` (layer
  axis matching the model's ``lax.scan`` stacks; ``K = slots + 1``).
- **Index 0 is the base model**: an all-zero adapter, so a decode batch
  mixing base-model rows with several tenants' adapter rows runs in ONE
  fused step — each row gathers its own A/B pair by index
  (``models.attention.lora_shift``), no weight merging, no per-tenant
  batch splitting.
- **Rank bucketing**: every adapter's rank is zero-padded to
  ``rank_bucket`` so all adapters share one gatherable stack and the
  decode step compiles once.  The ``alpha/rank`` scale is folded into B
  at registration, so the apply path is scale-free.
- **Ref-counting + LRU**: ``acquire`` pins an adapter while any request
  using it is in flight; eviction (to make room for a newly acquired
  adapter) only ever picks an *unpinned* resident, least-recently-used
  first.  Evicted adapters keep their host-side copy and reload on the
  next ``acquire`` — registration is not residency.

Gating mirrors the paged-KV path: uniform GQA/MLA attention stacks
(``supports_multi_lora``).
"""
from __future__ import annotations

import re
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro._compat import tree_flatten_with_path
from repro.configs.base import ModelConfig
from repro.finetune.lora import DEFAULT_TARGETS, LoraConfig, lora_unflatten

_KEY_RE = re.compile(r"\[(?:'([^']+)'|(\d+))\]")


def supports_multi_lora(cfg: ModelConfig) -> bool:
    """True iff batched multi-LoRA decode is available: uniform GQA/MLA
    attention stacks (same shape of gating as ``supports_paged_cache``;
    SSM/hybrid mixers, encoder-decoder, and vision-prefixed models keep
    the merge-and-deploy path)."""
    from repro.models.model import stack_plan
    if getattr(cfg, "is_encoder_decoder", False):
        return False
    if getattr(cfg, "frontend", "text") == "vision":
        return False
    plan = stack_plan(cfg)
    return plan["kind"] == "uniform" and plan["mixer"] in ("gqa", "mla")


def adapter_namespace(namespace: str, adapter: str) -> str:
    """Prefix-cache namespace for a request: KV produced under an adapter
    is only valid for that adapter, so adapter'd requests get their own
    radix tree ('<tenant>//lora:<adapter>') and can never exchange cached
    KV with the base model or another adapter."""
    return f"{namespace}//lora:{adapter}" if adapter else namespace


def _parse_keystr(ks: str) -> Tuple:
    """``"['stack']['mixer']['wq']"`` -> ``("stack", "mixer", "wq")``
    (int for sequence indices) — inverts ``jax.tree_util.keystr``."""
    out: List[Any] = []
    for name, idx in _KEY_RE.findall(ks):
        out.append(name if name else int(idx))
    return tuple(out)


def _path_tuple(path) -> Tuple:
    out: List[Any] = []
    for e in path:
        if hasattr(e, "key"):
            out.append(e.key)
        elif hasattr(e, "idx"):
            out.append(e.idx)
        else:  # GetAttrKey etc.
            out.append(str(e))
    return tuple(out)


class AdapterPoolFull(RuntimeError):
    pass


class AdapterPool:
    """Device-resident stack of LoRA adapters over one base model.

    ``slots`` adapters can be resident at once (plus the implicit base at
    index 0); any number can be *registered* (host copies).  ``targets``
    defaults to the attention projections of ``finetune.lora``.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 rank_bucket: int = 8, dtype=jnp.float32,
                 targets: Sequence[str] = DEFAULT_TARGETS):
        if not supports_multi_lora(cfg):
            raise ValueError(
                f"{cfg.name}: multi-LoRA serving needs a uniform GQA/MLA "
                "attention stack (merge-and-deploy still works)")
        if slots < 1:
            raise ValueError("pool needs at least one adapter slot")
        self.cfg = cfg
        self.slots = slots
        self.rank_bucket = rank_bucket
        self.dtype = dtype
        self.targets_allowed = tuple(targets)
        # target map: path tuple -> dict(kaxis, a_shape, b_shape) where
        # shapes are the *padded* per-adapter shapes (no K axis)
        self._targets: Dict[Tuple, Dict[str, Any]] = {}
        for path, leaf in tree_flatten_with_path(params)[0]:
            pt = _path_tuple(path)
            if pt[-1] not in self.targets_allowed or leaf.ndim < 2:
                continue
            if leaf.ndim > 3:
                continue  # e.g. stacked MoE experts — not a LoRA target
            *batch, din, dout = leaf.shape
            ka = len(batch)  # 1 under a scanned stack, 0 for "first"
            self._targets[pt] = {
                "kaxis": ka,
                "a_shape": tuple(batch) + (din, rank_bucket),
                "b_shape": tuple(batch) + (rank_bucket, dout),
            }
        if not self._targets:
            raise ValueError("no LoRA-targetable params found")
        K = slots + 1
        self._lora = self._build_tree(
            lambda m: {"a": jnp.zeros(self._with_k(m["a_shape"],
                                                   m["kaxis"], K), dtype),
                       "b": jnp.zeros(self._with_k(m["b_shape"],
                                                   m["kaxis"], K), dtype)})
        self._kaxes = self._build_tree(lambda m: m["kaxis"])
        self._write = jax.jit(self._write_impl, donate_argnums=(0,))
        # host registry + residency bookkeeping
        self._host: Dict[str, Dict[Tuple, Dict[str, np.ndarray]]] = {}
        self._resident: "OrderedDict[str, int]" = OrderedDict()
        self._refs: Dict[str, int] = {}
        self._free: List[int] = list(range(1, K))
        self.loads = 0
        self.evictions = 0
        self.acquire_waits = 0

    # ------------------------------------------------------------ tree
    @staticmethod
    def _with_k(shape: Tuple[int, ...], kaxis: int, K: int):
        return shape[:kaxis] + (K,) + shape[kaxis:]

    def _build_tree(self, fn):
        """Materialize ``fn(meta)`` at every target path, nested like the
        params tree (dicts for names, lists for layer indices)."""
        root: Dict[str, Any] = {}
        for pt, meta in self._targets.items():
            node = root
            for i, k in enumerate(pt[:-1]):
                nxt = pt[i + 1]
                if isinstance(k, int):
                    while len(node) <= k:
                        node.append({} if not isinstance(nxt, int) else [])
                    node = node[k]
                else:
                    if k not in node:
                        node[k] = [] if isinstance(nxt, int) else {}
                    node = node[k]
            node[pt[-1]] = fn(meta)
        return root

    def _write_impl(self, tree, upd, idx):
        """Set adapter ``idx``'s A/B pair at every target (jitted, pool
        donated — an in-place load, not a copy of the whole stack)."""
        def walk(t, u, ka):
            if isinstance(t, dict) and set(t) == {"a", "b"} \
                    and not isinstance(ka, dict):
                out = {}
                for key in ("a", "b"):
                    arr = jnp.moveaxis(t[key], ka, 0)
                    arr = arr.at[idx].set(u[key].astype(arr.dtype))
                    out[key] = jnp.moveaxis(arr, 0, ka)
                return out
            if isinstance(t, dict):
                return {k: walk(t[k], u[k], ka[k]) for k in t}
            return [walk(x, y, z) for x, y, z in zip(t, u, ka)]

        return walk(tree, upd, self._kaxes)

    def lora_tree(self):
        """Current device adapter stacks — pass to
        ``model.decode_step(..., lora=...)`` with per-row adapter ids."""
        return self._lora

    # ------------------------------------------------------------ admin
    def register(self, name: str, adapters: Dict, lcfg: LoraConfig):
        """Upload a trained adapter under ``name`` (host copy; it becomes
        device-resident on first :meth:`acquire`).

        ``adapters`` is either the nested dict from ``lora_init``/SFT
        ({keystr: {"a", "b"}}) or the flat ``lora_export`` form
        ({"<keystr>.a": arr}).  Ranks are padded to ``rank_bucket``; the
        ``alpha/rank`` scale is folded into B.  Unsupported targets (e.g.
        MLP ``gate``/``up``/``down``) raise — silently dropping them
        would serve a *different* model than the tenant trained.
        """
        if not name:
            raise ValueError("adapter name must be non-empty")
        if self._refs.get(name, 0) > 0:
            raise ValueError(f"adapter {name!r} is pinned by in-flight "
                             "requests; cannot re-register")
        if any(k.endswith(".a") or k.endswith(".b") for k in adapters):
            adapters = lora_unflatten(adapters)   # stored-artifact form
        nested = {k: dict(v) for k, v in adapters.items()}
        host: Dict[Tuple, Dict[str, np.ndarray]] = {}
        for ks, ab in nested.items():
            pt = _parse_keystr(ks)
            meta = self._targets.get(pt)
            if meta is None:
                raise ValueError(
                    f"adapter {name!r} targets {ks} which this pool does "
                    f"not serve (targets: {sorted(self.targets_allowed)})")
            a = np.asarray(ab["a"], np.float32)
            b = np.asarray(ab["b"], np.float32)
            r = a.shape[-1]
            if r > self.rank_bucket:
                raise ValueError(
                    f"adapter {name!r} rank {r} exceeds the pool's rank "
                    f"bucket {self.rank_bucket}")
            want_a = meta["a_shape"][:-1] + (r,)
            want_b = meta["b_shape"][:-2] + (r,) + meta["b_shape"][-1:]
            if a.shape != want_a or b.shape != want_b:
                raise ValueError(
                    f"adapter {name!r} shape mismatch at {ks}: "
                    f"A{a.shape}/B{b.shape} vs A{want_a}/B{want_b}")
            pad_a = [(0, 0)] * a.ndim
            pad_a[-1] = (0, self.rank_bucket - r)
            pad_b = [(0, 0)] * b.ndim
            pad_b[-2] = (0, self.rank_bucket - r)
            host[pt] = {"a": np.pad(a, pad_a),
                        "b": np.pad(b, pad_b) * lcfg.scale}
        if not host:
            raise ValueError(f"adapter {name!r} is empty")
        self._host[name] = host
        if name in self._resident:     # hot re-register (e.g. retrain)
            self._load(name, self._resident[name])

    def deregister(self, name: str):
        """Forget ``name`` entirely (host copy and residency)."""
        if self._refs.get(name, 0) > 0:
            raise ValueError(f"adapter {name!r} is pinned; cannot "
                             "deregister")
        self._host.pop(name, None)
        idx = self._resident.pop(name, None)
        self._refs.pop(name, None)
        if idx is not None:
            self._free.append(idx)

    def has(self, name: str) -> bool:
        return name in self._host

    @property
    def registered(self) -> List[str]:
        return sorted(self._host)

    @property
    def resident(self) -> List[str]:
        return list(self._resident)

    # ------------------------------------------------------------ runtime
    def _load(self, name: str, idx: int):
        upd = self._build_tree(lambda m: {
            "a": jnp.zeros(m["a_shape"], self.dtype),
            "b": jnp.zeros(m["b_shape"], self.dtype)})
        for pt, ab in self._host[name].items():
            node = upd
            for k in pt[:-1]:
                node = node[k]
            node[pt[-1]] = {"a": jnp.asarray(ab["a"], self.dtype),
                            "b": jnp.asarray(ab["b"], self.dtype)}
        self._lora = self._write(self._lora, upd,
                                 jnp.asarray(idx, jnp.int32))
        self.loads += 1

    def acquire(self, name: str) -> Optional[int]:
        """Pin ``name`` and return its device index (the per-row adapter
        id for the decode batch).  Loads it into a free slot — evicting
        the LRU *unpinned* resident if needed — or returns ``None`` when
        every slot is pinned by in-flight requests (caller retries later).
        Raises ``KeyError`` for names never registered."""
        if name not in self._host:
            raise KeyError(f"unknown adapter {name!r}")
        if name in self._resident:
            self._resident.move_to_end(name)
            self._refs[name] = self._refs.get(name, 0) + 1
            return self._resident[name]
        if not self._free:
            victim = next((n for n in self._resident
                           if self._refs.get(n, 0) == 0), None)
            if victim is None:
                self.acquire_waits += 1
                return None
            self._free.append(self._resident.pop(victim))
            self.evictions += 1
        idx = self._free.pop()
        self._load(name, idx)
        self._resident[name] = idx
        self._refs[name] = self._refs.get(name, 0) + 1
        return idx

    def release(self, name: str):
        """Unpin one in-flight use (the adapter stays resident — warm for
        the tenant's next request — until LRU eviction needs the slot).
        Raises on an unbalanced release — like ``BlockPool.decref``, a
        refcount bug must surface immediately: silently under-counting
        would let eviction reload another tenant's weights into a device
        index a running request still decodes with."""
        if self._refs.get(name, 0) <= 0:
            raise ValueError(f"release of unpinned adapter {name!r}")
        self._refs[name] -= 1

    def stats(self) -> Dict[str, int]:
        return {"registered": len(self._host),
                "resident": len(self._resident),
                "pinned": sum(1 for n, r in self._refs.items() if r > 0),
                "slots": self.slots,
                "loads": self.loads,
                "evictions": self.evictions,
                "acquire_waits": self.acquire_waits}

    def collect_metrics(self, reg) -> None:
        """Pull adapter-pool residency/churn into a metrics registry:
        slot residency gauges plus load/evict/acquire-wait counters
        (an acquire-wait is a request left queued because every device
        slot was pinned — the multi-LoRA analogue of KV exhaustion)."""
        s = self.stats()
        reg.gauge("repro_adapters_registered_count",
                  "adapters registered (host copies)").set(
            s["registered"])
        reg.gauge("repro_adapters_resident_slots",
                  "device slots holding an adapter").set(s["resident"])
        reg.gauge("repro_adapters_pinned_slots",
                  "resident adapters pinned by in-flight requests").set(
            s["pinned"])
        reg.gauge("repro_adapters_capacity_slots",
                  "device adapter slots").set(s["slots"])
        reg.counter("repro_adapters_loads_total",
                    "host->device adapter loads").set(s["loads"])
        reg.counter("repro_adapters_evictions_total",
                    "LRU evictions of unpinned residents").set(
            s["evictions"])
        reg.counter("repro_adapters_acquire_waits_total",
                    "acquires deferred because all slots were "
                    "pinned").set(s["acquire_waits"])
