"""Token sampling: greedy / temperature / top-k / top-p (jit-able).

Two entry points: :func:`sample` filters one (B, V) batch with *shared*
scalar parameters (Python-level branching, one compile per setting), and
:func:`sample_batched` takes *per-row* parameter vectors with purely
traced control flow, so the engine can fuse one sampling call for a whole
continuous batch — mixed greedy/temperature/top-k/top-p requests — inside
the jitted decode step.  Rows with ``temperature <= 0`` reduce to argmax
exactly, so greedy outputs are identical between the two paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def sample(logits: jax.Array, key: jax.Array, *, temperature: float = 0.0,
           top_k: int = 0, top_p: float = 1.0) -> jax.Array:
    """logits: (B, V) fp32 -> (B,) int32 token ids."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, NEG, logits)
    if top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_l, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, NEG, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_batched(logits: jax.Array, key: jax.Array,
                   temperature: jax.Array, top_k: jax.Array,
                   top_p: jax.Array) -> jax.Array:
    """Per-row sampling over one batch: logits (B, V) fp32; temperature
    (B,) fp32; top_k (B,) int32 (0 disables); top_p (B,) fp32 (1.0
    disables).  Returns (B,) int32 token ids.

    The per-row filters mirror :func:`sample` exactly — kth-largest
    cutoff for top-k, smallest cumulative-probability set for top-p over
    the already-top-k-filtered logits — but with traced parameters, so a
    batch mixing settings compiles once.
    """
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits / jnp.maximum(temperature, 1e-6)[:, None]
    # top-k: kth-largest value per row (k = V disables the filter)
    desc = jnp.sort(l, axis=-1)[:, ::-1]
    k_eff = jnp.where(top_k > 0, jnp.minimum(top_k, V), V)
    kth = jnp.take_along_axis(desc, (k_eff - 1)[:, None], axis=-1)
    l = jnp.where(l < kth, NEG, l)
    # top-p on the filtered logits: smallest set with cum prob >= top_p
    desc = jnp.sort(l, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cut_idx = jnp.sum(cum < top_p[:, None], axis=-1)
    cutoff = jnp.take_along_axis(desc, cut_idx[:, None], axis=-1)
    l = jnp.where(l < cutoff, NEG, l)
    sampled = jax.random.categorical(key, l, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)
