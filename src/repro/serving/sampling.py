"""Token sampling: greedy / temperature / top-k / top-p (jit-able).

Entry points: :func:`sample` filters one (B, V) batch with *shared*
scalar parameters (Python-level branching, one compile per setting);
:func:`sample_batched` takes *per-row* parameter vectors with purely
traced control flow, so the engine can fuse one sampling call for a
whole continuous batch — mixed greedy/temperature/top-k/top-p requests —
inside the jitted decode step; :func:`spec_accept_batched` is the
speculative-decoding accept/reject cascade over a multi-token verify
launch, built on the same per-row filter (:func:`filter_logits`) so
speculative and plain sampling target the identical distribution.  Rows
with ``temperature <= 0`` reduce to argmax exactly, so greedy outputs
are identical across all paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def sample(logits: jax.Array, key: jax.Array, *, temperature: float = 0.0,
           top_k: int = 0, top_p: float = 1.0) -> jax.Array:
    """logits: (B, V) fp32 -> (B,) int32 token ids."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, NEG, logits)
    if top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_l, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, NEG, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def filter_logits(logits: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """Per-row temperature/top-k/top-p filter with traced parameters —
    the shared transform behind :func:`sample_batched` and the
    speculative verify cascade (:func:`spec_accept_batched`), so both
    paths sample from the *same* filtered target distribution.

    logits (B, V) fp32; temperature (B,) fp32; top_k (B,) int32 (0
    disables); top_p (B,) fp32 (1.0 disables).  Returns filtered logits
    (B, V): kth-largest cutoff for top-k, then the smallest
    cumulative-probability set >= top_p over the top-k-filtered logits —
    mirroring the Python-branching :func:`sample` exactly.
    """
    B, V = logits.shape
    l = logits / jnp.maximum(temperature, 1e-6)[:, None]
    # top-k: kth-largest value per row (k = V disables the filter)
    desc = jnp.sort(l, axis=-1)[:, ::-1]
    k_eff = jnp.where(top_k > 0, jnp.minimum(top_k, V), V)
    kth = jnp.take_along_axis(desc, (k_eff - 1)[:, None], axis=-1)
    l = jnp.where(l < kth, NEG, l)
    # top-p on the filtered logits: smallest set with cum prob >= top_p
    desc = jnp.sort(l, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cut_idx = jnp.sum(cum < top_p[:, None], axis=-1)
    cutoff = jnp.take_along_axis(desc, cut_idx[:, None], axis=-1)
    return jnp.where(l < cutoff, NEG, l)


def sample_batched(logits: jax.Array, key: jax.Array,
                   temperature: jax.Array, top_k: jax.Array,
                   top_p: jax.Array) -> jax.Array:
    """Per-row sampling over one batch: logits (B, V) fp32; temperature
    (B,) fp32; top_k (B,) int32 (0 disables); top_p (B,) fp32 (1.0
    disables).  Returns (B,) int32 token ids.

    The per-row filters (:func:`filter_logits`) mirror :func:`sample`
    exactly but with traced parameters, so a batch mixing settings
    compiles once.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = filter_logits(logits, temperature, top_k, top_p)
    sampled = jax.random.categorical(key, l, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)


def spec_accept_batched(logits: jax.Array, tokens: jax.Array,
                        draft_probs: jax.Array, n_draft: jax.Array,
                        key: jax.Array, temperature: jax.Array,
                        top_k: jax.Array, top_p: jax.Array,
                        greedy: bool):
    """Distribution-preserving speculative accept/reject for one batch.

    One verify launch scored a T-token tail per row: ``logits`` (B,T,V)
    where ``logits[:, t]`` is the target distribution for the token
    *after* tail position t; ``tokens`` (B,T) is the tail itself —
    ``tokens[:, 0]`` the last emitted (always-valid) token and
    ``tokens[:, 1:]`` the k = T-1 drafted tokens; ``draft_probs``
    (B,k,V) the distribution each draft was sampled from — or ``None``
    for deterministic drafters, in which case the one-hot ``q`` is
    built *inside* the jit from the draft token ids (skipping a dense
    (B,k,V) host allocation + transfer on the decode hot path);
    ``n_draft`` (B,) how many drafts are real for each row (0 disables
    speculation for the row — it degenerates to one plain sample from
    ``logits[:, 0]``, the baseline micro-step).

    Per row: drafts are accepted left-to-right while ``u < p(d)/q(d)``
    (standard leapfrog rejection); at the first rejection the token is
    resampled from the residual ``norm(max(p - q, 0))``, and when every
    draft is accepted a bonus token is sampled from the next position's
    target.  The emitted-token marginal therefore equals the (filtered)
    target distribution regardless of the drafter — the property
    tests/test_speculative.py checks statistically.  Greedy rows
    (``temperature <= 0``, or the whole batch when the static ``greedy``
    flag is set) use exact argmax matching, which makes speculative
    outputs *token-identical* to the non-speculative engine.

    Returns (out_tokens (B,T), n_emit (B,)): row b emits
    ``out_tokens[b, :n_emit[b]]`` (``n_emit = accepted + 1``, always
    >= 1) and rolls its KV length back to ``base + n_emit``.
    """
    B, T, V = logits.shape
    k = T - 1
    drafts = tokens[:, 1:]                                     # (B,k)
    tpos = jnp.arange(k)[None, :]
    tt = jnp.arange(T)[None, :]
    gm = jnp.argmax(logits, axis=-1).astype(jnp.int32)         # (B,T)
    drafts_pad = jnp.concatenate(
        [drafts, jnp.zeros((B, 1), jnp.int32)], axis=1)        # (B,T)

    def emit(m, corr):
        """Tokens 0..m-1 from the drafts, token m = correction/bonus."""
        return jnp.where(tt < m[:, None], drafts_pad,
                         jnp.where(tt == m[:, None], corr, 0))

    acc_g = (drafts == gm[:, :k]) & (tpos < n_draft[:, None])
    m_g = jnp.sum(jnp.cumprod(acc_g.astype(jnp.int32), axis=1), axis=1)
    out_g, n_g = emit(m_g, gm), m_g + 1
    if greedy:
        return out_g, n_g.astype(jnp.int32)

    lf = filter_logits(logits.reshape(B * T, V),
                       jnp.repeat(temperature, T), jnp.repeat(top_k, T),
                       jnp.repeat(top_p, T))
    p = jax.nn.softmax(lf, axis=-1).reshape(B, T, V)
    if draft_probs is None:  # deterministic drafter: q = one-hot(draft)
        draft_probs = jax.nn.one_hot(drafts, V, dtype=jnp.float32)
    q = jnp.where(tpos[..., None] < n_draft[:, None, None],
                  draft_probs, 0.0)                            # (B,k,V)
    ku, kc = jax.random.split(key)
    u = jax.random.uniform(ku, (B, max(k, 1)))[:, :k]
    p_d = jnp.take_along_axis(p[:, :k], drafts[..., None], -1)[..., 0]
    q_d = jnp.take_along_axis(q, drafts[..., None], -1)[..., 0]
    # u < p/q  <=>  u*q < p (q > 0 wherever a draft was proposed)
    acc = (u * q_d < p_d) & (tpos < n_draft[:, None])
    m = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)
    # residual at the first rejected position; at m == n_draft the draft
    # mass is zero there, so the "residual" is the plain target (bonus)
    p_m = jnp.take_along_axis(p, m[:, None, None], axis=1)[:, 0]
    q_pad = jnp.concatenate([q, jnp.zeros((B, 1, V))], axis=1)
    q_m = jnp.take_along_axis(q_pad, m[:, None, None], axis=1)[:, 0]
    resid = jnp.maximum(p_m - q_m, 0.0)
    rs = jnp.sum(resid, axis=-1, keepdims=True)
    # p <= q pointwise can only mean p == q, where rejection has
    # probability 0 — the guard only protects against float underflow
    resid = jnp.where(rs > 1e-12, resid / jnp.maximum(rs, 1e-30), p_m)
    corr = jax.random.categorical(
        kc, jnp.log(jnp.maximum(resid, 1e-30)), axis=-1).astype(jnp.int32)
    out_s, n_s = emit(m, corr[:, None]), m + 1
    g_row = (temperature <= 0.0)
    out = jnp.where(g_row[:, None], out_g, out_s)
    return out, jnp.where(g_row, n_g, n_s).astype(jnp.int32)
