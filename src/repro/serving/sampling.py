"""Token sampling: greedy / temperature / top-k / top-p (jit-able)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def sample(logits: jax.Array, key: jax.Array, *, temperature: float = 0.0,
           top_k: int = 0, top_p: float = 1.0) -> jax.Array:
    """logits: (B, V) fp32 -> (B,) int32 token ids."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, NEG, logits)
    if top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_l, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, NEG, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
