"""Chunked-prefill scheduler policy for the inference engine.

The engine's old loop ("admit one request OR decode the batch") had two
problems: a deep waiting queue starved every running request (a tick
that admitted never decoded), and every prompt was prefilled from
scratch.  This policy object replaces it (see README.md):

- **Admission** pops up to ``admit_per_tick`` requests per tick.  Each
  admitted request first runs a longest-prefix match against the radix
  prefix cache (:mod:`repro.serving.prefix_cache`); the matched KV
  becomes the request's cache prefix and only the uncached suffix needs
  compute.  On the *paged* KV path the hit is copy-free — the matched
  physical blocks are spliced into the request's block table with a
  refcount bump.  On the dense fallback the matched segment is copied
  into the request's slot.
- **Decode runs every tick.**  Running requests emit at least one token
  per tick regardless of admission activity.
- **Chunked prefill.**  Uncached suffixes are consumed through the
  batched decode step — at most ``prefill_chunk`` suffix tokens per
  request per tick, as micro-steps in which *every* running slot
  advances: prefilling slots consume their next prompt token while
  decoding slots keep emitting.  A long prefill therefore never stalls
  a running decode (the old loop's ITL cliff).
- **Fused batched sampling.**  Each micro-step makes one jitted
  decode+sample call with per-slot temperature/top-k/top-p vectors and
  one coalesced ``device_get`` of the sampled tokens — not a per-slot
  ``int(tok[0])`` sync per running request.
- **Preemption, not over-commit (paged).**  Decode growth allocates real
  pool blocks.  On exhaustion the scheduler first evicts unpinned prefix
  tree leaves, then preempts the *latest-admitted* running request: its
  blocks are freed and it returns to the queue head with its generated
  tokens folded into the prompt, so resumption re-prefills (usually a
  prefix-cache hit) and continues token-exactly.
- **Speculative bursts.**  With a drafter attached
  (``InferenceEngine(speculative=...)``), micro-steps with decoding
  slots run :meth:`_spec_micro_step` instead: each decode slot drafts up
  to ``spec_k`` tokens, ONE multi-token verify launch scores them all,
  and the slot emits 1..spec_k+1 accepted tokens (rejected tail rolled
  back by length shrink + block trim).  Greedy outputs stay
  token-identical to the plain micro-step.

Exactness: suffix tokens pass through ``decode_step`` at their true
positions against the already-written prefix KV, which is the same math
as a full prefill (causal attention, identical RoPE positions); the
engine-vs-reference tests pin this token-for-token for both KV layouts.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.adapters import adapter_namespace
from repro.serving.prefix_cache import (Match, PagedPrefixCache, PrefixCache,
                                        supports_prefix_cache)


@dataclasses.dataclass
class SchedulerConfig:
    admit_per_tick: int = 1
    # max uncached suffix tokens consumed per request per tick; also the
    # one-shot prefill size cap for cache-miss prompts
    prefill_chunk: int = 512
    enable_prefix_cache: bool = True
    # token-block size of the radix tree; on the paged KV path this is
    # also the physical pool block size (node <-> block, 1:1)
    prefix_block: int = 16
    # KV token budget of the prefix cache; default = one full slot batch
    # (dense) / the pool size (paged)
    cache_capacity_tokens: Optional[int] = None
    # graceful degradation: after this many pressure events (kv-defers /
    # preemptions) step the ladder down one level (1 = suspend
    # speculative decoding, 2 = also pause admission); after this many
    # pressure-free ticks step back up
    degrade_after: int = 4
    restore_after: int = 6


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    """Pow2 prefill-padding bucket (shared with speculative.py's
    draft-model prefill, so both compile against the same shape set)."""
    for b in buckets:
        if n <= b:
            return b
    return -(-n // 4096) * 4096


class ChunkedPrefillScheduler:
    """Policy object driving one engine's admission + decode loop."""

    def __init__(self, engine, config: Optional[SchedulerConfig] = None):
        from repro.models import model as M
        self.eng = engine
        self.config = config or SchedulerConfig()
        self.paged = getattr(engine, "paged", False)
        # disaggregated serving: "prefill" ticks export a KVHandoff when
        # a prompt's KV is complete (never decoding), "decode" ticks
        # admit from the engine's handoff queue (never raw prompts)
        self.role = getattr(engine, "role", "unified")
        self.supported = supports_prefix_cache(engine.cfg)
        self.prefix_cache: Optional[PrefixCache] = None
        if self.config.enable_prefix_cache and self.supported:
            if self.paged:
                cap = (self.config.cache_capacity_tokens
                       if self.config.cache_capacity_tokens is not None
                       else (engine.slots.bp.num_blocks - 1)
                       * engine.slots.block_size)
                self.prefix_cache = PagedPrefixCache(
                    engine.slots.bp,
                    block_size=engine.slots.block_size,
                    capacity_tokens=cap)
            else:
                cap = (self.config.cache_capacity_tokens
                       if self.config.cache_capacity_tokens is not None
                       else engine.capacity * engine.slots.B)
                self.prefix_cache = PrefixCache(
                    M.cache_axes(engine.cfg),
                    block_size=self.config.prefix_block,
                    capacity_tokens=cap)
        # slot -> index of the next prompt token to stream through decode
        self.pending: Dict[int, int] = {}
        # request_id -> pinned radix nodes (unpinned at finish/release)
        self._locked: Dict[str, List] = {}
        # slot -> admission sequence number (preemption picks the max)
        self._admit_order: Dict[int, int] = {}
        self._admit_seq = itertools.count()
        # slot -> device adapter id (rows without an entry decode as base)
        self._slot_adapter: Dict[int, int] = {}
        # decode role: slot -> the KVHandoff it was admitted from, kept
        # so preemption can requeue the pair (re-admission re-imports)
        self._slot_handoff: Dict[int, object] = {}
        # graceful-degradation ladder: 0 = normal, 1 = speculative
        # decoding suspended, 2 = admission paused too.  Pressure events
        # (kv admission defers, preemptions) push it down; pressure-free
        # ticks pull it back up.
        self.degrade_level = 0
        self._tick_pressure = 0   # pressure events in the current tick
        self._pressure = 0        # accumulated since last transition
        self._calm_ticks = 0      # consecutive pressure-free ticks
        # observability (engine-owned; None = zero-overhead off state).
        # Push-side instruments are pre-registered here so the per-tick
        # path is attribute lookups + appends, never registry lookups.
        self.obs = getattr(engine, "obs", None)
        if self.obs is not None:
            reg = self.obs.registry
            self._h_tick = reg.histogram(
                "repro_sched_tick_seconds",
                "wall time of one scheduler tick")
            self._h_occupancy = reg.histogram(
                "repro_sched_batch_occupancy_ratio",
                "running slots / batch size, sampled per micro-step",
                buckets=(0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875,
                         1.0))
            self._g_queue = reg.gauge(
                "repro_sched_queue_depth_requests",
                "requests waiting for admission")
            self._g_running = reg.gauge(
                "repro_sched_running_requests",
                "requests holding a decode slot")
            self._c_deferred = reg.counter(
                "repro_sched_admit_deferred_total",
                "admissions deferred to a later tick",
                labelnames=("reason",))
            self._c_degrade = reg.counter(
                "repro_sched_degrade_transitions_total",
                "graceful-degradation ladder transitions",
                labelnames=("direction",))
            self._g_degrade = reg.gauge(
                "repro_sched_degrade_level_count",
                "degradation level (0 normal, 1 spec off, 2 admission "
                "paused)")
            self._c_handoff_out = reg.counter(
                "repro_serving_handoff_exported_total",
                "prefill-role KV handoffs exported to the outbox")
            self._c_handoff_in = reg.counter(
                "repro_serving_handoff_imported_total",
                "decode-role KV handoffs imported into a slot")
            self._c_handoff_blocks = reg.counter(
                "repro_serving_handoff_blocks_total",
                "physical KV blocks carried by handoffs (exported, or "
                "scattered on import — adopted blocks excluded)")
            self._c_handoff_bytes = reg.counter(
                "repro_serving_handoff_bytes_total",
                "host payload bytes gathered for exported handoffs")
            self._c_handoff_adopted = reg.counter(
                "repro_serving_handoff_adopted_blocks_total",
                "imported-handoff blocks satisfied by the decode-side "
                "radix tree (spliced, not re-uploaded)")

    def _defer(self, reason: str) -> bool:
        """Count a deferred admission (kv pressure / pinned adapter
        slots); returns False so call sites can ``return self._defer``."""
        if reason == "kv":
            self._tick_pressure += 1
        if self.obs is not None:
            self._c_deferred.labels(reason=reason).inc()
        return False

    # ------------------------------------------------------------ tick
    def tick(self):
        if self.obs is None:
            self._run_tick()
            return
        eng, tr = self.eng, self.obs.tracer
        t0 = eng.clock()
        sp = tr.begin("scheduler", "tick", cat="sched",
                      queued=len(eng.queue), running=len(eng.running))
        self._run_tick()
        tr.end(sp)
        self._h_tick.observe(eng.clock() - t0)
        self._g_queue.set(len(eng.queue))
        self._g_running.set(len(eng.running))

    def _run_tick(self):
        if self.obs is not None:
            # direct begin/end (no contextmanager frame) and no child
            # span when the phase has no work — decode-heavy ticks with
            # an empty queue stay one event, not three
            tr = self.obs.tracer
            if self.eng.queue or self.eng.handoffs:
                sp = tr.begin("scheduler", "admit", cat="sched")
                self._admit_tick()
                tr.end(sp)
            else:
                self._admit_tick()
            if self.eng.running:
                sp = tr.begin("scheduler", "decode", cat="sched")
                self._decode_tick()
                tr.end(sp)
            else:
                self._decode_tick()
            self._degrade_update()
            return
        self._admit_tick()
        self._decode_tick()
        self._degrade_update()

    def _admit_tick(self):
        if self.degrade_level >= 2:
            # deepest ladder rung: shed admission load entirely so the
            # running batch can finish and free pool blocks.  This defer
            # must NOT count as pressure or the pause would self-sustain.
            if self.eng.queue or self.eng.handoffs:
                self._defer("degraded")
            return
        admitted = 0
        while admitted < self.config.admit_per_tick and self._admit_one():
            admitted += 1

    # ------------------------------------------------- graceful degradation
    def _degrade_update(self):
        """End-of-tick ladder update: sustained pressure steps down
        (suspend speculation, then pause admission); sustained calm
        steps back up one rung at a time."""
        if self._tick_pressure:
            self._pressure += self._tick_pressure
            self._tick_pressure = 0
            self._calm_ticks = 0
            if (self._pressure >= self.config.degrade_after
                    and self.degrade_level < 2):
                self._pressure = 0
                self._set_degrade(self.degrade_level + 1)
            return
        self._calm_ticks += 1
        if self._calm_ticks >= self.config.restore_after:
            self._calm_ticks = 0
            self._pressure = 0
            if self.degrade_level > 0:
                self._set_degrade(self.degrade_level - 1)

    def _set_degrade(self, level: int):
        old, self.degrade_level = self.degrade_level, level
        if self.obs is not None:
            direction = "down" if level > old else "up"
            self._c_degrade.labels(direction=direction).inc()
            self._g_degrade.set(level)
            self.obs.tracer.instant(
                "scheduler", "degrade" if level > old else "restore",
                cat="sched", level=level)

    def drained(self) -> bool:
        return (not self.eng.queue and not self.eng.running
                and not self.eng.handoffs)

    def match_len(self, namespace: str, tokens) -> int:
        """Longest stored prefix (tokens) — used for affinity routing."""
        if self.prefix_cache is None:
            return 0
        return self.prefix_cache.match_len(namespace, tokens)

    @staticmethod
    def _ns(req) -> str:
        """Prefix-cache namespace: KV computed under a LoRA adapter is
        only reusable under that same adapter, so adapter'd requests get
        a dedicated radix tree within their tenant namespace."""
        return adapter_namespace(req.namespace, req.adapter)

    # ------------------------------------------------------------ admission
    def _admit_one(self) -> bool:
        if self.role == "decode":
            return self._admit_handoff()
        eng = self.eng
        if not eng.queue:
            return False
        if not eng.slots.free:
            return self._defer("slots")
        req = eng.queue[0]
        # a preempted request resumes with its generated tokens folded
        # into the prompt; only the *remaining* budget counts
        need = (len(req.prompt) + req.max_new_tokens - len(req.generated))
        if eng.drafter is not None:
            # the speculative verify step writes spec_k + 1 tail
            # positions before accept/reject, so the slot must be able
            # to address spec_k extra positions past the last real token
            need += eng.spec_k
        if need > eng.capacity or (req.adapter and (
                eng.adapters is None or not eng.adapters.has(req.adapter))):
            # can never fit / names an unknown adapter: explicit
            # rejection, not a silent "finish"
            eng.queue.popleft()
            req.done = True
            eng.metrics.reject(req.request_id, eng.clock())
            return True      # queue progressed; keep admitting
        n = len(req.prompt)
        chunk0 = n
        if self.supported and n > self.config.prefill_chunk:
            chunk0 = self.config.prefill_chunk
        if self.paged:
            # worst-case (cache-miss) block need for the first chunk;
            # eviction of unpinned tree leaves can free at most
            # evictable_blocks() more.  Admission is counted in physical
            # pool blocks, so an int8 pool (kv_dtype="int8") doubles the
            # admittable load at the same pool_tokens budget with no
            # change here — num_free simply starts ~2x higher.
            avail = eng.slots.bp.num_free
            if self.prefix_cache is not None:
                avail += self.prefix_cache.evictable_blocks()
            if eng.slots.blocks_for(chunk0) > avail:
                return self._defer("kv")
        elif not eng.ledger.can_admit(req.request_id, need):
            return self._defer("kv")
        aid = 0
        if req.adapter:
            # load-or-pin the adapter (refcount++).  None means every
            # device adapter slot is pinned by an in-flight request —
            # leave the request queued and retry next tick.
            aid = eng.adapters.acquire(req.adapter)
            if aid is None:
                return self._defer("adapter")
        eng.queue.popleft()
        if not self.paged:
            eng.ledger.admit(req.request_id, need)
        slot = eng.slots.allocate(req.request_id)
        if aid:
            self._slot_adapter[slot] = aid
        eng.metrics.prefill_start(req.request_id, eng.clock())

        cached = 0
        if self.prefix_cache is not None and not req.extras:
            m: Match = self.prefix_cache.match(self._ns(req), req.prompt)
            if self.paged:
                bs = eng.slots.block_size
                n_use = min(len(m.nodes), (n - 1) // bs)
                cached = n_use * bs
            else:
                cached = min(m.length, n - 1)
            # take the hit only when streaming the uncached suffix costs
            # no more model launches than the miss path (one one-shot
            # prefill chunk + streamed tail) — a short cached prefix on a
            # long prompt would otherwise *worsen* TTFT.  Paged hits are
            # whole-block, losing up to block_size-1 cached tokens to
            # rounding; grant exactly that slack so accept decisions
            # match the dense (token-granular) policy
            miss_launches = 1 + max(0, n - self.config.prefill_chunk)
            if self.paged:
                miss_launches += eng.slots.block_size - 1
            if cached > 0 and n - cached <= miss_launches:
                if self.paged:
                    nodes = m.nodes[:n_use]
                    self.prefix_cache.lock(nodes)
                    self._locked.setdefault(req.request_id, []).extend(nodes)
                    ids = self.prefix_cache.gather_block_ids(m, n_use)
                    # copy-free: refcount bump + table splice, no KV moved
                    eng.slots.adopt_prefix(slot, ids, cached)
                else:
                    self.prefix_cache.lock(m.nodes)
                    self._locked.setdefault(req.request_id,
                                            []).extend(m.nodes)
                    seg = self.prefix_cache.gather(m, cached)
                    seg = self._pad_segment(seg, min(_bucket(cached),
                                                     eng.capacity))
                    eng.slots.insert(slot, seg, cached)
                eng.metrics.prefix_hit(req.request_id, cached)
            else:
                cached = 0
        eng.running[slot] = req
        self._admit_order[slot] = next(self._admit_seq)

        if cached > 0:
            # stream the uncached suffix through decode micro-steps
            self.pending[slot] = cached
            return True

        # cache miss: one-shot prefill of the first chunk (the whole
        # prompt unless it exceeds prefill_chunk on a chunkable model)
        chunk = chunk0
        if self.paged and not self._ensure_blocks(slot, chunk):
            # pool exhausted even after eviction: put the request back
            # and wait for blocks to free up
            eng.running.pop(slot, None)
            self._admit_order.pop(slot, None)
            self._release_adapter(slot, req)
            eng.slots.release(slot)
            eng.queue.appendleft(req)
            return self._defer("kv")
        pad = _bucket(chunk)
        toks = np.zeros((1, pad), np.int32)
        toks[0, :chunk] = req.prompt[:chunk]
        n_front = (eng.cfg.frontend_tokens
                   if eng.cfg.frontend == "vision" else 0)
        batch = {"tokens": jnp.asarray(toks),
                 "prompt_lengths": jnp.asarray([chunk + n_front], jnp.int32)}
        if req.extras:
            batch.update({k: jnp.asarray(v) for k, v in req.extras.items()})
        lo, ai = self._lora_args([aid])
        logits, cache, _ = eng._prefill(eng.params, batch, lo, ai)
        from repro.models import model as M
        if self.paged:
            eng.slots.insert_prefill(slot, cache, chunk + n_front)
        else:
            cache = M.pad_cache(eng.cfg, cache, eng.capacity)
            eng.slots.insert(slot, cache, chunk + n_front)

        if chunk < n:
            self.pending[slot] = chunk
        elif self.role == "prefill":
            self._store_prompt(slot, req)
            self._handoff_out(slot, req)
        else:
            self._store_prompt(slot, req)
            tok = eng._sample(logits, req)
            self._emit(slot, req, int(tok[0]))
        return True

    # ----------------------------------------------------------- handoff
    def _admit_handoff(self) -> bool:
        """Decode-role admission: import a prefilled request's KV from
        the engine's handoff queue.  Mirrors :meth:`_admit_one`
        (capacity checks, adapter pins, prefix adoption, explicit
        rejection) but never runs prefill compute — the handoff blocks
        are spliced/scattered in and the standard pending-stream path
        re-feeds the final prompt token to produce the first-token
        logits on THIS engine.  Pool pressure defers (the pair stays
        queued); nothing is ever silently dropped."""
        eng = self.eng
        if not eng.handoffs:
            return False
        if not eng.slots.free:
            return self._defer("slots")
        req, ho = eng.handoffs[0]
        need = (len(req.prompt) + req.max_new_tokens - len(req.generated))
        if eng.drafter is not None:
            need += eng.spec_k
        if need > eng.capacity or (req.adapter and (
                eng.adapters is None or not eng.adapters.has(req.adapter))):
            eng.handoffs.popleft()
            req.done = True
            eng.metrics.reject(req.request_id, eng.clock())
            return True
        # worst-case block need for the imported prefix (prefix adoption
        # can only shrink it); eviction of unpinned tree leaves can free
        # at most evictable_blocks() more
        avail = eng.slots.bp.num_free
        if self.prefix_cache is not None:
            avail += self.prefix_cache.evictable_blocks()
        if eng.slots.blocks_for(ho.length) > avail:
            return self._defer("kv")
        aid = 0
        if req.adapter:
            aid = eng.adapters.acquire(req.adapter)
            if aid is None:
                return self._defer("adapter")
        eng.handoffs.popleft()
        slot = eng.slots.allocate(req.request_id)
        if aid:
            self._slot_adapter[slot] = aid
        eng.metrics.prefill_start(req.request_id, eng.clock())

        adopted_ids: List[int] = []
        adopted = 0
        if self.prefix_cache is not None and not req.extras:
            bs = eng.slots.block_size
            m: Match = self.prefix_cache.match(self._ns(req), req.prompt)
            # cap adoption so position ho.length - 1 (re-fed locally for
            # the first-token logits) lands in a privately imported
            # block — shared tree blocks are never written
            n_use = min(len(m.nodes), (ho.length - 1) // bs)
            if n_use > 0:
                nodes = m.nodes[:n_use]
                self.prefix_cache.lock(nodes)
                self._locked.setdefault(req.request_id, []).extend(nodes)
                adopted_ids = list(
                    self.prefix_cache.gather_block_ids(m, n_use))
                adopted = n_use * bs
                eng.metrics.prefix_hit(req.request_id, adopted)
        shortfall = eng.slots.blocks_for(ho.length) - len(adopted_ids)
        if eng.slots.bp.num_free < shortfall:
            self._reclaim(shortfall)
        ok = eng.slots.import_kv(slot, ho, adopted_ids, adopted)
        if not ok:
            # pool raced away between the avail check and the alloc:
            # roll the admission back completely and retry next tick
            self._release_adapter(slot, req)
            if self.prefix_cache is not None:
                nodes = self._locked.pop(req.request_id, None)
                if nodes:
                    self.prefix_cache.unlock(nodes)
            eng.slots.release(slot)
            eng.handoffs.appendleft((req, ho))
            return self._defer("kv")
        eng.running[slot] = req
        self._admit_order[slot] = next(self._admit_seq)
        self._slot_handoff[slot] = ho
        # resume point: the imported KV covers [0, ho.length); rewind one
        # token so the standard pending stream re-feeds the final prompt
        # token at its true position (rewriting identical KV in a private
        # block) and samples the first token here — token-identical to a
        # unified engine at temperature 0.  A preempted-and-refolded
        # request streams its folded suffix through the same path.
        eng.slots.lengths[slot] = ho.length - 1
        self.pending[slot] = ho.length - 1
        if self.obs is not None:
            self._c_handoff_in.inc()
            self._c_handoff_blocks.inc(ho.n_blocks - len(adopted_ids))
            if adopted_ids:
                self._c_handoff_adopted.inc(len(adopted_ids))
            self.obs.tracer.instant(
                "scheduler", "handoff_import", cat="sched",
                rid=req.request_id, tokens=ho.length,
                adopted_blocks=len(adopted_ids))
        return True

    def _handoff_out(self, slot: int, req):
        """Prefill-role completion: instead of sampling the first token,
        export the slot's finished KV as a host-side payload onto the
        engine's outbox and retire the slot.  The request is NOT done —
        a decode-role engine imports the payload and finishes it."""
        eng = self.eng
        ho = eng.slots.export_kv(req.request_id)
        ho.prompt_tokens = list(req.prompt)
        ho.adapter = req.adapter
        ho.exported_at = eng.clock()
        eng.metrics.handoff(req.request_id, eng.clock())
        eng.ledger.release(req.request_id)
        eng.slots.release(slot)
        eng.running.pop(slot, None)
        self.pending.pop(slot, None)
        self._admit_order.pop(slot, None)
        self._release_adapter(slot, req)
        self._release_drafter(slot)
        if self.prefix_cache is not None:
            nodes = self._locked.pop(req.request_id, None)
            if nodes:
                self.prefix_cache.unlock(nodes)
        eng.outbox.append((req, ho))
        if self.obs is not None:
            self._c_handoff_out.inc()
            self._c_handoff_blocks.inc(ho.n_blocks)
            self._c_handoff_bytes.inc(ho.payload_bytes)
            self.obs.tracer.instant(
                "scheduler", "handoff_export", cat="sched",
                rid=req.request_id, tokens=ho.length, blocks=ho.n_blocks)

    def _lora_args(self, ids):
        """(lora_tree, adapter_ids) for a model call — (None, None) on
        engines without an adapter pool, so the jit signature never
        changes mid-run."""
        if self.eng.adapters is None:
            return None, None
        return (self.eng.adapters.lora_tree(),
                jnp.asarray(np.asarray(ids, np.int32)))

    def _release_adapter(self, slot: int, req):
        """Unpin the request's adapter (refcount--; the weights stay
        resident for LRU reuse).  Keyed on the slot's pin entry so every
        ``acquire`` is paired with exactly one ``release``."""
        if self._slot_adapter.pop(slot, None) is not None:
            self.eng.adapters.release(req.adapter)

    def _release_drafter(self, slot: int):
        """Drop the drafter's per-slot state (draft KV cache / lookup
        index) when the slot turns over — finish or preemption."""
        if self.eng.drafter is not None:
            self.eng.drafter.release(slot)

    def _pad_segment(self, seg, target: int):
        """Pad a gathered segment's kvseq up to ``target`` so the slot
        insert compiles per pow2 bucket, not per exact match length."""
        from repro.serving.prefix_cache import tree_walk

        def one(arr, ax):
            i = ax.index("act_kvseq")
            if arr.shape[i] >= target:
                return arr
            pads = [(0, 0)] * arr.ndim
            pads[i] = (0, target - arr.shape[i])
            return jnp.pad(arr, pads)
        return tree_walk(one, seg, self.eng.slots._axes)

    # ------------------------------------------------------ paged memory
    def _ensure_blocks(self, slot: int, new_len: int) -> bool:
        """ensure_capacity with tree-eviction fallback (no preemption)."""
        eng = self.eng
        if eng.slots.ensure_capacity(slot, new_len):
            return True
        need = (eng.slots.blocks_for(new_len)
                - len(eng.slots.seq_blocks.get(slot, [])))
        self._reclaim(need)
        return eng.slots.ensure_capacity(slot, new_len)

    def _reclaim(self, n_blocks: int) -> bool:
        """Evict unpinned prefix-tree leaves until the pool has
        ``n_blocks`` free (shared leaves may free nothing — their blocks
        survive until the last running holder releases)."""
        bp = self.eng.slots.bp
        pc = self.prefix_cache
        while bp.num_free < n_blocks:
            if pc is None or not pc._evict_one():
                return False
        return True

    def _preempt_latest(self):
        """Free the latest-admitted running request's blocks and return
        it to the queue head.  Its generated tokens are folded into the
        prompt, so re-admission re-prefills (typically a prefix-cache
        hit) and generation resumes token-exactly."""
        eng = self.eng
        slot = max(eng.running, key=lambda s: self._admit_order.get(s, -1))
        req = eng.running.pop(slot)
        self.pending.pop(slot, None)
        self._admit_order.pop(slot, None)
        self._release_adapter(slot, req)
        self._release_drafter(slot)
        if self.prefix_cache is not None:
            nodes = self._locked.pop(req.request_id, None)
            if nodes:
                self.prefix_cache.unlock(nodes)
        fresh = req.generated[req.n_folded:]
        if fresh:
            req.prompt = list(req.prompt) + list(fresh)
            req.n_folded = len(req.generated)
        eng.slots.release(slot)
        eng.ledger.release(req.request_id)
        ho = self._slot_handoff.pop(slot, None)
        if ho is not None:
            # decode-role slot: the engine rejects raw prompts, so the
            # (request, handoff) pair requeues; re-admission re-imports
            # the payload and streams the folded suffix token-exactly
            eng.handoffs.appendleft((req, ho))
        else:
            eng.queue.appendleft(req)
        eng.metrics.preempt(req.request_id, eng.clock())
        self._tick_pressure += 1

    def evacuate(self) -> List:
        """Pull every in-flight request off the engine (crash/timeout
        path): running requests go through the preemption fold — their
        committed tokens become prompt suffix, slots/ledger/adapter
        pins/drafter state released — then the whole queue is drained.
        Returns the requests oldest-first, ready to resubmit anywhere
        token-exactly (at temperature 0)."""
        eng = self.eng
        while eng.running:
            self._preempt_latest()
        out = list(eng.queue)
        eng.queue.clear()
        # decode role: prefilled-but-waiting pairs evacuate as plain
        # requests — the handoff payload referenced a pool that may be
        # gone, so the gateway resubmits them for a fresh prefill
        out.extend(r for r, _ in eng.handoffs)
        eng.handoffs.clear()
        return out

    def reset_cache(self) -> None:
        """Drop the whole radix prefix cache (crash path: the cached KV
        lived in the dead process).  Call after :meth:`evacuate` — only
        unlocked nodes can be evicted."""
        pc = self.prefix_cache
        if pc is None:
            return
        while pc._evict_one():
            pass

    def _grow_all(self, n: int = 1):
        """Allocate the next ``n`` positions' blocks for every running
        slot (n = spec_k + 1 on speculative steps — rejected tail blocks
        are trimmed back after accept/reject), preempting latest-admitted
        requests when the pool (plus tree eviction) cannot supply them."""
        eng = self.eng
        while eng.running:
            stuck = None
            for slot in sorted(eng.running):
                if not self._ensure_blocks(slot,
                                           int(eng.slots.lengths[slot]) + n):
                    stuck = slot
                    break
            if stuck is None:
                return
            self._preempt_latest()

    # ------------------------------------------------------------ decode
    def _decode_tick(self):
        if not self.eng.running:
            return
        # while any slot is still prefilling (and the per-tick chunk
        # budget lasts), run extra micro-steps; every running slot
        # advances each micro-step, so decode is never stalled
        limit = max(1, self.config.prefill_chunk)
        steps = 0
        while True:
            self._micro_step()
            steps += 1
            if not self.pending or steps >= limit or not self.eng.running:
                break

    def _micro_step(self):
        """One fused decode+sample step.  Prefilling slots consume their
        next prompt token; decoding slots feed their last sampled token
        (its KV gets written now) and emit a new one.  Sampling runs
        batched inside the jitted step; the sampled tokens come back in
        one coalesced transfer.

        With a drafter attached, any tick with at least one *decoding*
        slot runs the speculative variant instead (prefilling slots ride
        along, advancing one prompt token as usual)."""
        eng = self.eng
        eng._fault("micro_step")
        if (eng.drafter is not None and self.degrade_level < 1
                and any(s not in self.pending for s in eng.running)):
            return self._spec_micro_step()
        if eng.paged:
            self._grow_all()
        if not eng.running:
            return
        if self.obs is None:
            return self._micro_step_body()
        # hot path: direct begin/end, O(1) slot counts (pending is a
        # subset of running), occupancy is one bisect
        self._h_occupancy.observe(len(eng.running) / eng.slots.B)
        tr = self.obs.tracer
        npre = len(self.pending)
        sp = tr.begin("scheduler", "micro_step", cat="sched",
                      decoding=len(eng.running) - npre, prefilling=npre)
        self._micro_step_body()
        tr.end(sp)

    def _micro_step_body(self):
        eng = self.eng
        B = eng.slots.B
        toks = np.zeros((B, 1), np.int32)
        advance = np.zeros((B,), bool)
        temps = np.zeros((B,), np.float32)
        tks = np.zeros((B,), np.int32)
        tps = np.ones((B,), np.float32)
        for slot, req in eng.running.items():
            advance[slot] = True
            if slot in self.pending:
                toks[slot, 0] = req.prompt[self.pending[slot]]
            else:
                toks[slot, 0] = req.generated[-1]
            temps[slot] = req.temperature
            tks[slot] = req.top_k
            tps[slot] = req.top_p
        greedy = bool(np.all(temps <= 0.0))
        aids = np.zeros((B,), np.int32)
        for slot, idx in self._slot_adapter.items():
            aids[slot] = idx
        lo, ai = self._lora_args(aids)
        eng.key, key = jax.random.split(eng.key)
        if eng.paged:
            lengths = np.where(advance, eng.slots.lengths + 1,
                               eng.slots.lengths).astype(np.int32)
            out, new_pool = eng._decode_sample_paged(
                eng.params, jnp.asarray(toks), eng.slots.pool,
                eng.slots.tables_device(), jnp.asarray(lengths), key,
                jnp.asarray(temps), jnp.asarray(tks), jnp.asarray(tps),
                lo, ai, greedy)
            eng.slots.pool = new_pool
        else:
            lengths = jnp.where(jnp.asarray(advance),
                                eng.slots.lengths + 1, eng.slots.lengths)
            out, new_cache = eng._decode_sample(
                eng.params, jnp.asarray(toks), eng.slots.cache, lengths,
                key, jnp.asarray(temps), jnp.asarray(tks),
                jnp.asarray(tps), lo, ai, greedy)
            eng.slots.cache = new_cache
        eng.slots.lengths = lengths
        sampled = np.asarray(out)          # one device_get for the batch
        for slot, req in list(eng.running.items()):
            if slot in self.pending:
                self.pending[slot] += 1
                if self.pending[slot] >= len(req.prompt):
                    # last prompt token consumed: its logits are the
                    # next-token logits — prefill is complete
                    del self.pending[slot]
                    self._store_prompt(slot, req)
                    if self.role == "prefill":
                        self._handoff_out(slot, req)
                    else:
                        self._emit(slot, req, int(sampled[slot]))
            else:
                self._emit(slot, req, int(sampled[slot]))

    def _spec_micro_step(self):
        """One speculative verify micro-step (variable tokens per tick).

        Per running *decode* slot: ask the drafter for up to spec_k
        candidate tokens (capped by the request's remaining budget),
        then score ``[last_emitted, draft_1..draft_n]`` in ONE jitted
        multi-token verify launch that also runs accept/reject
        (``sampling.spec_accept_batched``) — so a slot emits between 1
        and spec_k + 1 tokens per launch.  Prefilling slots ride along,
        consuming one prompt token (their draft count is 0, which
        degenerates to the plain micro-step for that row).

        The launch writes KV for all spec_k + 1 tail positions before
        the verdict is known; rejected positions are rolled back by
        shrinking the slot's length (stale KV past the length is never
        read and is overwritten when decode resumes there) and, on the
        paged path, returning the now-unreferenced tail blocks to the
        pool (``PagedCacheSlots.trim``).
        """
        eng = self.eng
        T = eng.spec_k + 1
        if eng.paged:
            self._grow_all(T)
        if not eng.running:
            return
        if self.obs is None:
            return self._spec_body(T)
        self._h_occupancy.observe(len(eng.running) / eng.slots.B)
        tr = self.obs.tracer
        sp = tr.begin("scheduler", "spec_verify", cat="sched",
                      slots=len(eng.running), k=eng.spec_k)
        self._spec_body(T)
        tr.end(sp)

    def _spec_body(self, T: int):
        eng = self.eng
        k = T - 1
        B = eng.slots.B
        Vp = eng.cfg.vocab_padded
        toks = np.zeros((B, T), np.int32)
        nd = np.zeros((B,), np.int32)
        # deterministic drafters (q = one-hot) skip the dense (B,k,V)
        # host buffer: the accept jit rebuilds q from the token ids
        det = eng.drafter.deterministic
        dprobs = None if det else np.zeros((B, k, Vp), np.float32)
        advance = np.zeros((B,), bool)
        temps = np.zeros((B,), np.float32)
        tks = np.zeros((B,), np.int32)
        tps = np.ones((B,), np.float32)
        for slot, req in eng.running.items():
            advance[slot] = True
            temps[slot] = req.temperature
            tks[slot] = req.top_k
            tps[slot] = req.top_p
            if slot in self.pending:
                toks[slot, 0] = req.prompt[self.pending[slot]]
                continue  # prefill rows advance exactly one prompt token
            toks[slot, 0] = req.generated[-1]
            # drafting past the remaining budget is wasted verification:
            # the launch emits at most n_draft + 1 tokens
            cap = min(k, req.max_new_tokens - len(req.generated) - 1)
            if cap <= 0:
                continue
            ctx = list(req.prompt) + list(req.generated)
            drafts, qp = eng.drafter.propose(slot, ctx, cap,
                                             req.temperature)
            n = len(drafts)
            if n:
                toks[slot, 1:1 + n] = drafts
                if not det:
                    dprobs[slot, :n] = qp
                nd[slot] = n
        greedy = bool(np.all(temps <= 0.0))
        aids = np.zeros((B,), np.int32)
        for slot, idx in self._slot_adapter.items():
            aids[slot] = idx
        lo, ai = self._lora_args(aids)
        eng.key, key = jax.random.split(eng.key)
        base = np.asarray(eng.slots.lengths, np.int32)
        lengths = np.where(advance, base + T, base).astype(np.int32)
        dp = None if det else jnp.asarray(dprobs)
        if eng.paged:
            out, nem, new_pool = eng._verify_paged(
                eng.params, jnp.asarray(toks), eng.slots.pool,
                eng.slots.tables_device(), jnp.asarray(lengths), key,
                jnp.asarray(temps), jnp.asarray(tks), jnp.asarray(tps),
                dp, jnp.asarray(nd), lo, ai, greedy)
            eng.slots.pool = new_pool
        else:
            out, nem, new_cache = eng._verify(
                eng.params, jnp.asarray(toks), eng.slots.cache,
                jnp.asarray(lengths), key, jnp.asarray(temps),
                jnp.asarray(tks), jnp.asarray(tps), dp,
                jnp.asarray(nd), lo, ai, greedy)
            eng.slots.cache = new_cache
        out = np.asarray(out)           # one device_get for the batch
        nem = np.asarray(nem)
        # roll lengths back to the accepted burst BEFORE emitting (a
        # finishing _emit releases the slot and resets its length)
        final = base.copy()
        for slot in eng.running:
            final[slot] = base[slot] + (1 if slot in self.pending
                                        else int(nem[slot]))
        if eng.paged:
            for slot in eng.running:
                eng.slots.trim(slot, int(final[slot]))
            eng.slots.lengths = final
        else:
            eng.slots.lengths = jnp.asarray(final)
        for slot, req in list(eng.running.items()):
            if slot in self.pending:
                self.pending[slot] += 1
                if self.pending[slot] >= len(req.prompt):
                    del self.pending[slot]
                    self._store_prompt(slot, req)
                    if self.role == "prefill":
                        self._handoff_out(slot, req)
                    else:
                        self._emit(slot, req, int(out[slot, 0]))
                continue
            n = int(nem[slot])
            emitted = 0
            for t in range(n):
                self._emit(slot, req, int(out[slot, t]))
                emitted += 1
                if req.done:
                    break  # EOS/budget mid-burst: drop the tail
            eng.metrics.speculative(int(nd[slot]), n - 1, emitted)

    # ------------------------------------------------------------ lifecycle
    def _store_prompt(self, slot: int, req):
        """Index this prompt's KV (from its slot, before any generated
        token could be confused for prompt) into the radix tree."""
        if self.prefix_cache is None or req.extras:
            return
        if len(req.prompt) < self.prefix_cache.block_size:
            return
        if self.paged:
            # zero-copy: donate the slot's own physical block ids (the
            # tree refcounts them; nothing is extracted or copied)
            ids = self.eng.slots.block_ids(slot)
            bs = self.eng.slots.block_size
            new = self.prefix_cache.insert(
                self._ns(req), req.prompt, lambda s, e: ids[s // bs])
        else:
            new = self.prefix_cache.insert(
                self._ns(req), req.prompt,
                lambda s, e: self.eng.slots.extract(slot, s, e))
        if new:
            self._locked.setdefault(req.request_id, []).extend(new)

    def _emit(self, slot: int, req, token: int):
        eng = self.eng
        # the fault fires BEFORE the token commits: a crash here drops
        # the uncommitted token, and temp-0 resumption re-derives it
        eng._fault("emission")
        req.generated.append(token)
        eng.metrics.token(req.request_id, eng.clock())
        if (token == req.eos_id
                or len(req.generated) >= req.max_new_tokens):
            req.done = True
            eng.metrics.finish(req.request_id, eng.clock())
            eng.ledger.release(req.request_id)
            eng.slots.release(slot)
            eng.running.pop(slot, None)
            self.pending.pop(slot, None)
            self._admit_order.pop(slot, None)
            self._slot_handoff.pop(slot, None)
            self._release_adapter(slot, req)
            self._release_drafter(slot)
            if self.prefix_cache is not None:
                nodes = self._locked.pop(req.request_id, None)
                if nodes:
                    self.prefix_cache.unlock(nodes)
