"""Radix-tree prefix cache over token-block chunks (see README.md).

Automatic prefix reuse for the serving engine: prompts that share a
prefix (system prompts, few-shot headers, multi-turn history) reuse the
KV segments a previous prefill already computed, so only the uncached
suffix is prefilled.  The index is a trie whose edges are fixed-size
*token blocks* (``block_size`` tokens per node); each node owns the KV
segment for its block — a pytree mirroring the model cache structure
with ``act_batch == 1`` and ``act_kvseq == block_size``.

Properties the engine relies on:

- **Exactness.** For causal attention, K/V at position *i* depend only on
  tokens ``0..i``, so a stored block is valid KV for *any* prompt that
  shares the token prefix up to that block.  Segments are stored bits,
  never recomputed, so reuse is bit-identical to the original prefill.
- **Namespaces.** Trees are per-namespace (the gateway uses the project
  of the API key), so tenants can never be served KV derived from
  another tenant's prompts.
- **Ref-counting + LRU eviction.** Nodes on a path in use by an
  in-flight request are pinned (``refs > 0``); eviction takes unpinned
  leaves in least-recently-used order.  Capacity is accounted in a
  dedicated :class:`~repro.serving.kvcache.BlockLedger` (one ledger
  block per node), so admission-style pressure triggers eviction exactly
  like slot admission does.
- **Copy-on-write.** ``gather`` returns a concatenated segment that the
  scheduler ``dynamic_update_slice``-inserts into the dense per-slot
  cache; the slot owns its copy, so later eviction of tree nodes never
  invalidates running requests.

Only architectures whose cache leaves all carry an ``act_kvseq`` axis
(pure attention: GQA / MLA) support position-sliced KV segments; SSM /
hybrid / encoder-decoder / vision-prefixed models are detected by
:func:`supports_prefix_cache` and served without reuse.
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.serving.kvcache import BlockLedger, tree_multi, tree_walk

try:  # optional: the tree logic itself is testable without jax arrays
    import jax.numpy as jnp
except Exception:  # pragma: no cover - jax is a hard dep of the engine
    jnp = None


def concat_segments(segs: Sequence, axes):
    """Concatenate KV segments along each leaf's ``act_kvseq`` axis."""
    if len(segs) == 1:
        return segs[0]
    return tree_multi(
        lambda leaves, ax: jnp.concatenate(leaves, axis=ax.index("act_kvseq")),
        list(segs), axes)


def slice_segment(seg, axes, length: int):
    """Take the first ``length`` positions of a segment."""
    def one(arr, ax):
        i = ax.index("act_kvseq")
        if arr.shape[i] <= length:
            return arr
        idx = [slice(None)] * arr.ndim
        idx[i] = slice(0, length)
        return arr[tuple(idx)]
    return tree_walk(one, seg, axes)


def segment_length(seg, axes) -> int:
    """The ``act_kvseq`` extent of a segment (first leaf)."""
    out = []

    def one(arr, ax):
        out.append(arr.shape[ax.index("act_kvseq")])
        return arr
    tree_walk(one, seg, axes)
    return out[0]


def supports_prefix_cache(cfg) -> bool:
    """True iff every cache leaf is position-sliceable along the sequence.

    SSM / hybrid states have no per-position KV; encoder-decoder and
    vision-prefixed models key their cache on non-token inputs.  This is
    the same architecture class that can page its KV, so the single
    predicate lives in the model layer (one gate, no drift)."""
    from repro.models import model as M
    return M.supports_paged_cache(cfg)


# ------------------------------------------------------------------ the tree
class _Node:
    __slots__ = ("block", "seg", "children", "parent", "refs", "last_use",
                 "namespace", "node_id")

    def __init__(self, block: Tuple[int, ...], seg, parent: "_Node | None",
                 namespace: str, node_id: int):
        self.block = block
        self.seg = seg
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.parent = parent
        self.refs = 0
        self.last_use = 0
        self.namespace = namespace
        self.node_id = node_id

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"_Node(id={self.node_id}, refs={self.refs}, "
                f"children={len(self.children)})")


class Match:
    """Result of a longest-prefix lookup: the matched node path."""
    __slots__ = ("namespace", "nodes", "length")

    def __init__(self, namespace: str, nodes: List[_Node], length: int):
        self.namespace = namespace
        self.nodes = nodes
        self.length = length


class PrefixCache:
    """Block-chunked radix tree of reusable KV prefixes.

    ``axes`` is the model's cache-axes pytree (``M.cache_axes(cfg)``),
    used to locate the ``act_kvseq`` dimension of every leaf.  Capacity
    is ``capacity_tokens`` rounded down to whole blocks; accounting goes
    through a dedicated :class:`BlockLedger` so eviction behaves exactly
    like slot admission under memory pressure.
    """

    def __init__(self, axes, *, block_size: int = 16,
                 capacity_tokens: int = 4096):
        if block_size <= 0:
            raise ValueError("block_size must be positive")
        self.axes = axes
        self.block_size = block_size
        self.ledger = BlockLedger(capacity_tokens, block_size)
        self.roots: Dict[str, _Node] = {}
        self._clock = itertools.count(1)
        self._ids = itertools.count()
        # stats
        self.queries = 0
        self.hit_queries = 0
        self.hit_tokens = 0
        self.evicted_nodes = 0

    # ------------------------------------------------------------ helpers
    def _root(self, namespace: str) -> _Node:
        root = self.roots.get(namespace)
        if root is None:
            root = _Node((), None, None, namespace, next(self._ids))
            self.roots[namespace] = root
        return root

    def _blocks(self, tokens: Sequence[int]):
        bs = self.block_size
        for i in range(len(tokens) // bs):
            yield tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])

    @property
    def n_nodes(self) -> int:
        return len(self.ledger.used)

    @property
    def cached_tokens(self) -> int:
        return self.n_nodes * self.block_size

    # ------------------------------------------------------------ lookup
    def match(self, namespace: str, tokens: Sequence[int],
              peek: bool = False) -> Match:
        """Longest-prefix match in whole blocks.

        ``peek=True`` skips LRU/stat updates (used by affinity routing so
        probes don't pin recency).
        """
        root = self.roots.get(namespace)
        nodes: List[_Node] = []
        node = root
        if node is not None:
            for block in self._blocks(tokens):
                child = node.children.get(block)
                if child is None:
                    break
                nodes.append(child)
                node = child
        length = len(nodes) * self.block_size
        if not peek:
            self.queries += 1
            if nodes:
                self.hit_queries += 1
                self.hit_tokens += length
                tick = next(self._clock)
                for n in nodes:
                    n.last_use = tick
        return Match(namespace, nodes, length)

    def match_len(self, namespace: str, tokens: Sequence[int]) -> int:
        return self.match(namespace, tokens, peek=True).length

    def gather(self, match: Match, length: Optional[int] = None):
        """Concatenated KV segment for the first ``length`` matched tokens
        (copy-on-write: the caller inserts the result into its own slot)."""
        if not match.nodes:
            raise ValueError("gather on an empty match")
        length = match.length if length is None else length
        if not 0 < length <= match.length:
            raise ValueError(f"length {length} outside (0, {match.length}]")
        n_nodes = -(-length // self.block_size)
        seg = concat_segments([n.seg for n in match.nodes[:n_nodes]],
                              self.axes)
        return slice_segment(seg, self.axes, length)

    # ------------------------------------------------------------ pinning
    def lock(self, nodes: Sequence[_Node]):
        for n in nodes:
            n.refs += 1

    def unlock(self, nodes: Sequence[_Node]):
        for n in nodes:
            n.refs = max(0, n.refs - 1)

    # ------------------------------------------------------------ insert
    def insert(self, namespace: str, tokens: Sequence[int],
               extract: Callable[[int, int], Any]) -> List[_Node]:
        """Store the whole-block prefix of ``tokens``.

        ``extract(start, end)`` must return the KV segment for prompt
        positions ``[start, end)`` (the scheduler slices it out of the
        request's slot).  Existing nodes are deduplicated; only missing
        blocks are extracted.  Under ledger pressure, unpinned LRU leaves
        are evicted; if nothing is evictable the insert stops early
        (keeping the stored path a valid contiguous prefix).  Returns
        the newly created nodes, already pinned once for the caller.
        """
        node = self._root(namespace)
        created: List[_Node] = []
        tick = next(self._clock)
        # the path being extended must never be an eviction victim: evicting
        # the leaf we are about to hang a child off would orphan the child
        # (unreachable from the root) while it still holds a ledger block
        path_ids = {node.node_id}
        for i, block in enumerate(self._blocks(tokens)):
            child = node.children.get(block)
            if child is None:
                if (self.ledger.free_blocks < 1
                        and not self._evict_one(exclude=path_ids)):
                    break
                start = i * self.block_size
                seg = extract(start, start + self.block_size)
                child = _Node(block, seg, node, namespace, next(self._ids))
                child.refs = 1
                node.children[block] = child
                self.ledger.admit(f"pfx{child.node_id}", self.block_size)
                self._on_store(child)
                created.append(child)
            child.last_use = tick
            path_ids.add(child.node_id)
            node = child
        return created

    # ------------------------------------------------------ payload hooks
    def _on_store(self, node: _Node):
        """Called once per newly stored node (payload already in
        ``node.seg``).  The paged subclass ref-bumps the block pool."""

    def _release_payload(self, node: _Node):
        """Called when a node is evicted, before its payload is dropped.
        The paged subclass returns the physical block to the pool."""

    # ------------------------------------------------------------ eviction
    def _evictable(self, exclude=frozenset()) -> List[_Node]:
        out = []
        for root in self.roots.values():
            stack = list(root.children.values())
            while stack:
                n = stack.pop()
                if n.children:
                    stack.extend(n.children.values())
                elif n.refs == 0 and n.node_id not in exclude:
                    out.append(n)
        return out

    def _evict_one(self, exclude=frozenset()) -> bool:
        cands = self._evictable(exclude)
        if not cands:
            return False
        victim = min(cands, key=lambda n: n.last_use)
        victim.parent.children.pop(victim.block, None)
        self.ledger.release(f"pfx{victim.node_id}")
        self._release_payload(victim)
        victim.seg = None
        self.evicted_nodes += 1
        return True

    def evict(self, n_blocks: int) -> int:
        """Evict up to ``n_blocks`` unpinned LRU leaves; returns count."""
        done = 0
        while done < n_blocks and self._evict_one():
            done += 1
        return done

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, float]:
        return {
            "nodes": self.n_nodes,
            "cached_tokens": self.cached_tokens,
            "capacity_tokens": self.ledger.total_blocks * self.block_size,
            "queries": self.queries,
            "hit_queries": self.hit_queries,
            "hit_tokens": self.hit_tokens,
            "evicted_nodes": self.evicted_nodes,
        }

    def collect_metrics(self, reg) -> None:
        """Pull radix-tree hit/miss/eviction accounting into a metrics
        registry (absolute sets, safe on every snapshot)."""
        reg.counter("repro_prefix_queries_total",
                    "longest-prefix lookups").set(self.queries)
        reg.counter("repro_prefix_hits_total",
                    "lookups that matched at least one block").set(
            self.hit_queries)
        reg.counter("repro_prefix_misses_total",
                    "lookups that matched nothing").set(
            self.queries - self.hit_queries)
        reg.counter("repro_prefix_hit_tokens_total",
                    "prompt tokens served from the tree").set(
            self.hit_tokens)
        reg.counter("repro_prefix_evictions_total",
                    "nodes evicted under capacity pressure").set(
            self.evicted_nodes)
        reg.gauge("repro_prefix_cached_nodes",
                  "radix-tree nodes currently stored").set(self.n_nodes)
        reg.gauge("repro_prefix_cached_tokens",
                  "tokens' worth of KV indexed by the tree").set(
            self.cached_tokens)
        reg.gauge("repro_prefix_capacity_tokens",
                  "tree capacity in tokens").set(
            self.ledger.total_blocks * self.block_size)


# ------------------------------------------------------------------ paged
class PagedPrefixCache(PrefixCache):
    """Radix tree whose node payload is a *physical block id* into a
    shared :class:`~repro.serving.kvcache.BlockPool` — the zero-copy
    prefix cache of the paged KV path (README.md).

    Storing a prompt block is a refcount bump on the block the request
    already prefilled; serving a hit is a refcount bump + table splice
    into the new request's block table.  No KV tensor is copied in either
    direction (``gather`` is disabled to make that a hard guarantee).
    Evicting a node drops the tree's reference; the block returns to the
    pool only once no running request shares it.

    The ``extract`` callable passed to :meth:`PrefixCache.insert` must
    return the prompt's physical block id for positions ``[start, end)``
    (the scheduler reads it off the slot's block table).  Tree size is
    still budgeted through the base ledger so the cache cannot pin the
    whole pool; pool pressure additionally evicts on demand via
    ``evict``/``evictable_blocks``.
    """

    def __init__(self, pool, *, block_size: int = 16,
                 capacity_tokens: int = 4096):
        super().__init__(None, block_size=block_size,
                         capacity_tokens=capacity_tokens)
        self.pool = pool

    def _on_store(self, node):
        self.pool.incref([node.seg])

    def _release_payload(self, node):
        self.pool.decref([node.seg])

    def gather(self, match: Match, length: Optional[int] = None):
        raise RuntimeError(
            "PagedPrefixCache is zero-copy: splice block ids "
            "(gather_block_ids) instead of gathering KV segments")

    def gather_block_ids(self, match: Match, n_blocks: int) -> List[int]:
        """Physical block ids for the first ``n_blocks`` matched blocks."""
        if not 0 < n_blocks <= len(match.nodes):
            raise ValueError(f"n_blocks {n_blocks} outside "
                             f"(0, {len(match.nodes)}]")
        return [n.seg for n in match.nodes[:n_blocks]]

    def evictable_blocks(self) -> int:
        """How many pool blocks eviction could release right now (upper
        bound: a block shared with a running request frees nothing)."""
        return len(self._evictable())
