"""Serving-plane fault injection (paper §6: long-lived services on
batch-first HPC nodes).

The trainer proves its checkpoint/restore story against an injected
``failure_injector``; this module is the serving counterpart.  A
deterministic, seeded :class:`FaultInjector` fires :class:`FaultSpec`
faults at three engine points —

- ``admission``  — checked in :meth:`InferenceEngine.submit`,
- ``micro_step`` — checked at the top of every fused decode micro-step,
- ``emission``   — checked before every token is appended to a request,

and each fault is one of three kinds:

- ``crash``  — the engine "process" dies: :meth:`InferenceEngine.crash`
  evacuates every in-flight request (committed tokens folded into the
  prompt via the scheduler's preemption path, so a resubmission is
  token-exact at temperature 0), drops the now-lost KV pool contents,
  and the engine reports ``health() == "down"`` until
  :meth:`InferenceEngine.recover`;
- ``hang``   — injected latency: the virtual clock advances by
  ``hang_s`` (no real sleep anywhere), which is what deadline
  enforcement sees;
- ``reject`` — a transient refusal (queue-full / admission-pressure
  shape) that raises :class:`EngineFailure` without taking the engine
  down.

Everything is reproducible: ``at_call`` faults fire on the Nth check of
their point, and probabilistic faults draw from a seeded
``numpy`` generator in a fixed order, so a chaos run replays exactly in
tests and benchmarks.  :class:`VirtualClock` and :class:`Backoff` (full
jitter) are shared by the gateway's retry path and the tests so no real
``time.sleep`` is ever needed.  See docs/robustness.md.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

POINTS = ("admission", "micro_step", "emission")
KINDS = ("crash", "hang", "reject")


class EngineFailure(RuntimeError):
    """An inference engine crashed, refused, or is unavailable.

    ``point`` names where it fired (one of :data:`POINTS`, or
    ``"submit"`` for down/draining engines); ``kind`` is one of
    :data:`KINDS` plus ``"down"``/``"draining"``/``"timeout"``."""

    def __init__(self, msg: str, point: str = "", kind: str = "crash"):
        super().__init__(msg)
        self.point = point
        self.kind = kind


class EngineTimeout(EngineFailure):
    """``run_until_idle(deadline=...)`` ran out of wall budget; the
    in-flight requests were evacuated and ride on ``.requests``."""

    def __init__(self, msg: str, requests: Optional[list] = None):
        super().__init__(msg, point="run", kind="timeout")
        self.requests = requests or []


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault.  ``at_call`` fires on the Nth check of
    ``point`` (1-based, deterministic); ``prob`` fires per-check from
    the injector's seeded rng.  ``times`` bounds total firings
    (``<= 0`` = unlimited).  ``hang_s`` is the injected latency for
    ``kind == "hang"``."""
    point: str
    kind: str = "crash"
    at_call: Optional[int] = None
    prob: float = 0.0
    hang_s: float = 0.0
    times: int = 1

    def __post_init__(self):
        if self.point not in POINTS:
            raise ValueError(f"fault point {self.point!r} not in {POINTS}")
        if self.kind not in KINDS:
            raise ValueError(f"fault kind {self.kind!r} not in {KINDS}")
        if self.at_call is None and self.prob <= 0.0:
            raise ValueError("fault needs at_call or prob > 0")


def parse_fault_spec(text: str) -> FaultSpec:
    """CLI shorthand ``kind@point[:at_call[:hang_s]]`` — e.g.
    ``crash@micro_step:40`` or ``hang@micro_step:5:0.25``."""
    kind, _, rest = text.partition("@")
    parts = rest.split(":")
    point = parts[0]
    at_call = int(parts[1]) if len(parts) > 1 else 1
    hang_s = float(parts[2]) if len(parts) > 2 else 0.0
    return FaultSpec(point=point, kind=kind, at_call=at_call,
                     hang_s=hang_s)


class FaultInjector:
    """Deterministic fault schedule over the engine's check points.

    The engine calls :meth:`check` at every fault point; the injector
    keeps its own per-point call counters, so schedules are independent
    of engine internals and replay exactly.  ``clock_advance`` (e.g.
    :meth:`VirtualClock.advance`) realises ``hang`` faults without a
    real sleep.  ``fired`` logs ``(point, kind, call#)`` for test
    assertions."""

    def __init__(self, specs: Sequence[FaultSpec], *, seed: int = 0,
                 clock_advance: Optional[Callable[[float], None]] = None):
        self.specs = list(specs)
        self.rng = np.random.default_rng(seed)
        self.clock_advance = clock_advance
        self.calls = {p: 0 for p in POINTS}
        self._left = [s.times for s in self.specs]
        self.fired: List[Tuple[str, str, int]] = []

    def check(self, point: str) -> Optional[FaultSpec]:
        """Count one check of ``point``; return the fault to realise
        (or None).  Probabilistic specs draw rng in spec order, so the
        schedule is a pure function of (specs, seed, call sequence)."""
        self.calls[point] += 1
        n = self.calls[point]
        for i, s in enumerate(self.specs):
            if s.point != point or self._left[i] == 0:
                continue
            if s.at_call is not None:
                hit = s.at_call == n
            else:
                hit = float(self.rng.random()) < s.prob
            if hit:
                if self._left[i] > 0:
                    self._left[i] -= 1
                self.fired.append((point, s.kind, n))
                return s
        return None


class VirtualClock:
    """Injectable monotonic clock: ``now()``/``__call__`` read it,
    ``advance``/``sleep`` move it.  The whole retry/backoff/deadline
    story runs against this in tests — zero real sleeps."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def now(self) -> float:
        return self.t

    def advance(self, dt: float):
        self.t += float(dt)

    def sleep(self, dt: float):
        self.advance(dt)


class Backoff:
    """Exponential backoff with *full jitter*: attempt ``a`` sleeps
    ``uniform(0, min(cap, base * 2**a))``.  Seeded, so a retry schedule
    is reproducible; jitter decorrelates replicas hammering a recovering
    engine (the thundering-herd fix)."""

    def __init__(self, base_s: float = 0.05, cap_s: float = 2.0,
                 seed: int = 0):
        self.base_s = base_s
        self.cap_s = cap_s
        self.rng = np.random.default_rng(seed)

    def delay(self, attempt: int) -> float:
        hi = min(self.cap_s, self.base_s * (2.0 ** attempt))
        return float(self.rng.uniform(0.0, hi))


class ChaosEngine:
    """Bind a :class:`FaultInjector` to an engine and proxy everything
    else through, so the gateway (or any caller) serves a chaos replica
    with no code changes.  ``auto_recover_probes`` models MTTR in
    health-probe units: after a crash, the Nth ``health()`` probe
    triggers :meth:`~repro.serving.engine.InferenceEngine.recover` —
    which is exactly how a gateway retry loop re-discovers a restarted
    replica."""

    def __init__(self, engine, injector: FaultInjector, *,
                 auto_recover_probes: int = 0):
        self.engine = engine
        self.injector = injector
        self.auto_recover_probes = auto_recover_probes
        self._probes_down = 0
        engine.faults = injector

    def __getattr__(self, name):
        return getattr(self.engine, name)

    def health(self) -> str:
        st = self.engine.health()
        if st == "down" and self.auto_recover_probes > 0:
            self._probes_down += 1
            if self._probes_down >= self.auto_recover_probes:
                self.engine.recover()
                self._probes_down = 0
                return self.engine.health()
        return st
