"""Draft-token sources for speculative decoding.

The engine breaks the one-token-per-launch decode bound by *drafting* up
to k candidate continuations per running sequence, scoring all of them
in one multi-token target launch (``M.verify_step`` /
``M.verify_step_paged``), and keeping the longest accepted prefix via
distribution-preserving rejection sampling
(``sampling.spec_accept_batched``).  Two draft sources sit behind one
interface:

- :class:`NGramDrafter` — prompt-lookup decoding: candidate
  continuations are read out of the request's *own* prompt + generated
  tokens (the longest suffix n-gram that occurred earlier predicts the
  tokens that followed it).  No extra model, no extra launches — free
  wins on code/RAG/summarisation workloads where outputs quote inputs.
- :class:`DraftModelDrafter` — a small compatible model (same
  tokenizer/vocab, e.g. qwen1_5_4b drafting for qwen2_5_32b) runs its
  own KV cache per slot and autoregressively proposes k tokens; its
  per-token distributions are reported as the rejection-sampling
  ``q`` so acceptance stays exact for any temperature.

A drafter proposes *per slot*; its state must be dropped when the slot
turns over (finish/preempt) via :meth:`release` — the scheduler calls it
wherever the slot's adapter pin is released.

Correctness contract: drafts are suggestions only.  The accept/reject
step guarantees the emitted-token distribution equals the target
model's (greedy outputs are token-identical to the non-speculative
engine), so a bad drafter can only cost speed, never change tokens.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.scheduler import _bucket


class Drafter:
    """Interface: ``propose`` returns up to ``k`` draft tokens for one
    slot plus the (n, vocab_padded) distribution each was sampled from
    — or ``None`` when ``deterministic`` is set, in which case the
    accept/reject jit builds the one-hot ``q`` from the token ids
    itself (no dense (B,k,V) host buffer on the decode hot path)."""

    name = "none"
    deterministic = False

    def propose(self, slot: int, context: Sequence[int], k: int,
                temperature: float) -> Tuple[List[int],
                                             Optional[np.ndarray]]:
        raise NotImplementedError

    def release(self, slot: int) -> None:
        """Forget per-slot state (slot finished / preempted)."""


class NGramDrafter(Drafter):
    """Prompt-lookup drafter (deterministic, model-free).

    Finds the longest suffix n-gram (``min_n <= n <= max_n``) of the
    context that also occurs earlier in the context, preferring the most
    recent earlier occurrence, and proposes the tokens that followed it.
    The scan is O(len * max_n) per call — fine at serving prompt sizes,
    and stateless so preemption/slot-turnover needs no bookkeeping.
    """

    name = "ngram"
    deterministic = True

    def __init__(self, vocab_padded: int, max_n: int = 3, min_n: int = 1):
        self.vocab = vocab_padded
        self.max_n = max_n
        self.min_n = min_n

    def propose(self, slot, context, k, temperature):
        ctx = list(context)
        drafts: List[int] = []
        for n in range(min(self.max_n, len(ctx) - 1), self.min_n - 1, -1):
            pat = tuple(ctx[-n:])
            for i in range(len(ctx) - n - 1, -1, -1):
                if tuple(ctx[i:i + n]) == pat:
                    drafts = ctx[i + n:i + n + k]
                    break
            if drafts:
                break
        return drafts, None  # deterministic: q is one-hot, built in-jit


class DraftModelDrafter(Drafter):
    """Small-model drafter with a per-slot dense KV cache.

    The draft model replays exactly the tokens the target has committed:
    per ``propose`` it (a) catches its cache up on the tokens emitted
    since the last round — one multi-token :func:`~repro.models.model.
    verify_step` launch over the delta (at most k+1 tokens) — then (b)
    autoregressively decodes ``k`` draft tokens, recording the
    distribution each was sampled from.  Draft-token KV written past the
    committed context is *not* rolled back: K/V at a position depend
    only on that position's token, so the next round's delta overwrites
    accepted positions with identical values and rejected positions
    with the corrected token's values.
    """

    name = "draft"

    def __init__(self, cfg, params, capacity: int, seed: int = 0):
        from repro.models import model as M
        self.cfg, self.params = cfg, params
        self.capacity = capacity
        self._M = M
        self._state: Dict[int, Dict] = {}
        self._rng = np.random.default_rng(seed)
        self._prefill = jax.jit(lambda p, b: M.prefill(cfg, p, b))
        self._verify = jax.jit(
            lambda p, t, c, l: M.verify_step(cfg, p, t, c, l))
        self._decode = jax.jit(
            lambda p, t, c, l: M.decode_step(cfg, p, t, c, l))

    def _sync(self, slot: int, context: Sequence[int]):
        """Write KV for every context token not yet in the slot's draft
        cache; returns next-token logits (1, V) at the context end."""
        M = self._M
        st = self._state.get(slot)
        n = len(context)
        if st is None or st["n"] >= n:
            # fresh slot (or an impossible shrink — be safe): prefill
            pad = _bucket(n)
            toks = np.zeros((1, pad), np.int32)
            toks[0, :n] = context
            batch = {"tokens": jnp.asarray(toks),
                     "prompt_lengths": jnp.asarray([n], jnp.int32)}
            logits, cache, _ = self._prefill(self.params, batch)
            cache = jax.tree.map(lambda a: a.astype(jnp.bfloat16),
                                 M.pad_cache(self.cfg, cache, self.capacity))
            st = {"cache": cache, "n": n}
            self._state[slot] = st
            return logits, st
        delta = list(context[st["n"]:])
        lg, st["cache"] = self._verify(
            self.params, jnp.asarray([delta], jnp.int32), st["cache"],
            jnp.asarray([n], jnp.int32))
        st["n"] = n
        return lg[:, -1], st

    def propose(self, slot, context, k, temperature):
        logits, st = self._sync(slot, context)
        Vp = logits.shape[-1]
        drafts: List[int] = []
        probs: List[np.ndarray] = []
        cache, ln, l = st["cache"], st["n"], logits
        for _ in range(k):
            lv = np.asarray(l[0], np.float32)
            if temperature <= 0.0:
                tok = int(np.argmax(lv))
                pr = np.zeros((Vp,), np.float32)
                pr[tok] = 1.0
            else:
                x = lv / temperature
                x -= x.max()
                e = np.exp(x)
                pr = (e / e.sum()).astype(np.float32)
                tok = int(self._rng.choice(Vp, p=pr / pr.sum()))
            drafts.append(tok)
            probs.append(pr)
            ln += 1
            l, cache = self._decode(self.params,
                                    jnp.asarray([[tok]], jnp.int32), cache,
                                    jnp.asarray([ln], jnp.int32))
        st["cache"] = cache  # tail holds draft KV; next delta overwrites
        return drafts, (np.stack(probs) if probs
                        else np.zeros((0, Vp), np.float32))

    def release(self, slot):
        self._state.pop(slot, None)


def make_drafter(kind: Optional[str], cfg, *, spec_k: int, capacity: int,
                 draft_cfg=None, draft_params=None) -> Optional[Drafter]:
    """Engine-facing factory.  ``kind``: None | "ngram" | "draft"."""
    if not kind:
        return None
    if kind == "ngram":
        return NGramDrafter(cfg.vocab_padded)
    if kind == "draft":
        if draft_cfg is None or draft_params is None:
            raise ValueError("speculative='draft' needs draft_cfg and "
                             "draft_params")
        if draft_cfg.vocab_size != cfg.vocab_size:
            raise ValueError(
                f"draft model vocab ({draft_cfg.vocab_size}) must match "
                f"the target's ({cfg.vocab_size})")
        # the draft cache must hold context + k draft tokens
        return DraftModelDrafter(draft_cfg, draft_params,
                                 capacity=capacity + spec_k + 1)
    raise ValueError(f"unknown speculative drafter {kind!r} "
                     "(expected 'ngram' or 'draft')")
