"""Inference gateway + governance (paper §4.4): the LiteLLM/Waldur layer.

- API keys are minted per project with budgets, rate limits, and model
  ACLs (Waldur's role).
- The gateway routes to the least-loaded healthy replica of the model's
  deployment (LiteLLM's role), meters per-key token usage and cost, and
  rejects over-budget / over-rate / unauthorized calls.
- Model onboarding is declarative and passes a vetting step that checks
  the projected footprint and reserves failover capacity for hot models.
- ``model@adapter`` names route to a replica whose LoRA adapter pool
  holds the tenant's adapter (multi-LoRA serving: many fine-tunes share
  one deployment's weights); usage is metered per adapter as well as per
  project.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.serving.adapters import adapter_namespace
from repro.serving.engine import InferenceEngine, Request


class GatewayError(RuntimeError):
    pass


class RateLimited(GatewayError):
    pass


class OverBudget(GatewayError):
    pass


class Unauthorized(GatewayError):
    pass


@dataclasses.dataclass
class ApiKey:
    key: str
    project: str
    budget_usd: float = 10.0
    rate_limit_per_min: int = 600
    allowed_models: Optional[List[str]] = None  # None = all
    spent_usd: float = 0.0


@dataclasses.dataclass
class ModelEntry:
    name: str
    arch: str
    usd_per_1k_prompt: float
    usd_per_1k_completion: float
    hot: bool = False                     # requires reserved failover capacity
    deployment: str = ""
    vetted: bool = False
    footprint_gb: float = 0.0


class Gateway:
    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 obs=None):
        self.clock = clock
        self.obs = obs
        self.keys: Dict[str, ApiKey] = {}
        self.models: Dict[str, ModelEntry] = {}
        self.endpoints: Dict[str, List[InferenceEngine]] = {}
        self._windows: Dict[str, deque] = {}
        # adapter -> owning project.  An owned adapter is a tenant's
        # private fine-tune: only that project's keys may serve it.
        # Unowned adapters stay open (shared/demo adapters).
        self.adapter_owners: Dict[str, str] = {}
        self.usage_log: List[Dict[str, Any]] = []
        self._ids = itertools.count(1)
        if obs is not None:
            self._c_rejected = obs.registry.counter(
                "repro_gateway_rejected_requests_total",
                "calls rejected at the gateway, by governance check",
                labelnames=("kind",))

    # ----------------------------------------------------------- admin
    def mint_key(self, project: str, **kw) -> ApiKey:
        k = ApiKey(key=f"sk-{project}-{next(self._ids):06d}",
                   project=project, **kw)
        self.keys[k.key] = k
        self._windows[k.key] = deque()
        return k

    def vet_model(self, entry: ModelEntry, cfg: ModelConfig,
                  reserved_failover_gb: float = 0.0) -> ModelEntry:
        """Onboarding vetting (§4.4): compute footprint & cost basis; hot
        models must have failover capacity reserved."""
        entry.footprint_gb = cfg.param_count() * 2 / 1e9  # bf16 weights
        if entry.hot and reserved_failover_gb < entry.footprint_gb:
            raise GatewayError(
                f"hot model {entry.name} needs >= {entry.footprint_gb:.1f}"
                f" GB reserved at the secondary site")
        entry.vetted = True
        self.models[entry.name] = entry
        return entry

    def bind_endpoints(self, model: str, engines: List[InferenceEngine]):
        self.endpoints[model] = list(engines)

    def own_adapter(self, adapter: str, project: str):
        """Record ``project`` as the owner of ``adapter``: a fine-tune
        can regurgitate its training data, so an owned adapter is only
        servable by its owner's keys (base-model ACLs are not enough)."""
        self.adapter_owners[adapter] = project

    # ----------------------------------------------------------- checks
    @staticmethod
    def split_model(name: str) -> Tuple[str, str]:
        """``"qwen@tenant-a"`` -> ``("qwen", "tenant-a")``; plain names
        are the base model.  ACLs/vetting apply to the base model — an
        adapter is a tenant artifact *within* a deployment, not a
        separately onboarded model."""
        base, _, adapter = name.partition("@")
        return base, adapter

    def _check(self, key: str, model: str) -> ApiKey:
        if key not in self.keys:
            raise Unauthorized("unknown api key")
        k = self.keys[key]
        if model not in self.models or not self.models[model].vetted:
            raise Unauthorized(f"model {model} not onboarded")
        if k.allowed_models is not None and model not in k.allowed_models:
            raise Unauthorized(f"key not allowed on {model}")
        if k.spent_usd >= k.budget_usd:
            raise OverBudget(f"budget exhausted ({k.spent_usd:.4f} USD)")
        now = self.clock()
        w = self._windows[key]
        while w and now - w[0] > 60.0:
            w.popleft()
        if len(w) >= k.rate_limit_per_min:
            raise RateLimited("rate limit exceeded")
        w.append(now)
        return k

    def _pick(self, model: str, prompt: Optional[List[int]] = None,
              namespace: str = "", adapter: str = "") -> InferenceEngine:
        """Least-loaded healthy replica, with prefix affinity: when a
        prompt is given, prefer the replica whose radix tree holds the
        longest matching prefix (ties fall back to load).  With an
        ``adapter``, only replicas whose pool has it registered are
        eligible; among those, replicas where it is already
        device-resident (no load on admit) win ties."""
        engines = [e for e in self.endpoints.get(model, []) if e.healthy]
        if not engines:
            raise GatewayError(f"no healthy endpoint for {model}")
        if adapter:
            engines = [e for e in engines if e.adapters is not None
                       and e.adapters.has(adapter)]
            if not engines:
                # same message as the ownership check: a tenant must not
                # be able to distinguish "exists but private" from
                # "doesn't exist" (adapter-enumeration oracle)
                raise Unauthorized(f"adapter {adapter!r} not available")
            resident = lambda e: int(adapter in e.adapters.resident)  # noqa: E731
        else:
            resident = lambda e: 0  # noqa: E731
        if prompt:
            return max(engines,
                       key=lambda e: (e.prefix_match_len(namespace, prompt),
                                      resident(e), -e.num_active))
        return max(engines, key=lambda e: (resident(e), -e.num_active))

    # ----------------------------------------------------------- serve
    def completion(self, *, api_key: str, model: str, prompt: List[int],
                   max_tokens: int = 16, temperature: float = 0.0,
                   run: bool = True) -> Dict[str, Any]:
        """``model`` may be ``"name"`` (base) or ``"name@adapter"`` (the
        tenant's LoRA fine-tune served from the same weights)."""
        base, adapter = self.split_model(model)
        try:
            k = self._check(api_key, base)
            owner = self.adapter_owners.get(adapter) if adapter else None
            if owner is not None and owner != k.project:
                # deliberately identical to the not-registered error: do
                # not confirm existence or leak the owning project
                raise Unauthorized(f"adapter {adapter!r} not available")
        except GatewayError as e:
            if self.obs is not None:
                self._c_rejected.labels(kind=type(e).__name__).inc()
                self.obs.tracer.instant(
                    "gateway", "reject", cat="gateway",
                    kind=type(e).__name__, model=model)
            raise
        # the prefix-cache namespace is the key's project (extended by
        # the adapter id for adapter'd calls): tenants never reuse (or
        # even observe timing of) another tenant's — or another
        # adapter's — cached KV
        ns = adapter_namespace(k.project, adapter)
        try:
            eng = self._pick(base, prompt=list(prompt), namespace=ns,
                             adapter=adapter)
        except GatewayError as e:
            if self.obs is not None:
                self._c_rejected.labels(kind=type(e).__name__).inc()
                self.obs.tracer.instant(
                    "gateway", "reject", cat="gateway",
                    kind=type(e).__name__, model=model)
            raise
        req = Request(prompt=list(prompt), max_new_tokens=max_tokens,
                      temperature=temperature, namespace=k.project,
                      adapter=adapter)
        rid = eng.submit(req)
        if run:
            eng.run_until_idle()
        me = self.models[base]
        cost = (len(prompt) * me.usd_per_1k_prompt
                + len(req.generated) * me.usd_per_1k_completion) / 1000.0
        k.spent_usd += cost
        rec = {"request_id": rid, "project": k.project, "model": base,
               "adapter": adapter,
               "prompt_tokens": len(prompt),
               "completion_tokens": len(req.generated),
               "cost_usd": cost, "engine": eng.name}
        self.usage_log.append(rec)
        return {"id": rid, "tokens": req.generated, "usage": rec}

    # ----------------------------------------------------------- obs
    def collect_metrics(self, registry=None):
        """Pull-style export of the usage ledger into a metrics registry
        (labels: project, model, adapter).  Counters are set to the
        ledger's absolute totals — the ledger is the source of truth, so
        re-collecting is idempotent.  Also walks bound engines so one
        gateway snapshot carries the whole serving stack."""
        reg = registry
        if reg is None:
            if self.obs is None:
                raise ValueError("no registry: pass one or attach obs")
            reg = self.obs.registry
        c_req = reg.counter(
            "repro_gateway_requests_total",
            "completed gateway calls",
            labelnames=("project", "model", "adapter"))
        c_ptok = reg.counter(
            "repro_gateway_prompt_tokens_total",
            "prompt tokens metered at the gateway",
            labelnames=("project", "model", "adapter"))
        c_ctok = reg.counter(
            "repro_gateway_completion_tokens_total",
            "completion tokens metered at the gateway",
            labelnames=("project", "model", "adapter"))
        c_usd = reg.counter(
            "repro_gateway_spend_usd_total",
            "metered spend in USD",
            labelnames=("project", "model", "adapter"))
        agg: Dict[Tuple[str, str, str], Dict[str, float]] = {}
        for rec in self.usage_log:
            key = (rec["project"], rec["model"], rec.get("adapter") or "")
            d = agg.setdefault(key, {"n": 0, "pt": 0, "ct": 0, "usd": 0.0})
            d["n"] += 1
            d["pt"] += rec["prompt_tokens"]
            d["ct"] += rec["completion_tokens"]
            d["usd"] += rec["cost_usd"]
        for (proj, model, adapter), d in agg.items():
            lb = dict(project=proj, model=model, adapter=adapter)
            c_req.labels(**lb).set(d["n"])
            c_ptok.labels(**lb).set(d["pt"])
            c_ctok.labels(**lb).set(d["ct"])
            c_usd.labels(**lb).set(d["usd"])
        reg.gauge("repro_gateway_keys_count",
                  "API keys minted").set(len(self.keys))
        reg.gauge("repro_gateway_models_count",
                  "models onboarded").set(len(self.models))
        seen = set()
        for engines in self.endpoints.values():
            for eng in engines:
                if id(eng) not in seen and hasattr(eng, "collect_metrics"):
                    seen.add(id(eng))
                    eng.collect_metrics(reg)
        return reg

    # ----------------------------------------------------------- reports
    def _aggregate(self, key_fn) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for rec in self.usage_log:
            d = out.setdefault(key_fn(rec),
                               {"requests": 0, "prompt_tokens": 0,
                                "completion_tokens": 0, "cost_usd": 0.0})
            d["requests"] += 1
            d["prompt_tokens"] += rec["prompt_tokens"]
            d["completion_tokens"] += rec["completion_tokens"]
            d["cost_usd"] += rec["cost_usd"]
        return out

    def usage_by_project(self) -> Dict[str, Dict[str, float]]:
        return self._aggregate(lambda rec: rec["project"])

    def usage_by_adapter(self) -> Dict[str, Dict[str, float]]:
        """Per-served-variant accounting: key is ``model`` for base calls
        and ``model@adapter`` for adapter'd calls — the billing view of
        multi-LoRA serving (one deployment, many tenants' fine-tunes)."""
        return self._aggregate(
            lambda rec: rec["model"] + (f"@{rec['adapter']}"
                                        if rec.get("adapter") else ""))
