"""Inference gateway + governance (paper §4.4): the LiteLLM/Waldur layer.

- API keys are minted per project with budgets, rate limits, and model
  ACLs (Waldur's role).
- The gateway routes to the least-loaded healthy replica of the model's
  deployment (LiteLLM's role), meters per-key token usage and cost, and
  rejects over-budget / over-rate / unauthorized calls.
- Model onboarding is declarative and passes a vetting step that checks
  the projected footprint and reserves failover capacity for hot models.
- ``model@adapter`` names route to a replica whose LoRA adapter pool
  holds the tenant's adapter (multi-LoRA serving: many fine-tunes share
  one deployment's weights); usage is metered per adapter as well as per
  project.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.serving.adapters import adapter_namespace
from repro.serving.engine import InferenceEngine, Request
from repro.serving.faults import Backoff, EngineFailure, EngineTimeout


class GatewayError(RuntimeError):
    pass


class RateLimited(GatewayError):
    pass


class OverBudget(GatewayError):
    pass


class Unauthorized(GatewayError):
    pass


class NoHealthyEndpoint(GatewayError):
    """Every replica of the model is down or draining."""


class Overloaded(GatewayError):
    """Load shed: every eligible replica has an open breaker or a queue
    past ``max_queue_depth`` — reject fast instead of hanging."""


class DeadlineExceeded(GatewayError):
    """The request's deadline passed (in backoff or mid-decode; any
    in-flight work was evacuated token-exactly)."""


class UpstreamFailure(GatewayError):
    """Retry budget exhausted on engine failures; the last upstream
    error is the ``__cause__``."""


class CircuitBreaker:
    """Per-engine circuit breaker (closed → open → half-open → closed).

    ``record_failure`` opens the circuit after ``threshold`` consecutive
    failures (immediately when half-open); ``allow`` refuses while open
    and lets ONE probe through after ``cooldown_s``; ``record_success``
    closes it.  Clock is injected, so tests and the chaos benchmark run
    the whole state machine on virtual time."""

    def __init__(self, clock: Callable[[], float], threshold: int = 3,
                 cooldown_s: float = 30.0,
                 on_transition: Optional[Callable[[str], None]] = None):
        self.clock = clock
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.on_transition = on_transition
        self.state = "closed"
        self.failures = 0
        self.opened_at = 0.0

    def _to(self, state: str):
        if state != self.state:
            self.state = state
            if self.on_transition is not None:
                self.on_transition(state)

    def allow(self) -> bool:
        """May a request be routed here now?  Open circuits refuse
        until the cooldown elapses, then admit a single half-open
        probe."""
        if self.state == "open":
            if self.clock() - self.opened_at >= self.cooldown_s:
                self._to("half_open")
                return True
            return False
        return True

    def record_success(self):
        self.failures = 0
        self._to("closed")

    def record_failure(self):
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.threshold:
            self.opened_at = self.clock()
            self._to("open")


@dataclasses.dataclass
class ApiKey:
    key: str
    project: str
    budget_usd: float = 10.0
    rate_limit_per_min: int = 600
    allowed_models: Optional[List[str]] = None  # None = all
    spent_usd: float = 0.0


@dataclasses.dataclass
class ModelEntry:
    name: str
    arch: str
    usd_per_1k_prompt: float
    usd_per_1k_completion: float
    hot: bool = False                     # requires reserved failover capacity
    deployment: str = ""
    vetted: bool = False
    footprint_gb: float = 0.0


class DisaggRouter:
    """Prefill/decode pool pairing for one model (disaggregated serving).

    The paper's observation is that the two inference phases stress
    different resources — prefill is compute-bound and batch-friendly,
    decode is latency- and bandwidth-bound — so they belong on
    different pools.  This router owns one model's pair: a request
    prefills on a ``role="prefill"`` engine, the finished KV leaves as a
    host-side :class:`~repro.serving.kvcache.KVHandoff`, and a
    ``role="decode"`` engine imports it and streams every output token
    (token-identical to a unified engine at temperature 0).

    The gateway's resilience machinery applies *per phase*: each pool is
    picked through the same health/breaker/queue-depth gates
    (:meth:`Gateway._pick_from`), failures feed the failing engine's own
    breaker, and the retry loop resumes from the furthest durable state
    — a crash before export re-prefills, a crash mid-decode re-imports
    the SAME cached handoff (the request's committed tokens were folded
    at evacuation, so resumption is token-exact).  When a pool has no
    healthy engine and the gateway has unified endpoints bound for the
    model, :meth:`Gateway.completion` falls back to them.
    """

    def __init__(self, gateway: "Gateway", model: str,
                 prefill: List[InferenceEngine],
                 decode: List[InferenceEngine]):
        self.gw = gateway
        self.model = model
        self.prefill = list(prefill)
        self.decode = list(decode)
        self._h_handoff = None
        if gateway.obs is not None:
            self._h_handoff = gateway.obs.registry.histogram(
                "repro_serving_handoff_seconds",
                "prefill export to decode import latency")

    def _note_handoff(self, ho, src, dst):
        gw = self.gw
        if gw.obs is None:
            return
        self._h_handoff.observe(max(0.0, gw.clock() - ho.exported_at))
        gw.obs.tracer.instant(
            "gateway", "handoff", cat="gateway", rid=ho.request_id,
            src=src.name, dst=dst.name, tokens=ho.length,
            payload_bytes=ho.payload_bytes)

    # ------------------------------------------------------------ phases
    def _pop_pair(self, eng, req):
        """Pull this request's (req, handoff) pair off the prefill
        engine's outbox — matched by identity, the engine batches other
        requests' exports too."""
        for i, (r, h) in enumerate(eng.outbox):
            if r is req:
                del eng.outbox[i]
                return h
        return None

    def _prefill_phase(self, req, ns, adapter, deadline, deadline_s,
                       run):
        gw = self.gw
        eng = gw._pick_from(self.prefill, f"{self.model} prefill pool",
                            prompt=list(req.prompt), namespace=ns,
                            adapter=adapter)
        br = gw._breaker(eng)
        try:
            rid = eng.submit(req)
            if run:
                eng.run_until_idle(deadline=deadline)
        except EngineTimeout as e:
            de = DeadlineExceeded(
                f"deadline of {deadline_s}s exceeded on {eng.name} "
                f"(prefill)")
            raise de from e
        except EngineFailure as e:
            br.record_failure()
            uf = UpstreamFailure(f"{eng.name}: {e}")
            uf.__cause__ = e
            raise uf
        ho = self._pop_pair(eng, req)
        if ho is None and not req.done:
            # the request left the engine without an export and without
            # finishing (evacuated by a crash surfaced on another
            # request's drive): an upstream failure, so the retry loop
            # re-prefills token-exactly
            br.record_failure()
            uf = UpstreamFailure(
                f"{eng.name}: no handoff exported for {req.request_id}")
            raise uf
        br.record_success()
        return eng, rid, ho

    def _decode_phase(self, req, ho, ns, adapter, deadline, deadline_s,
                      run, src):
        gw = self.gw
        eng = gw._pick_from(self.decode, f"{self.model} decode pool",
                            prompt=list(req.prompt), namespace=ns,
                            adapter=adapter)
        br = gw._breaker(eng)
        try:
            eng.submit_handoff(req, ho)
            self._note_handoff(ho, src, eng)
            if run:
                eng.run_until_idle(deadline=deadline)
        except EngineTimeout as e:
            de = DeadlineExceeded(
                f"deadline of {deadline_s}s exceeded on {eng.name} "
                f"(decode)")
            raise de from e
        except EngineFailure as e:
            br.record_failure()
            uf = UpstreamFailure(f"{eng.name}: {e}")
            uf.__cause__ = e
            raise uf
        br.record_success()
        return eng

    # -------------------------------------------------------- completion
    def completion(self, k, base, adapter, ns, req, n_prompt, budget,
                   deadline, deadline_s, run, model):
        """Two-phase attempt loop with the gateway's retry semantics.
        The handoff payload is cached host-side across attempts: once
        prefill succeeded, only the decode phase is retried."""
        gw = self.gw
        if not run:
            raise GatewayError(
                "disaggregated serving drives both phases itself; "
                "run=False is only supported on unified endpoints")
        attempt = 0
        src, rid, ho = None, None, None
        while True:
            err: GatewayError
            try:
                if ho is None:
                    src, rid, ho = self._prefill_phase(
                        req, ns, adapter, deadline, deadline_s, run)
                    if ho is None:
                        # rejected at admission (can never fit / bad
                        # adapter): metered like the unified path
                        return gw._meter(k, base, adapter, req, rid,
                                         n_prompt, src)
                eng = self._decode_phase(req, ho, ns, adapter, deadline,
                                         deadline_s, run, src)
                return gw._meter(k, base, adapter, req, rid, n_prompt,
                                 eng)
            except Unauthorized:
                raise
            except DeadlineExceeded as de:
                gw._note_reject(de, model)
                raise
            except NoHealthyEndpoint as e:
                if gw.endpoints.get(base):
                    raise    # Gateway.completion falls back to unified
                err = e
            except GatewayError as e:
                err = e
            attempt += 1
            if attempt > budget:
                gw._note_reject(err, model)
                raise err
            delay = gw._backoff.delay(attempt - 1)
            if deadline is not None and gw.clock() + delay >= deadline:
                de = DeadlineExceeded(
                    f"deadline of {deadline_s}s exceeded after "
                    f"{attempt} attempt(s)")
                de.__cause__ = err
                gw._note_reject(de, model)
                raise de
            gw._note_retry(err, attempt, delay)
            gw._sleep(delay)

    # --------------------------------------------------------- pipelined
    def run_pipelined(self, requests: List[Request],
                      namespace: str = "",
                      max_steps: int = 100_000) -> List[List[int]]:
        """Batch driver used by benchmarks and load tests: submit every
        request to the prefill pool, then step both pools in lockstep,
        moving exported handoffs to the decode pool as they appear — so
        the prefill engines are already prefilling request N+1 while the
        decode engines stream request N's tokens.  Returns each
        request's generated tokens in submission order."""
        gw = self.gw
        for r in requests:
            eng = gw._pick_from(self.prefill,
                                f"{self.model} prefill pool",
                                prompt=list(r.prompt),
                                namespace=namespace, adapter=r.adapter)
            eng.submit(r)
        while max_steps:
            busy = False
            for e in self.prefill:
                if e.num_active:
                    e.step()
                    busy = True
                while e.outbox:
                    req, ho = e.outbox.popleft()
                    dst = gw._pick_from(self.decode,
                                        f"{self.model} decode pool",
                                        prompt=list(req.prompt),
                                        namespace=namespace,
                                        adapter=req.adapter)
                    dst.submit_handoff(req, ho)
                    self._note_handoff(ho, e, dst)
            for d in self.decode:
                if d.num_active:
                    d.step()
                    busy = True
            if not busy:
                break
            max_steps -= 1
        return [list(r.generated) for r in requests]


class Gateway:
    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 obs=None, *, retry_budget: int = 0,
                 deadline_s: Optional[float] = None,
                 backoff_base_s: float = 0.05, backoff_cap_s: float = 2.0,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 30.0,
                 max_queue_depth: Optional[int] = None,
                 seed: int = 0,
                 sleep: Optional[Callable[[float], None]] = None):
        """Resilience knobs (defaults preserve the old fail-fast
        behaviour): ``retry_budget`` bounds resubmissions after an
        engine failure (exponential backoff + full jitter between
        attempts, via ``sleep`` — inject a virtual clock's ``sleep`` in
        tests so no real time passes); ``deadline_s`` is the default
        per-request wall budget; ``breaker_*`` configure the per-engine
        circuit breaker consulted by ``_pick``; ``max_queue_depth``
        sheds load (typed :class:`Overloaded`) when every eligible
        replica's queue is deeper."""
        self.clock = clock
        self.obs = obs
        self.retry_budget = retry_budget
        self.deadline_s = deadline_s
        self.max_queue_depth = max_queue_depth
        self.breaker_threshold = breaker_threshold
        self.breaker_cooldown_s = breaker_cooldown_s
        self._backoff = Backoff(backoff_base_s, backoff_cap_s, seed=seed)
        self._sleep = sleep if sleep is not None else time.sleep
        self._breakers: Dict[int, CircuitBreaker] = {}
        self.keys: Dict[str, ApiKey] = {}
        self.models: Dict[str, ModelEntry] = {}
        self.endpoints: Dict[str, List[InferenceEngine]] = {}
        # model -> DisaggRouter (prefill/decode pool pair); consulted
        # before the unified endpoints, which stay the fallback
        self.routers: Dict[str, DisaggRouter] = {}
        self._windows: Dict[str, deque] = {}
        # adapter -> owning project.  An owned adapter is a tenant's
        # private fine-tune: only that project's keys may serve it.
        # Unowned adapters stay open (shared/demo adapters).
        self.adapter_owners: Dict[str, str] = {}
        self.usage_log: List[Dict[str, Any]] = []
        self._ids = itertools.count(1)
        if obs is not None:
            self._c_rejected = obs.registry.counter(
                "repro_gateway_rejected_requests_total",
                "calls rejected at the gateway, by governance check",
                labelnames=("kind",))
            self._c_retries = obs.registry.counter(
                "repro_serving_retries_total",
                "completion retries, by failure reason",
                labelnames=("reason",))
            self._c_breaker = obs.registry.counter(
                "repro_gateway_breaker_transitions_total",
                "circuit-breaker state transitions",
                labelnames=("engine", "state"))
            self._g_breaker = obs.registry.gauge(
                "repro_gateway_breaker_state",
                "per-engine breaker state (0 closed, 1 open, 2 "
                "half-open)",
                labelnames=("engine",))

    BREAKER_STATES = {"closed": 0, "open": 1, "half_open": 2}

    def _breaker(self, eng) -> CircuitBreaker:
        """Lazily create the engine's breaker (keyed by identity, so
        one engine bound under several models shares one circuit)."""
        br = self._breakers.get(id(eng))
        if br is None:
            name = getattr(eng, "name", f"engine-{len(self._breakers)}")
            on_transition = None
            if self.obs is not None:
                def on_transition(state, _name=name):
                    self._c_breaker.labels(engine=_name, state=state).inc()
                    self._g_breaker.labels(engine=_name).set(
                        self.BREAKER_STATES[state])
                    self.obs.tracer.instant(
                        "gateway", "breaker", cat="gateway",
                        engine=_name, state=state)
            br = CircuitBreaker(self.clock,
                                threshold=self.breaker_threshold,
                                cooldown_s=self.breaker_cooldown_s,
                                on_transition=on_transition)
            self._breakers[id(eng)] = br
        return br

    @staticmethod
    def _health(e) -> str:
        """Engine health, tolerating plain objects: fall back to the
        legacy ``healthy`` bool when there is no ``health()``."""
        fn = getattr(e, "health", None)
        if fn is not None:
            return fn()
        return "ok" if getattr(e, "healthy", True) else "down"

    # ----------------------------------------------------------- admin
    def mint_key(self, project: str, **kw) -> ApiKey:
        k = ApiKey(key=f"sk-{project}-{next(self._ids):06d}",
                   project=project, **kw)
        self.keys[k.key] = k
        self._windows[k.key] = deque()
        return k

    def vet_model(self, entry: ModelEntry, cfg: ModelConfig,
                  reserved_failover_gb: float = 0.0) -> ModelEntry:
        """Onboarding vetting (§4.4): compute footprint & cost basis; hot
        models must have failover capacity reserved."""
        entry.footprint_gb = cfg.param_count() * 2 / 1e9  # bf16 weights
        if entry.hot and reserved_failover_gb < entry.footprint_gb:
            raise GatewayError(
                f"hot model {entry.name} needs >= {entry.footprint_gb:.1f}"
                f" GB reserved at the secondary site")
        entry.vetted = True
        self.models[entry.name] = entry
        return entry

    def bind_endpoints(self, model: str, engines: List[InferenceEngine]):
        self.endpoints[model] = list(engines)

    def bind_disagg(self, model: str, prefill: List[InferenceEngine],
                    decode: List[InferenceEngine],
                    unified: Optional[List[InferenceEngine]] = None) \
            -> DisaggRouter:
        """Register a disaggregated prefill/decode pool pair for
        ``model``.  ``completion`` routes through the pair first;
        ``unified`` (or engines already bound via
        :meth:`bind_endpoints`) serve as the fallback when either pool
        has no healthy engine."""
        router = DisaggRouter(self, model, prefill, decode)
        self.routers[model] = router
        if unified is not None:
            self.bind_endpoints(model, unified)
        return router

    def own_adapter(self, adapter: str, project: str):
        """Record ``project`` as the owner of ``adapter``: a fine-tune
        can regurgitate its training data, so an owned adapter is only
        servable by its owner's keys (base-model ACLs are not enough)."""
        self.adapter_owners[adapter] = project

    # ----------------------------------------------------------- checks
    @staticmethod
    def split_model(name: str) -> Tuple[str, str]:
        """``"qwen@tenant-a"`` -> ``("qwen", "tenant-a")``; plain names
        are the base model.  ACLs/vetting apply to the base model — an
        adapter is a tenant artifact *within* a deployment, not a
        separately onboarded model."""
        base, _, adapter = name.partition("@")
        return base, adapter

    def _check(self, key: str, model: str) -> ApiKey:
        if key not in self.keys:
            raise Unauthorized("unknown api key")
        k = self.keys[key]
        if model not in self.models or not self.models[model].vetted:
            raise Unauthorized(f"model {model} not onboarded")
        if k.allowed_models is not None and model not in k.allowed_models:
            raise Unauthorized(f"key not allowed on {model}")
        if k.spent_usd >= k.budget_usd:
            raise OverBudget(f"budget exhausted ({k.spent_usd:.4f} USD)")
        now = self.clock()
        w = self._windows[key]
        while w and now - w[0] > 60.0:
            w.popleft()
        if len(w) >= k.rate_limit_per_min:
            raise RateLimited("rate limit exceeded")
        w.append(now)
        return k

    def _pick(self, model: str, prompt: Optional[List[int]] = None,
              namespace: str = "", adapter: str = "") -> InferenceEngine:
        """Least-loaded healthy replica among ``model``'s unified
        endpoints — see :meth:`_pick_from` for the gate order."""
        return self._pick_from(self.endpoints.get(model, []), model,
                               prompt=prompt, namespace=namespace,
                               adapter=adapter)

    def _pick_from(self, pool: List[InferenceEngine], what: str,
                   prompt: Optional[List[int]] = None,
                   namespace: str = "", adapter: str = "") \
            -> InferenceEngine:
        """Least-loaded healthy replica from ``pool``, with prefix
        affinity: when a prompt is given, prefer the replica whose radix
        tree holds the longest matching prefix (ties fall back to load).
        With an ``adapter``, only replicas whose pool has it registered
        are eligible; among those, replicas where it is already
        device-resident (no load on admit) win ties.

        Resilience gates, in order: replicas whose ``health()`` is not
        ``"ok"`` (down/draining) are skipped — :class:`NoHealthyEndpoint`
        when none remain; then each candidate's circuit breaker is
        consulted and (when ``max_queue_depth`` is set) its queue depth
        bounded — :class:`Overloaded` when that leaves nothing.  A
        half-open breaker wins routing outright: its single probe is how
        a recovered replica re-earns traffic."""
        model = what
        engines = [e for e in pool if self._health(e) == "ok"]
        if not engines:
            raise NoHealthyEndpoint(f"no healthy endpoint for {what}")
        if adapter:
            engines = [e for e in engines if e.adapters is not None
                       and e.adapters.has(adapter)]
            if not engines:
                # same message as the ownership check: a tenant must not
                # be able to distinguish "exists but private" from
                # "doesn't exist" (adapter-enumeration oracle)
                raise Unauthorized(f"adapter {adapter!r} not available")
            resident = lambda e: int(adapter in e.adapters.resident)  # noqa: E731
        else:
            resident = lambda e: 0  # noqa: E731
        engines = [e for e in engines if self._breaker(e).allow()]
        if self.max_queue_depth is not None:
            engines = [e for e in engines
                       if e.num_active < self.max_queue_depth]
        if not engines:
            raise Overloaded(f"all endpoints for {model} shedding load")
        for e in engines:
            if self._breakers[id(e)].state == "half_open":
                return e
        if prompt:
            return max(engines,
                       key=lambda e: (e.prefix_match_len(namespace, prompt),
                                      resident(e), -e.num_active))
        return max(engines, key=lambda e: (resident(e), -e.num_active))

    # ----------------------------------------------------------- serve
    def _note_reject(self, e: Exception, model: str):
        if self.obs is not None:
            self._c_rejected.labels(kind=type(e).__name__).inc()
            self.obs.tracer.instant(
                "gateway", "reject", cat="gateway",
                kind=type(e).__name__, model=model)

    def _note_retry(self, e: Exception, attempt: int, delay: float):
        if self.obs is not None:
            self._c_retries.labels(reason=type(e).__name__).inc()
            self.obs.tracer.instant(
                "gateway", "retry", cat="gateway",
                reason=type(e).__name__, attempt=attempt, delay_s=delay)

    def completion(self, *, api_key: str, model: str, prompt: List[int],
                   max_tokens: int = 16, temperature: float = 0.0,
                   run: bool = True, retries: Optional[int] = None,
                   deadline_s: Optional[float] = None) -> Dict[str, Any]:
        """``model`` may be ``"name"`` (base) or ``"name@adapter"`` (the
        tenant's LoRA fine-tune served from the same weights).

        One client call, at most ``1 + retries`` engine attempts
        (default: the gateway's ``retry_budget``), all within
        ``deadline_s`` of wall budget (default: the gateway's).  The
        SAME request object is resubmitted on retry — an engine crash
        folds its committed tokens into the prompt, so the retried
        request resumes exactly where the dead replica stopped
        (token-exact at temperature 0).  Failures feed the picked
        engine's breaker; a non-retryable or budget-exhausted failure
        surfaces as a typed :class:`GatewayError`
        (:class:`DeadlineExceeded` / :class:`NoHealthyEndpoint` /
        :class:`Overloaded` / :class:`UpstreamFailure`)."""
        base, adapter = self.split_model(model)
        try:
            k = self._check(api_key, base)
            owner = self.adapter_owners.get(adapter) if adapter else None
            if owner is not None and owner != k.project:
                # deliberately identical to the not-registered error: do
                # not confirm existence or leak the owning project
                raise Unauthorized(f"adapter {adapter!r} not available")
        except GatewayError as e:
            self._note_reject(e, model)
            raise
        # the prefix-cache namespace is the key's project (extended by
        # the adapter id for adapter'd calls): tenants never reuse (or
        # even observe timing of) another tenant's — or another
        # adapter's — cached KV
        ns = adapter_namespace(k.project, adapter)
        req = Request(prompt=list(prompt), max_new_tokens=max_tokens,
                      temperature=temperature, namespace=k.project,
                      adapter=adapter)
        n_prompt = len(prompt)
        budget = self.retry_budget if retries is None else retries
        if deadline_s is None:
            deadline_s = self.deadline_s
        deadline = (None if deadline_s is None
                    else self.clock() + deadline_s)
        router = self.routers.get(base)
        if router is not None:
            try:
                return router.completion(k, base, adapter, ns, req,
                                         n_prompt, budget, deadline,
                                         deadline_s, run, model)
            except NoHealthyEndpoint as e:
                if not self.endpoints.get(base):
                    self._note_reject(e, model)
                    raise
                # one pool is empty or entirely unhealthy: fall back to
                # the unified engines below (the request object already
                # carries any folded progress, so the resumption stays
                # token-exact)
                if self.obs is not None:
                    self.obs.tracer.instant(
                        "gateway", "disagg_fallback", cat="gateway",
                        model=model, reason=str(e))
        attempt = 0
        while True:
            err: GatewayError
            eng = None
            try:
                # req.prompt, not the original: retries carry the folded
                # tokens, and affinity should match the folded prefix
                eng = self._pick(base, prompt=list(req.prompt),
                                 namespace=ns, adapter=adapter)
            except Unauthorized as e:
                self._note_reject(e, model)
                raise
            except GatewayError as e:
                err = e
            if eng is not None:
                br = self._breaker(eng)
                try:
                    rid = eng.submit(req)
                    if run:
                        eng.run_until_idle(deadline=deadline)
                    br.record_success()
                    return self._meter(k, base, adapter, req, rid,
                                       n_prompt, eng)
                except EngineTimeout as e:
                    # client-side deadline, not an engine fault: the
                    # breaker is untouched and there is nothing to
                    # retry within
                    de = DeadlineExceeded(
                        f"deadline of {deadline_s}s exceeded on "
                        f"{eng.name}")
                    self._note_reject(de, model)
                    raise de from e
                except EngineFailure as e:
                    br.record_failure()
                    err = UpstreamFailure(f"{eng.name}: {e}")
                    err.__cause__ = e
            attempt += 1
            if attempt > budget:
                self._note_reject(err, model)
                raise err
            delay = self._backoff.delay(attempt - 1)
            if deadline is not None and self.clock() + delay >= deadline:
                de = DeadlineExceeded(
                    f"deadline of {deadline_s}s exceeded after "
                    f"{attempt} attempt(s)")
                de.__cause__ = err
                self._note_reject(de, model)
                raise de
            self._note_retry(err, attempt, delay)
            self._sleep(delay)

    def _meter(self, k: ApiKey, base: str, adapter: str, req: Request,
               rid: str, n_prompt: int, eng) -> Dict[str, Any]:
        me = self.models[base]
        cost = (n_prompt * me.usd_per_1k_prompt
                + len(req.generated) * me.usd_per_1k_completion) / 1000.0
        k.spent_usd += cost
        rec = {"request_id": rid, "project": k.project, "model": base,
               "adapter": adapter,
               "prompt_tokens": n_prompt,
               "completion_tokens": len(req.generated),
               "cost_usd": cost, "engine": eng.name}
        self.usage_log.append(rec)
        return {"id": rid, "tokens": req.generated, "usage": rec}

    # ----------------------------------------------------------- obs
    def collect_metrics(self, registry=None):
        """Pull-style export of the usage ledger into a metrics registry
        (labels: project, model, adapter).  Counters are set to the
        ledger's absolute totals — the ledger is the source of truth, so
        re-collecting is idempotent.  Also walks bound engines so one
        gateway snapshot carries the whole serving stack."""
        reg = registry
        if reg is None:
            if self.obs is None:
                raise ValueError("no registry: pass one or attach obs")
            reg = self.obs.registry
        c_req = reg.counter(
            "repro_gateway_requests_total",
            "completed gateway calls",
            labelnames=("project", "model", "adapter"))
        c_ptok = reg.counter(
            "repro_gateway_prompt_tokens_total",
            "prompt tokens metered at the gateway",
            labelnames=("project", "model", "adapter"))
        c_ctok = reg.counter(
            "repro_gateway_completion_tokens_total",
            "completion tokens metered at the gateway",
            labelnames=("project", "model", "adapter"))
        c_usd = reg.counter(
            "repro_gateway_spend_usd_total",
            "metered spend in USD",
            labelnames=("project", "model", "adapter"))
        agg: Dict[Tuple[str, str, str], Dict[str, float]] = {}
        for rec in self.usage_log:
            key = (rec["project"], rec["model"], rec.get("adapter") or "")
            d = agg.setdefault(key, {"n": 0, "pt": 0, "ct": 0, "usd": 0.0})
            d["n"] += 1
            d["pt"] += rec["prompt_tokens"]
            d["ct"] += rec["completion_tokens"]
            d["usd"] += rec["cost_usd"]
        for (proj, model, adapter), d in agg.items():
            lb = dict(project=proj, model=model, adapter=adapter)
            c_req.labels(**lb).set(d["n"])
            c_ptok.labels(**lb).set(d["pt"])
            c_ctok.labels(**lb).set(d["ct"])
            c_usd.labels(**lb).set(d["usd"])
        reg.gauge("repro_gateway_keys_count",
                  "API keys minted").set(len(self.keys))
        reg.gauge("repro_gateway_models_count",
                  "models onboarded").set(len(self.models))
        seen = set()
        pools = list(self.endpoints.values())
        for router in self.routers.values():
            pools.append(router.prefill)
            pools.append(router.decode)
        for engines in pools:
            for eng in engines:
                if id(eng) not in seen and hasattr(eng, "collect_metrics"):
                    seen.add(id(eng))
                    eng.collect_metrics(reg)
        return reg

    # ----------------------------------------------------------- reports
    def _aggregate(self, key_fn) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for rec in self.usage_log:
            d = out.setdefault(key_fn(rec),
                               {"requests": 0, "prompt_tokens": 0,
                                "completion_tokens": 0, "cost_usd": 0.0})
            d["requests"] += 1
            d["prompt_tokens"] += rec["prompt_tokens"]
            d["completion_tokens"] += rec["completion_tokens"]
            d["cost_usd"] += rec["cost_usd"]
        return out

    def usage_by_project(self) -> Dict[str, Dict[str, float]]:
        return self._aggregate(lambda rec: rec["project"])

    def usage_by_adapter(self) -> Dict[str, Dict[str, float]]:
        """Per-served-variant accounting: key is ``model`` for base calls
        and ``model@adapter`` for adapter'd calls — the billing view of
        multi-LoRA serving (one deployment, many tenants' fine-tunes)."""
        return self._aggregate(
            lambda rec: rec["model"] + (f"@{rec['adapter']}"
                                        if rec.get("adapter") else ""))
