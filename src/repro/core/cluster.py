"""Cluster model: heterogeneous node pools with diskless-HPC semantics.

Mirrors the paper's Alpernetes substrate (§4.1): *hpc* nodes (Alps Cray EX
— diskless, any node attachable to any plane, state lost on reboot) and
*commodity* nodes (VMs — persistent, host control planes and lightweight
services).  Planes (repro.core.planes) acquire nodes from here; the
elastic controller (§6.2) moves delta-pool nodes between planes.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional


class NodeKind(str, enum.Enum):
    HPC = "hpc"              # diskless Cray EX (GPU/TPU pod member)
    COMMODITY = "commodity"  # VM on virtualization stack


class NodeState(str, enum.Enum):
    FREE = "free"
    BATCH = "batch"          # attached to the batch plane (Slurm role)
    SERVICE = "service"      # attached to the service plane (K8s role)
    DOWN = "down"


@dataclasses.dataclass
class Node:
    name: str
    kind: NodeKind
    chips: int = 4
    memory_gb: int = 96
    labels: Dict[str, str] = dataclasses.field(default_factory=dict)
    state: NodeState = NodeState.FREE
    # diskless semantics: everything here is lost on reboot/failure
    ephemeral: Dict[str, object] = dataclasses.field(default_factory=dict)
    boot_count: int = 0

    def reboot(self):
        """Diskless node: a reboot recreates the node from a clean state."""
        self.ephemeral = {}
        self.boot_count += 1
        if self.state == NodeState.DOWN:
            self.state = NodeState.FREE


class Cluster:
    def __init__(self, name: str = "alps"):
        self.name = name
        self.nodes: Dict[str, Node] = {}
        self.vclusters: Dict[str, List[str]] = {}

    # ---------------------------------------------------------- inventory
    def add_nodes(self, prefix: str, n: int, kind: NodeKind, **kw) -> List[str]:
        names = []
        for i in range(n):
            name = f"{prefix}{i:04d}"
            self.nodes[name] = Node(name, kind, **kw)
            names.append(name)
        return names

    def define_vcluster(self, name: str, node_names: List[str]):
        """A vCluster is a logical partition of the machine (§4.1.4)."""
        for n in node_names:
            assert n in self.nodes, n
        self.vclusters[name] = list(node_names)

    def free_nodes(self, kind: Optional[NodeKind] = None,
                   vcluster: Optional[str] = None) -> List[Node]:
        pool = (self.vclusters[vcluster] if vcluster else self.nodes)
        out = [self.nodes[n] for n in pool]
        return [n for n in out if n.state == NodeState.FREE
                and (kind is None or n.kind == kind)]

    # ---------------------------------------------------------- lifecycle
    def attach(self, name: str, plane: NodeState) -> Node:
        """Any HPC node can attach to any plane (paper §4.1.4), provided
        it is free.  Attaching clears node-local state (diskless)."""
        node = self.nodes[name]
        if node.state != NodeState.FREE:
            raise RuntimeError(f"{name} is {node.state}, not free")
        node.ephemeral = {}
        node.state = plane
        return node

    def detach(self, name: str) -> Node:
        node = self.nodes[name]
        node.state = NodeState.FREE
        node.ephemeral = {}
        return node

    def fail(self, name: str) -> Node:
        node = self.nodes[name]
        node.state = NodeState.DOWN
        node.ephemeral = {}
        return node

    def nodes_in(self, plane: NodeState, kind: Optional[NodeKind] = None):
        return [n for n in self.nodes.values() if n.state == plane
                and (kind is None or n.kind == kind)]
