"""Artifact registry with provenance + retention (paper §6.6).

Tracks checkpoints, datasets/mixtures, adapters, and released models as a
lineage DAG, so "which data produced this model" is answerable and GC can
reclaim storage without destroying reproducibility: an artifact is
collectible only if it is unpinned, past retention, not among the newest
of its kind, and not the *direct* provenance of a pinned artifact (deeper
ancestors are reproducible from the retained intermediate, so they may
age out — this is what keeps "checkpoint explosion" bounded).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional, Set


@dataclasses.dataclass
class Artifact:
    artifact_id: str
    kind: str                   # checkpoint | dataset | adapter | model | eval
    uri: str
    size_bytes: int = 0
    parents: List[str] = dataclasses.field(default_factory=list)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    created: float = 0.0
    pinned: bool = False
    deleted: bool = False


@dataclasses.dataclass
class RetentionPolicy:
    max_age_s: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {"checkpoint": 7 * 86400.0})
    keep_last_per_kind: int = 2


class ArtifactRegistry:
    def __init__(self, clock=time.time):
        self.clock = clock
        self.artifacts: Dict[str, Artifact] = {}
        self._ids = itertools.count(1)

    def register(self, kind: str, uri: str, *, parents: Optional[List[str]] = None,
                 size_bytes: int = 0, pinned: bool = False,
                 **meta) -> Artifact:
        for p in (parents or []):
            if p not in self.artifacts:
                raise KeyError(f"unknown parent artifact {p}")
        a = Artifact(f"{kind}-{next(self._ids):05d}", kind, uri,
                     size_bytes, list(parents or []), dict(meta),
                     created=self.clock(), pinned=pinned)
        self.artifacts[a.artifact_id] = a
        return a

    def pin(self, artifact_id: str, value: bool = True):
        self.artifacts[artifact_id].pinned = value

    # ------------------------------------------------------------ lineage
    def lineage(self, artifact_id: str) -> List[Artifact]:
        """All ancestors (provenance chain) oldest-first."""
        seen: Set[str] = set()
        order: List[Artifact] = []

        def walk(aid: str):
            a = self.artifacts[aid]
            for p in a.parents:
                if p not in seen:
                    seen.add(p)
                    walk(p)
                    order.append(self.artifacts[p])

        walk(artifact_id)
        return order

    def descendants(self, artifact_id: str) -> List[Artifact]:
        out = []
        for a in self.artifacts.values():
            if artifact_id in a.parents:
                out.append(a)
                out.extend(self.descendants(a.artifact_id))
        dedup = {a.artifact_id: a for a in out}
        return list(dedup.values())

    # ------------------------------------------------------------ GC
    def collectible(self, policy: RetentionPolicy) -> List[Artifact]:
        now = self.clock()
        by_kind: Dict[str, List[Artifact]] = {}
        for a in self.artifacts.values():
            if not a.deleted:
                by_kind.setdefault(a.kind, []).append(a)
        keep_new: Set[str] = set()
        for kind, arts in by_kind.items():
            arts.sort(key=lambda a: a.created)
            for a in arts[-policy.keep_last_per_kind:]:
                keep_new.add(a.artifact_id)

        out = []
        for a in self.artifacts.values():
            if a.deleted or a.pinned or a.artifact_id in keep_new:
                continue
            max_age = policy.max_age_s.get(a.kind)
            if max_age is not None and now - a.created < max_age:
                continue
            # direct provenance of a pinned artifact is protected; deeper
            # ancestors can be re-derived from the retained intermediate
            children = [c for c in self.artifacts.values()
                        if a.artifact_id in c.parents]
            if any(c.pinned for c in children):
                continue
            out.append(a)
        return out

    def gc(self, policy: RetentionPolicy) -> int:
        freed = 0
        for a in self.collectible(policy):
            a.deleted = True
            freed += a.size_bytes
        return freed

    def storage_by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for a in self.artifacts.values():
            if not a.deleted:
                out[a.kind] = out.get(a.kind, 0) + a.size_bytes
        return out
