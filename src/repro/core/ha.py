"""Active-active high availability (paper §6.3).

Multiple *sites* serve production traffic concurrently; a cluster-mesh
router health-gates endpoints, redistributes traffic in near real time,
and fences split-brain with monotonic configuration epochs: control-plane
writes carry the epoch, and a site that was partitioned (and therefore
missed epochs) refuses stale writes until it re-syncs.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass
class Site:
    name: str
    endpoints: List[Any] = dataclasses.field(default_factory=list)
    healthy: bool = True
    partitioned: bool = False
    epoch: int = 0                 # last config epoch this site has seen


class SplitBrainError(RuntimeError):
    pass


class ClusterMesh:
    """Cross-site service discovery + global load balancing + fencing."""

    def __init__(self, sites: List[Site]):
        self.sites = {s.name: s for s in sites}
        self.epoch = max((s.epoch for s in sites), default=0)
        self.routed: Dict[str, int] = {s.name: 0 for s in sites}

    # ------------------------------------------------------------ health
    def probe(self):
        for s in self.sites.values():
            s.healthy = (not s.partitioned) and any(
                getattr(e, "healthy", True) for e in s.endpoints)

    def partition(self, name: str):
        self.sites[name].partitioned = True
        self.probe()

    def heal(self, name: str):
        s = self.sites[name]
        s.partitioned = False
        s.epoch = self.epoch      # re-sync config before serving writes
        self.probe()

    # ------------------------------------------------------------ control
    def propose_config(self, site_name: str) -> int:
        """A control-plane write from a site.  Stale-epoch sites (healed
        from a partition without re-sync, or still partitioned) are fenced."""
        s = self.sites[site_name]
        if s.partitioned:
            raise SplitBrainError(
                f"{site_name} is partitioned; write fenced")
        if s.epoch < self.epoch:
            raise SplitBrainError(
                f"{site_name} at epoch {s.epoch} < mesh epoch "
                f"{self.epoch}; must re-sync")
        self.epoch += 1
        for other in self.sites.values():
            if not other.partitioned:
                other.epoch = self.epoch
        return self.epoch

    # ------------------------------------------------------------ routing
    def route(self, prefer: Optional[str] = None,
              prompt: Optional[list] = None, namespace: str = ""):
        """Pick the healthiest/least-loaded endpoint across sites; failing
        sites are skipped in near real time (active-active failover).

        With ``prompt``, routing is prefix-affine: among healthy sites the
        replica whose radix prefix cache holds the longest match for the
        prompt wins (so a tenant's shared system prompt keeps landing on
        the replica that already has its KV), with site preference and
        load as tie-breakers."""
        self.probe()
        order = sorted(
            (s for s in self.sites.values() if s.healthy),
            key=lambda s: (0 if s.name == prefer else 1,
                           self.routed[s.name]))
        if prompt:
            best = None          # (match, -site_rank, -load, site, eng)
            for rank, site in enumerate(order):
                for e in site.endpoints:
                    if not getattr(e, "healthy", True):
                        continue
                    fn = getattr(e, "prefix_match_len", None)
                    m = fn(namespace, prompt) if fn else 0
                    key = (m, -rank, -getattr(e, "num_active", 0))
                    if best is None or key > best[0]:
                        best = (key, site, e)
            if best is not None:
                _, site, eng = best
                self.routed[site.name] += 1
                return site, eng
            raise RuntimeError("no healthy site available")
        for site in order:
            live = [e for e in site.endpoints
                    if getattr(e, "healthy", True)]
            if live:
                self.routed[site.name] += 1
                eng = min(live, key=lambda e: getattr(e, "num_active", 0))
                return site, eng
        raise RuntimeError("no healthy site available")
