"""The bifurcated orchestration planes (paper §4.3.2, Fig. 2).

- ``BatchPlane``: Slurm-role — gang-scheduled jobs with priorities,
  preemption, and requeue-on-failure.  Pre-training and heavy fine-tuning
  execute here (checkpoint/restart comes from repro.training.trainer).
- ``ServicePlane``: Kubernetes-role — declarative Deployments reconciled
  against actual replica state (GitOps-style), health probes, node
  selectors ("hpc=true" for engines, commodity for control services), and
  the §5.3.1 property: commodity-hosted services survive HPC maintenance.

Both planes draw nodes from one ``Cluster``; the elastic controller moves
delta capacity between them.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Any, Callable, Dict, List, Optional

from repro.core.cluster import Cluster, Node, NodeKind, NodeState


class JobState(str, enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    CANCELLED = "cancelled"


@dataclasses.dataclass
class BatchJob:
    name: str
    nodes_needed: int
    run_fn: Optional[Callable[["BatchJob"], Any]] = None
    priority: int = 0
    max_requeues: int = 3
    job_id: str = ""
    state: JobState = JobState.PENDING
    assigned: List[str] = dataclasses.field(default_factory=list)
    requeues: int = 0
    result: Any = None
    error: str = ""
    script: str = ""            # recipe name (for bridge-submitted jobs)
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)


class BatchPlane:
    """Gang scheduler over the cluster's batch partition."""

    def __init__(self, cluster: Cluster, vcluster: Optional[str] = None):
        self.cluster = cluster
        self.vcluster = vcluster
        self.queue: List[BatchJob] = []
        self.jobs: Dict[str, BatchJob] = {}
        self._ids = itertools.count(1)

    def submit(self, job: BatchJob) -> str:
        job.job_id = f"job-{next(self._ids)}"
        self.jobs[job.job_id] = job
        self.queue.append(job)
        self.queue.sort(key=lambda j: -j.priority)
        return job.job_id

    def cancel(self, job_id: str):
        job = self.jobs[job_id]
        if job.state == JobState.RUNNING:
            self._release(job)
        job.state = JobState.CANCELLED
        if job in self.queue:
            self.queue.remove(job)

    def _release(self, job: BatchJob):
        for n in job.assigned:
            if self.cluster.nodes[n].state == NodeState.BATCH:
                self.cluster.detach(n)
        job.assigned = []

    def tick(self) -> List[str]:
        """One scheduler pass: start pending jobs that fit.  Returns ids
        of jobs that changed state."""
        changed = []
        for job in list(self.queue):
            free = self.cluster.free_nodes(NodeKind.HPC, self.vcluster)
            if len(free) < job.nodes_needed:
                continue
            take = [n.name for n in free[:job.nodes_needed]]
            for n in take:
                self.cluster.attach(n, NodeState.BATCH)
            job.assigned = take
            job.state = JobState.RUNNING
            self.queue.remove(job)
            changed.append(job.job_id)
            if job.run_fn is not None:
                try:
                    job.result = job.run_fn(job)
                    job.state = JobState.DONE
                except Exception as e:  # noqa: BLE001
                    job.error = f"{type(e).__name__}: {e}"
                    self._on_failure(job)
                finally:
                    self._release(job)
        return changed

    def _on_failure(self, job: BatchJob):
        """Node failure / job crash: requeue (checkpoint/restart picks up
        from the last published step)."""
        if job.requeues < job.max_requeues:
            job.requeues += 1
            job.state = JobState.PENDING
            self.queue.append(job)
            self.queue.sort(key=lambda j: -j.priority)
        else:
            job.state = JobState.FAILED

    def handle_node_failure(self, node_name: str):
        """A batch node died: fail the node, requeue any job using it."""
        self.cluster.fail(node_name)
        for job in self.jobs.values():
            if job.state == JobState.RUNNING and node_name in job.assigned:
                for n in job.assigned:
                    if n != node_name:
                        self.cluster.detach(n)
                job.assigned = []
                self._on_failure(job)


# ===================================================================== #
@dataclasses.dataclass
class DeploymentSpec:
    """Declarative deployment (the YAML-onboarding analogue, §4.4)."""
    name: str
    replicas: int
    node_selector: NodeKind = NodeKind.HPC
    factory: Optional[Callable[[str], Any]] = None  # node -> engine/handler
    version: int = 1


@dataclasses.dataclass
class Replica:
    deployment: str
    node: str
    handler: Any
    version: int
    healthy: bool = True


class ServicePlane:
    """Declarative reconciler over service nodes (K8s role)."""

    def __init__(self, cluster: Cluster):
        self.cluster = cluster
        self.specs: Dict[str, DeploymentSpec] = {}
        self.replicas: Dict[str, List[Replica]] = {}
        self.events: List[str] = []

    def apply(self, spec: DeploymentSpec):
        """Declare desired state (GitOps commit)."""
        self.specs[spec.name] = spec
        self.replicas.setdefault(spec.name, [])

    def delete(self, name: str):
        for r in self.replicas.get(name, []):
            self._teardown(r)
        self.replicas.pop(name, None)
        self.specs.pop(name, None)

    def _teardown(self, r: Replica):
        node = self.cluster.nodes.get(r.node)
        if node and node.state == NodeState.SERVICE:
            # only detach if no other replica uses this node
            others = [x for rs in self.replicas.values() for x in rs
                      if x is not r and x.node == r.node]
            if not others:
                self.cluster.detach(r.node)
        self.events.append(f"teardown {r.deployment}@{r.node}")

    def reconcile(self) -> List[str]:
        """Drive actual state toward desired state.  Returns events."""
        start = len(self.events)
        for name, spec in self.specs.items():
            reps = self.replicas[name]
            # remove unhealthy / outdated replicas
            for r in list(reps):
                node = self.cluster.nodes.get(r.node)
                node_ok = node is not None and node.state == NodeState.SERVICE
                if not r.healthy or not node_ok or r.version != spec.version:
                    self._teardown(r)
                    reps.remove(r)
            # scale down
            while len(reps) > spec.replicas:
                self._teardown(reps.pop())
            # scale up
            while len(reps) < spec.replicas:
                node = self._acquire(spec.node_selector)
                if node is None:
                    self.events.append(f"pending {name}: no {spec.node_selector} node")
                    break
                handler = spec.factory(node.name) if spec.factory else None
                reps.append(Replica(name, node.name, handler, spec.version))
                self.events.append(f"start {name}@{node.name} v{spec.version}")
        return self.events[start:]

    def _acquire(self, kind: NodeKind) -> Optional[Node]:
        free = self.cluster.free_nodes(kind)
        if not free:
            return None
        return self.cluster.attach(free[0].name, NodeState.SERVICE)

    def endpoints(self, name: str) -> List[Replica]:
        return [r for r in self.replicas.get(name, []) if r.healthy]

    def handle_node_failure(self, node_name: str):
        """HPC node lost: mark replicas unhealthy; commodity-hosted
        services are unaffected (the §5.3.1 uptime argument)."""
        self.cluster.fail(node_name)
        for reps in self.replicas.values():
            for r in reps:
                if r.node == node_name:
                    r.healthy = False
                    if r.handler is not None and hasattr(r.handler, "healthy"):
                        r.handler.healthy = False

    def rolling_update(self, name: str):
        self.specs[name].version += 1
