"""FirecREST-style bridge (paper §4.3.2): the service plane's control
logic programmatically submits and monitors *execution-plane* (batch) jobs
through a narrow, typed API — never by sharing schedulers.

Each submission references a curated *recipe* (script) from the catalog
(repro.finetune.recipes); free-form scripts are rejected for non-expert
tenants, which is how the "safe-by-default" blueprint guarantee is
enforced at the boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.core.planes import BatchJob, BatchPlane, JobState


@dataclasses.dataclass
class SubmitResponse:
    job_id: str
    status: str


class PlaneBridge:
    def __init__(self, batch: BatchPlane,
                 recipe_runner: Optional[Callable] = None,
                 allowed_scripts: Optional[List[str]] = None):
        self.batch = batch
        self.recipe_runner = recipe_runner
        self.allowed_scripts = allowed_scripts
        self.audit_log: List[Dict[str, Any]] = []

    # ---- REST-shaped surface -----------------------------------------
    def submit(self, *, script: str, params: Dict[str, Any],
               nodes: int = 1, priority: int = 0,
               tenant: str = "default") -> SubmitResponse:
        if self.allowed_scripts is not None \
                and script not in self.allowed_scripts:
            self.audit_log.append({"tenant": tenant, "script": script,
                                   "action": "rejected"})
            raise PermissionError(
                f"script {script!r} is not in the curated catalog")

        def run(job: BatchJob):
            if self.recipe_runner is None:
                return None
            return self.recipe_runner(script, dict(params), job)

        job = BatchJob(name=f"{tenant}:{script}", nodes_needed=nodes,
                       run_fn=run, priority=priority, script=script,
                       params=dict(params))
        jid = self.batch.submit(job)
        self.audit_log.append({"tenant": tenant, "script": script,
                               "action": "submitted", "job_id": jid})
        return SubmitResponse(jid, JobState.PENDING.value)

    def status(self, job_id: str) -> Dict[str, Any]:
        j = self.batch.jobs[job_id]
        return {"job_id": job_id, "state": j.state.value,
                "requeues": j.requeues, "error": j.error,
                "nodes": list(j.assigned)}

    def cancel(self, job_id: str) -> Dict[str, Any]:
        self.batch.cancel(job_id)
        return self.status(job_id)

    def result(self, job_id: str) -> Any:
        j = self.batch.jobs[job_id]
        if j.state != JobState.DONE:
            raise RuntimeError(f"job {job_id} is {j.state.value}")
        return j.result
