"""Elastic resource management (paper §6.2): a fixed *baseline* of nodes
stays with the inference service; a *delta* pool moves between the batch
and service planes under observed demand.

Scaling policy: scale OUT when queue pressure exceeds ``hi`` for
``patience`` consecutive ticks (claim a delta node from batch/free),
scale IN when utilization stays under ``lo`` (return the node).  Node
transitions respect diskless semantics — a node moving planes arrives
clean and its engine is rebuilt by the deployment factory.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.core.cluster import Cluster, NodeKind, NodeState
from repro.core.planes import BatchPlane, DeploymentSpec, ServicePlane


@dataclasses.dataclass
class ElasticPolicy:
    hi_queue_per_replica: float = 4.0   # scale out above this
    lo_util: float = 0.25               # scale in below this
    patience: int = 3
    min_replicas: int = 1               # baseline ("hot" models stay up)
    max_replicas: int = 8


class ElasticController:
    def __init__(self, cluster: Cluster, service: ServicePlane,
                 deployment: str, policy: ElasticPolicy,
                 load_fn: Callable[[], Dict[str, float]]):
        """load_fn returns {"queue": waiting requests, "active": running
        requests, "capacity": per-replica concurrent slots}."""
        self.cluster = cluster
        self.service = service
        self.deployment = deployment
        self.policy = policy
        self.load_fn = load_fn
        self.hot_ticks = 0
        self.cold_ticks = 0
        self.decisions: List[str] = []

    def tick(self) -> Optional[str]:
        spec = self.service.specs[self.deployment]
        n = max(len(self.service.endpoints(self.deployment)), 1)
        load = self.load_fn()
        queue_pr = load["queue"] / n
        util = load["active"] / max(n * load["capacity"], 1e-9)

        decision = None
        if queue_pr > self.policy.hi_queue_per_replica:
            self.hot_ticks += 1
            self.cold_ticks = 0
            if (self.hot_ticks >= self.policy.patience
                    and spec.replicas < self.policy.max_replicas
                    and self._delta_available()):
                spec.replicas += 1
                decision = f"scale-out -> {spec.replicas}"
                self.hot_ticks = 0
        elif util < self.policy.lo_util:
            self.cold_ticks += 1
            self.hot_ticks = 0
            if (self.cold_ticks >= self.policy.patience
                    and spec.replicas > self.policy.min_replicas):
                spec.replicas -= 1
                decision = f"scale-in -> {spec.replicas}"
                self.cold_ticks = 0
        else:
            self.hot_ticks = self.cold_ticks = 0
        if decision:
            self.decisions.append(decision)
            self.service.reconcile()
        return decision

    def _delta_available(self) -> bool:
        return bool(self.cluster.free_nodes(NodeKind.HPC))
