"""The full FM lifecycle (paper Fig. 1) as an executable pipeline:

  data prep -> pre-train -> SFT -> alignment -> safety/capability eval
  -> release optimization (quantize) -> publish -> deploy (serve)

Every stage consumes/produces registry artifacts with full lineage, runs
on the correct plane (training stages through the bridge onto the batch
plane; deployment onto the service plane), and evaluation is interleaved
between stages with gates — exactly the iterative post-training loop the
paper operationalizes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.core.registry import ArtifactRegistry


@dataclasses.dataclass
class StageResult:
    stage: str
    artifact_id: Optional[str]
    metrics: Dict[str, Any]
    passed: bool = True


@dataclasses.dataclass
class Stage:
    name: str
    run: Callable[["LifecycleContext"], StageResult]
    gate: Optional[Callable[[StageResult], bool]] = None


class LifecycleError(RuntimeError):
    pass


class LifecycleContext:
    """Mutable state threaded through stages (params, adapters, data…)."""

    def __init__(self, registry: ArtifactRegistry):
        self.registry = registry
        self.state: Dict[str, Any] = {}
        self.artifacts: Dict[str, str] = {}   # stage -> artifact id
        self.history: List[StageResult] = []

    def register(self, stage: str, kind: str, uri: str,
                 parent_stages: List[str] = (), **meta) -> str:
        parents = [self.artifacts[s] for s in parent_stages
                   if s in self.artifacts]
        a = self.registry.register(kind, uri, parents=parents, **meta)
        self.artifacts[stage] = a.artifact_id
        return a.artifact_id


class LifecyclePipeline:
    def __init__(self, stages: List[Stage], registry: ArtifactRegistry):
        self.stages = stages
        self.ctx = LifecycleContext(registry)

    def run(self, stop_on_gate_failure: bool = True) -> List[StageResult]:
        for stage in self.stages:
            res = stage.run(self.ctx)
            if stage.gate is not None:
                res.passed = bool(stage.gate(res))
            self.ctx.history.append(res)
            if not res.passed and stop_on_gate_failure:
                raise LifecycleError(
                    f"stage {stage.name!r} failed its gate: {res.metrics}")
        return self.ctx.history
