"""Dataset mixtures and filtering — the data-preparation stage of the
lifecycle (Fig. 1: "datasets preparation ... data mixtures").

A ``Mixture`` is a versioned, deterministic weighted blend of sources;
its recipe (weights + filters) is hashable so the artifact registry can
track which mixture produced which checkpoint (provenance, §6.6)."""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SourceSpec:
    name: str
    weight: float
    filter_name: str = "none"   # none | dedup_rows | max_token


FILTERS: Dict[str, Callable] = {
    "none": lambda b: b,
}


def register_filter(name: str):
    def deco(fn):
        FILTERS[name] = fn
        return fn
    return deco


@register_filter("dedup_rows")
def _dedup_rows(batch):
    """Drop duplicate rows (zero their mask) within the batch."""
    toks = batch["tokens"]
    _, first_idx = np.unique(toks, axis=0, return_index=True)
    keep = np.zeros(toks.shape[0], bool)
    keep[first_idx] = True
    out = dict(batch)
    out["mask"] = batch["mask"] * keep[:, None]
    return out


@register_filter("max_token")
def _max_token(batch, limit: int = 1 << 30):
    out = dict(batch)
    out["mask"] = batch["mask"] * (batch["targets"] < limit)
    return out


class Mixture:
    def __init__(self, sources: Sequence[Tuple[SourceSpec, object]],
                 seed: int = 0):
        self.sources = list(sources)
        self.seed = seed
        total = sum(s.weight for s, _ in self.sources)
        self.probs = np.array([s.weight / total for s, _ in self.sources])

    def recipe_hash(self) -> str:
        doc = json.dumps([dataclasses.asdict(s) for s, _ in self.sources],
                         sort_keys=True)
        return hashlib.sha256(doc.encode()).hexdigest()[:16]

    def batch(self, step: int, shard: int = 0, num_shards: int = 1):
        rng = np.random.Generator(np.random.Philox(
            key=self.seed + 3, counter=[step, shard, 0, 0]))
        i = int(rng.choice(len(self.sources), p=self.probs))
        spec, ds = self.sources[i]
        b = ds.batch(step, shard, num_shards)
        b = FILTERS[spec.filter_name](b)
        b["source"] = spec.name
        return b
