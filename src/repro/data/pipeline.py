"""Deterministic, resumable, sharded data pipeline.

Every batch is a pure function of (seed, step, shard) via counter-based
Philox keys — so restart-from-checkpoint resumes the exact token stream
with zero pipeline state, and elastic resharding (different shard count)
keeps determinism per (step, global_index).

The synthetic corpus is a fixed random bigram chain over the vocab, so
small models measurably learn (loss drops below unigram entropy) in the
end-to-end examples — a stand-in for the tokenized web corpora the paper's
pre-training jobs consume from the parallel filesystem.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 8          # bigram successors per token
    kind: str = "bigram"        # bigram | uniform


class SyntheticLM:
    """Deterministic synthetic LM stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.Generator(np.random.Philox(key=cfg.seed))
        v = cfg.vocab_size
        # fixed bigram table: token t can be followed by branching tokens
        self.successors = rng.integers(0, v, size=(v, cfg.branching),
                                       dtype=np.int32)

    def _tokens(self, step: int, shard: int, n_rows: int) -> np.ndarray:
        c = self.cfg
        rng = np.random.Generator(np.random.Philox(
            key=c.seed + 1, counter=[step, shard, 0, 0]))
        if c.kind == "uniform":
            return rng.integers(0, c.vocab_size,
                                size=(n_rows, c.seq_len + 1), dtype=np.int32)
        out = np.empty((n_rows, c.seq_len + 1), np.int32)
        out[:, 0] = rng.integers(0, c.vocab_size, size=n_rows)
        choices = rng.integers(0, c.branching,
                               size=(n_rows, c.seq_len)).astype(np.int32)
        for t in range(c.seq_len):
            out[:, t + 1] = self.successors[out[:, t], choices[:, t]]
        return out

    def batch(self, step: int, shard: int = 0,
              num_shards: int = 1) -> Dict[str, np.ndarray]:
        c = self.cfg
        assert c.global_batch % num_shards == 0
        rows = c.global_batch // num_shards
        toks = self._tokens(step, shard, rows)
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "mask": np.ones((rows, c.seq_len), np.float32),
        }

    def iterate(self, start_step: int = 0, shard: int = 0,
                num_shards: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, shard, num_shards)
            step += 1


class SFTDataset:
    """Synthetic (prompt, response) pairs with loss masked to the response —
    the supervised fine-tuning stage of the lifecycle.

    The "instruction style" is a low-rank behaviour: responses cycle
    through a fixed token pattern (period ``style_period`` starting at
    ``style_base``), so LoRA-rank adapters can provably express it — the
    test signal is a steep response-loss drop."""

    def __init__(self, cfg: DataConfig, prompt_len: int = 16,
                 style_base: int = 7, style_period: int = 4):
        self.cfg = cfg
        self.prompt_len = prompt_len
        self.style_base = style_base
        self.style_period = style_period
        self.base = SyntheticLM(cfg)

    def batch(self, step: int, shard: int = 0, num_shards: int = 1):
        b = self.base.batch(step + 100_000, shard, num_shards)
        c = self.cfg
        P = self.prompt_len
        pos = np.arange(c.seq_len)
        resp_row = (self.style_base
                    + (pos % self.style_period)) % c.vocab_size
        resp = np.broadcast_to(resp_row, b["tokens"].shape).astype(np.int32)
        tokens = b["tokens"].copy()
        targets = b["targets"].copy()
        tokens[:, P:] = resp[:, P - 1:-1]
        targets[:, P - 1:] = resp[:, P - 1:]
        mask = np.zeros_like(b["mask"])
        mask[:, P - 1:] = 1.0  # loss only on the response
        return {"tokens": tokens, "targets": targets, "mask": mask}


class PreferenceDataset:
    """Synthetic preference pairs (chosen/rejected) for DPO alignment."""

    def __init__(self, cfg: DataConfig, prompt_len: int = 16):
        self.cfg = cfg
        self.prompt_len = prompt_len
        self.sft = SFTDataset(cfg, prompt_len)

    def batch(self, step: int, shard: int = 0, num_shards: int = 1):
        good = self.sft.batch(step, shard, num_shards)
        rng = np.random.Generator(np.random.Philox(
            key=self.cfg.seed + 9, counter=[step, shard, 0, 0]))
        P = self.prompt_len
        bad_resp = rng.integers(0, self.cfg.vocab_size,
                                size=good["tokens"].shape, dtype=np.int32)
        bad_tokens = good["tokens"].copy()
        bad_targets = good["targets"].copy()
        bad_tokens[:, P:] = bad_resp[:, P:]
        bad_targets[:, P - 1:-1] = bad_resp[:, P:]
        bad_targets[:, -1] = bad_resp[:, -1]
        return {
            "chosen": good,
            "rejected": {"tokens": bad_tokens, "targets": bad_targets,
                         "mask": good["mask"]},
        }
