"""Byte-level tokenizer (stub for the data-prep stage).

Real deployments plug in a trained BPE vocabulary; every interface the
framework relies on (encode/decode/vocab_size/special ids) is here, and
synthetic pipelines bypass tokenization entirely."""
from __future__ import annotations

from typing import List


class ByteTokenizer:
    """256 byte tokens + specials; ids are stable and reversible."""

    PAD, BOS, EOS = 256, 257, 258

    @property
    def vocab_size(self) -> int:
        return 259

    def encode(self, text: str, *, bos: bool = True,
               eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return ids

    def decode(self, ids: List[int]) -> str:
        return bytes(i for i in ids if i < 256).decode("utf-8",
                                                       errors="replace")
