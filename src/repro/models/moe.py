"""Mixture-of-Experts: top-k routing with shared experts.

Two implementations of the same math:

- ``dense``: every expert computes every token, combined with routing
  weights (exact, no capacity drops) — the oracle for tests and tiny runs.
- ``ep``: expert-parallel shard_map — tokens are locally dispatched into
  per-expert capacity buffers, exchanged with ``all_to_all`` over the
  "model" mesh axis (the EP axis), computed as batched matmuls, and
  returned.  This is the production path; the all-to-all is what the
  dry-run collective parse attributes to MoE.

Experts are padded up to a multiple of the EP axis (e.g. granite's 40
experts pad to 48 on a 16-way axis); pad experts receive no tokens but do
appear in the batched matmul — the MODEL_FLOPS/HLO ratio in the roofline
accounts for this.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.param import ParamSpec
from repro.parallel import sharding


def padded_experts(cfg: ModelConfig, ep: Optional[int] = None) -> int:
    e = cfg.num_experts
    if ep is None:
        ep = sharding.mesh_axis_size(
            (sharding.current_rules() or sharding.make_rules("train"))
            .resolve("expert"))
    return -(-e // max(ep, 1)) * max(ep, 1)


def moe_specs(cfg: ModelConfig, num_experts_padded: Optional[int] = None):
    d, ff = cfg.d_model, cfg.moe_d_ff
    E = num_experts_padded or cfg.num_experts
    s = {
        "router": ParamSpec((d, cfg.num_experts), ("fsdp", None), "fan_in"),
        "w_gate": ParamSpec((E, d, ff), ("expert", "fsdp", None), "fan_in"),
        "w_up": ParamSpec((E, d, ff), ("expert", "fsdp", None), "fan_in"),
        "w_down": ParamSpec((E, ff, d), ("expert", None, "fsdp"), "fan_in"),
    }
    if cfg.num_shared_experts:
        sff = cfg.num_shared_experts * ff
        s["shared_gate"] = ParamSpec((d, sff), ("fsdp", "tensor"), "fan_in")
        s["shared_up"] = ParamSpec((d, sff), ("fsdp", "tensor"), "fan_in")
        s["shared_down"] = ParamSpec((sff, d), ("tensor", "fsdp"), "fan_in")
    return s


def _router(cfg: ModelConfig, w, x):
    """x: (..., d) -> probs (..., k), ids (..., k), aux loss (scalar part)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.moe_top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    # switch-style load balancing aux loss
    E = cfg.num_experts
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    one_hot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)
    ce = jnp.mean(jnp.sum(one_hot, axis=-2).reshape(-1, E), axis=0) / cfg.moe_top_k
    aux = E * jnp.sum(me * ce)
    return top_p, top_i, aux


def _expert_mlp(cfg, p, xe):
    """xe: (E, C, d) batched per-expert tokens."""
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    act = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
    return jnp.einsum("ecf,efd->ecd", act * u, p["w_down"])


def _shared(cfg, p, x):
    if not cfg.num_shared_experts:
        return 0.0
    g = jnp.einsum("...d,df->...f", x, p["shared_gate"])
    u = jnp.einsum("...d,df->...f", x, p["shared_up"])
    g = sharding.constrain(
        g, ("act_batch",) + (None,) * (g.ndim - 2) + ("act_ff",))
    act = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
    return jnp.einsum("...f,fd->...d", act * u, p["shared_down"])


# ------------------------------------------------------------- dense
def moe_dense(cfg: ModelConfig, p, x):
    """Exact all-experts compute (oracle / tiny paths).  x: (B,S,d)."""
    top_p, top_i, aux = _router(cfg, p["router"], x)
    E = cfg.num_experts
    E_stored = p["w_gate"].shape[0]  # may be padded for EP divisibility
    xe = jnp.broadcast_to(x[None], (E_stored,) + x.shape).reshape(
        E_stored, -1, x.shape[-1])
    ye = _expert_mlp(cfg, p, xe).reshape((E_stored,) + x.shape)[:E]
    one_hot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)  # (B,S,k,E)
    combine = jnp.einsum("bske,bsk->bse", one_hot, top_p)
    y = jnp.einsum("ebsd,bse->bsd", ye.astype(jnp.float32), combine)
    return y.astype(x.dtype) + _shared(cfg, p, x), aux


# ------------------------------------------------------------- EP
def _dispatch_local(cfg, x, top_p, top_i, E_pad, C):
    """Build per-expert capacity buffers on one device.

    x: (T,d).  Returns xe (E_pad,C,d), combine (T,k,2) slot refs:
    (expert, slot) with -1 for dropped, and weight buffer (E_pad,C)."""
    T, d = x.shape
    k = cfg.moe_top_k
    flat_e = top_i.reshape(-1)                       # (T*k,)
    # stable order by expert id
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    # rank within expert = position - first occurrence offset
    counts = jnp.bincount(flat_e, length=E_pad)      # (E_pad,)
    offsets = jnp.concatenate([jnp.zeros(1, counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * k) - offsets[e_sorted]
    keep = rank < C
    slot = jnp.where(keep, e_sorted * C + rank, E_pad * C)  # overflow bin
    tok = order // k
    xe = jnp.zeros((E_pad * C + 1, d), x.dtype).at[slot].set(x[tok])
    wt = jnp.zeros((E_pad * C + 1,), jnp.float32).at[slot].set(
        top_p.reshape(-1)[order])
    # map back: for each (token,slot-in-k) its buffer position
    back = jnp.full((T * k,), E_pad * C, jnp.int32)
    back = back.at[order].set(jnp.where(keep, slot, E_pad * C).astype(jnp.int32))
    return xe[:-1].reshape(E_pad, C, d), wt[:-1].reshape(E_pad, C), back


def moe_ep(cfg: ModelConfig, p, x, *, capacity_factor=None):
    """Expert-parallel MoE via shard_map all-to-all.  x: (B,S,d)."""
    mesh, rules = sharding.active()
    ep_axis = rules.resolve("expert")
    assert isinstance(ep_axis, str)
    m = mesh.shape[ep_axis]
    # stored expert count is padded at spec time (multiple of 16, which any
    # production EP degree divides); derive from the weights, not the mesh
    E_pad = p["w_gate"].shape[0]
    assert E_pad % m == 0, (E_pad, m)
    E_loc = E_pad // m
    cf = capacity_factor or cfg.capacity_factor
    k = cfg.moe_top_k

    batch_ax = rules.resolve("act_batch")
    seq_ax = rules.resolve("act_qseq")
    x_spec = P(batch_ax, seq_ax, None)
    w_specs = {
        "router": P(None, None),
        "w_gate": P(ep_axis, None, None),
        "w_up": P(ep_axis, None, None),
        "w_down": P(ep_axis, None, None),
    }
    used = {n for n in jax.tree.leaves((batch_ax, seq_ax, ep_axis))
            if isinstance(n, str)}

    B, S, d = x.shape
    shards = sharding.mesh_axis_size(batch_ax) * sharding.mesh_axis_size(seq_ax)
    T_loc = max((B * S) // max(shards, 1), 1)
    C = max(int(T_loc * k / E_pad * cf), 1)
    C = -(-C // 4) * 4 if C > 4 else C

    def local_fn(xl, wg, wu, wd, router):
        Bl, Sl, _ = xl.shape
        xt = xl.reshape(Bl * Sl, d)
        top_p, top_i, aux = _router(cfg, router, xt)
        xe, wt, back = _dispatch_local(cfg, xt, top_p, top_i, E_pad, C)
        # exchange: (E_pad,C,d) -> (E_loc, m*C, d)
        xr = jax.lax.all_to_all(xe, ep_axis, 0, 1, tiled=True)
        pe = {"w_gate": wg, "w_up": wu, "w_down": wd}
        ye = _expert_mlp(cfg, pe, xr)
        yb = jax.lax.all_to_all(ye, ep_axis, 1, 0, tiled=True)  # (E_pad,C,d)
        flat = jnp.concatenate(
            [yb.reshape(E_pad * C, d).astype(jnp.float32),
             jnp.zeros((1, d), jnp.float32)])
        wflat = jnp.concatenate([wt.reshape(-1), jnp.zeros((1,))])
        yk = flat[back] * wflat[back][:, None]          # (T*k, d)
        y = jnp.sum(yk.reshape(Bl * Sl, k, d), axis=1)
        aux = jax.lax.pmean(aux, tuple(sorted(used)))
        return y.reshape(Bl, Sl, d).astype(xl.dtype), aux

    fn = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, w_specs["w_gate"], w_specs["w_up"],
                  w_specs["w_down"], w_specs["router"]),
        out_specs=(x_spec, P()),
        check_vma=False)
    y, aux = fn(x, p["w_gate"], p["w_up"], p["w_down"], p["router"])
    return y + _shared(cfg, p, x), aux


def moe_block(cfg: ModelConfig, p, x):
    """Dispatch to impl per cfg.moe_impl / context.  Returns (y, aux)."""
    impl = cfg.moe_impl
    if impl == "auto":
        act = sharding.active()
        if act is not None:
            mesh, rules = act
            ep = rules.resolve("expert")
            impl = "ep" if isinstance(ep, str) and mesh.shape[ep] > 1 else "dense"
        else:
            impl = "dense"
    if impl == "ep":
        return moe_ep(cfg, p, x)
    return moe_dense(cfg, p, x)
