"""Layer kinds and their param specs / application.

A "layer" is one residual block: (norm → mixer → +res) [→ norm → ffn → +res].
Kinds compose the mixer (gqa attention / MLA / mamba / cross-attn) with the
ffn (dense MLP / MoE / none) to cover every assigned architecture.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_mlp, apply_norm, mlp_specs, norm_specs
from repro.parallel import sharding


def mixer_specs(cfg: ModelConfig, mixer: str):
    if mixer == "gqa":
        return attn_mod.attn_specs(cfg)
    if mixer == "mla":
        return mla_mod.mla_specs(cfg)
    if mixer == "mamba":
        return ssm_mod.mamba_specs(cfg)
    raise ValueError(mixer)


def layer_specs(cfg: ModelConfig, mixer: str, ffn: str,
                num_experts_padded: Optional[int] = None):
    """mixer: gqa|mla|mamba|none ; ffn: mlp|moe|none ; (+cross for enc-dec)."""
    s = {}
    if mixer != "none":
        s["mixer"] = mixer_specs(cfg, mixer)
        s["ln1"] = norm_specs(cfg)
    if ffn == "mlp":
        s["mlp"] = mlp_specs(cfg)
        s["ln2"] = norm_specs(cfg)
    elif ffn == "moe":
        s["moe"] = moe_mod.moe_specs(cfg, num_experts_padded)
        s["ln2"] = norm_specs(cfg)
    return s


def dec_layer_specs(cfg: ModelConfig):
    """Whisper decoder layer: self-attn + cross-attn + mlp."""
    return {
        "mixer": attn_mod.attn_specs(cfg),
        "ln1": norm_specs(cfg),
        "cross": attn_mod.attn_specs(cfg),
        "ln_cross": norm_specs(cfg),
        "mlp": mlp_specs(cfg),
        "ln2": norm_specs(cfg),
    }


def _gather_fsdp(p, specs):
    """ZeRO-3 weight gather: constrain each param with its 'fsdp' axis
    dropped, so SPMD all-gathers the (small) weight shards over "data"
    instead of batch-gathering the (huge) activations.  No-op outside an
    active mesh or when fsdp is unmapped (decode rules)."""
    from repro.models.param import ParamSpec

    def walk(pp, ss):
        if isinstance(ss, ParamSpec):
            if "fsdp" not in ss.axes:
                return pp
            return sharding.constrain(
                pp, tuple(None if a == "fsdp" else a for a in ss.axes))
        return {k: walk(pp[k], ss[k]) for k in pp}

    return walk(p, specs)


def apply_layer(cfg: ModelConfig, p, x, positions, *, mixer: str, ffn: str,
                mode: str, cache=None, lengths=None, causal: bool = True,
                enc_out=None, cross_cache=None, block_tables=None,
                lora=None, adapter_ids=None):
    """Returns (x, new_cache, new_cross_cache, aux).  ``block_tables``
    switches attention mixers to the paged-pool decode path (SSM mixers
    have no per-position KV and never see it).  ``lora`` is this layer's
    slice of the stacked multi-LoRA adapter tree (``{"mixer": {target:
    {"a", "b"}}}``); with per-row ``adapter_ids`` the attention mixers add
    each row's adapter shift (see ``attention.lora_shift``)."""
    if sharding.active() is not None:
        E_pad = p["moe"]["w_gate"].shape[0] if ffn == "moe" else None
        spec_tree = (dec_layer_specs(cfg) if "cross" in p
                     else layer_specs(cfg, mixer, ffn, E_pad))
        # EP expert weights keep their fsdp sharding (gathered at the
        # shard_map boundary); everything else is explicitly ZeRO-gathered
        skip = {"w_gate", "w_up", "w_down", "router"}
        if ffn == "moe":
            moe_p, moe_s = p["moe"], spec_tree["moe"]
            gathered_moe = dict(
                {k: moe_p[k] for k in moe_p if k in skip},
                **_gather_fsdp({k: moe_p[k] for k in moe_p
                                if k not in skip},
                               {k: moe_s[k] for k in moe_s
                                if k not in skip}))
            p = dict(_gather_fsdp(
                {k: v for k, v in p.items() if k != "moe"},
                {k: v for k, v in spec_tree.items() if k != "moe"}),
                moe=gathered_moe)
        else:
            p = _gather_fsdp(p, spec_tree)
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    new_cross = None
    # Megatron-style sequence parallelism: the residual stream is sharded
    # over ("act_batch", "act_qseq") so per-layer remat residuals stay small;
    # XLA inserts the AG/RS pairs around TP matmuls automatically.
    x = sharding.constrain(x, ("act_batch", "act_qseq", None))

    lmix = lora.get("mixer") if lora else None
    if mixer != "none":
        h = apply_norm(cfg, p["ln1"], x)
        if mixer == "gqa":
            o, new_cache = attn_mod.attention_block(
                cfg, p["mixer"], h, positions, mode=mode, cache=cache,
                lengths=lengths, causal=causal, block_tables=block_tables,
                lora=lmix, adapter_ids=adapter_ids)
        elif mixer == "mla":
            o, new_cache = mla_mod.mla_block(
                cfg, p["mixer"], h, positions, mode=mode, cache=cache,
                lengths=lengths, block_tables=block_tables,
                lora=lmix, adapter_ids=adapter_ids)
        elif mixer == "mamba":
            o, new_cache = ssm_mod.mamba_block(
                cfg, p["mixer"], h, mode=mode, cache=cache)
        x = x + o

    if "cross" in p:
        h = apply_norm(cfg, p["ln_cross"], x)
        if mode in ("train", "prefill"):
            assert enc_out is not None
            kv = attn_mod.cross_kv(cfg, p["cross"], enc_out)
            new_cross = kv if mode == "prefill" else None
        else:
            kv = cross_cache
        x = x + attn_mod.cross_attention_block(cfg, p["cross"], h, kv)

    if ffn == "mlp":
        h = apply_norm(cfg, p["ln2"], x)
        x = x + apply_mlp(cfg, p["mlp"], h)
    elif ffn == "moe":
        h = apply_norm(cfg, p["ln2"], x)
        y, aux = moe_mod.moe_block(cfg, p["moe"], h)
        x = x + y

    x = sharding.constrain(x, ("act_batch", "act_qseq", None))
    return x, new_cache, new_cross, aux
