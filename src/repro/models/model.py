"""Composable LM covering all assigned architectures.

Pure-functional API:
  model_specs(cfg)          -> ParamSpec tree (shapes + logical axes)
  init(cfg, key, dtype)     -> params
  train_loss(cfg, params, batch)            -> (loss, metrics)
  prefill(cfg, params, batch)               -> (logits_last, cache, aux)
  decode_step(cfg, params, tokens, cache, lengths) -> (logits, cache)
  make_cache(cfg, batch, capacity, ...)     -> cache pytree (zeros/abstract)
  input_specs(cfg, shape)   -> ShapeDtypeStruct stand-ins for the dry-run

Layer stacks run under ``lax.scan`` over stacked parameters (compact HLO —
mandatory for compiling 80+ dry-run cells on one CPU core); Jamba scans
over period-8 super-blocks.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec, WHISPER_ENCODER_FRAMES
from repro.models import moe as moe_mod
from repro.models.blocks import apply_layer, dec_layer_specs, layer_specs
from repro.models.layers import (apply_norm, embed_tokens, embedding_specs,
                                 norm_specs, unembed)
from repro.models.param import (ParamSpec, abstract_params, init_params,
                                param_axes, stack_specs)
from repro.parallel import sharding

# --------------------------------------------------------------- plans
JAMBA_FFN = ("mlp", "moe")  # even positions dense, odd positions MoE


def _ep_degree(multi_pod_hint: int = 16) -> int:
    """Experts are padded to a multiple of 16 at spec time; every divisor
    of 16 is then a valid EP degree (the 16-way production "model" axis
    and the 2/4/8-way test meshes alike)."""
    return 16


def stack_plan(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.is_encoder_decoder:
        return {"kind": "encdec"}
    if cfg.block_period > 1:
        return {"kind": "hybrid",
                "groups": cfg.num_layers // cfg.block_period}
    mixer = {"gqa": "gqa", "mla": "mla", "none": "mamba"}[cfg.attention]
    ffn = "none" if cfg.family == "ssm" else (
        "moe" if cfg.has_moe else "mlp")
    first = []
    n = cfg.num_layers
    if cfg.has_moe and cfg.first_k_dense:
        first = [(mixer, "mlp")] * cfg.first_k_dense
        n -= cfg.first_k_dense
    return {"kind": "uniform", "mixer": mixer, "ffn": ffn,
            "first": first, "n": n}


def _E_pad(cfg: ModelConfig) -> int:
    return moe_mod.padded_experts(cfg, _ep_degree())


def model_specs(cfg: ModelConfig):
    plan = stack_plan(cfg)
    s: Dict[str, Any] = {"embed": embedding_specs(cfg),
                         "ln_f": norm_specs(cfg)}
    if plan["kind"] == "uniform":
        if plan["first"]:
            s["first"] = [layer_specs(cfg, m, f, _E_pad(cfg))
                          for m, f in plan["first"]]
        s["stack"] = stack_specs(
            layer_specs(cfg, plan["mixer"], plan["ffn"], _E_pad(cfg)),
            plan["n"])
    elif plan["kind"] == "hybrid":
        sub = {}
        for i in range(cfg.block_period):
            mixer = "gqa" if i in cfg.attn_positions else "mamba"
            ffn = JAMBA_FFN[i % cfg.moe_layer_period == cfg.moe_layer_offset]
            sub[f"sub{i}"] = layer_specs(cfg, mixer, ffn, _E_pad(cfg))
        s["stack"] = stack_specs(sub, plan["groups"])
    else:  # encdec
        s["enc_stack"] = stack_specs(
            layer_specs(cfg, "gqa", "mlp"), cfg.num_encoder_layers)
        s["ln_enc"] = norm_specs(cfg)
        s["dec_stack"] = stack_specs(dec_layer_specs(cfg),
                                     cfg.num_layers)
    return s


def init(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    return init_params(model_specs(cfg), key, dtype)


def model_param_axes(cfg: ModelConfig):
    return param_axes(model_specs(cfg))


# --------------------------------------------------------------- caches
def _layer_cache_struct(cfg: ModelConfig, mixer: str, B: int, cap: int,
                        dtype):
    if mixer == "gqa":
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        return {"k": ((B, cap, kv, hd), dtype),
                "v": ((B, cap, kv, hd), dtype)}
    if mixer == "mla":
        return {"ckv": ((B, cap, cfg.kv_lora_rank), dtype),
                "kpe": ((B, cap, cfg.qk_rope_head_dim), dtype)}
    if mixer == "mamba":
        convdim = cfg.ssm_d_inner + 2 * cfg.ssm_state
        return {"conv": ((B, cfg.ssm_conv_width - 1, convdim), dtype),
                "ssd": ((B, cfg.ssm_heads, cfg.ssm_head_dim,
                         cfg.ssm_state), jnp.float32)}
    raise ValueError(mixer)


def _cache_axes_one(cfg: ModelConfig, mixer: str):
    if mixer == "gqa":
        ax = ("act_batch", "act_kvseq", "act_heads", None)
        return {"k": ax, "v": ax}
    if mixer == "mla":
        return {"ckv": ("act_batch", "act_kvseq", None),
                "kpe": ("act_batch", "act_kvseq", None)}
    if mixer == "mamba":
        return {"conv": ("act_batch", None, "act_ff"),
                "ssd": ("act_batch", "act_ssm_heads", None, None)}
    raise ValueError(mixer)


def _materialize(tree, abstract: bool):
    def one(leaf):
        shape, dt = leaf
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)
    return jax.tree.map(one, tree,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2 and isinstance(x[0], tuple))


def _stackc(tree, n):
    return jax.tree.map(
        lambda leaf: ((n,) + leaf[0], leaf[1]), tree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))


def cache_struct(cfg: ModelConfig, B: int, cap: int, dtype=jnp.bfloat16):
    plan = stack_plan(cfg)
    if plan["kind"] == "uniform":
        c: Dict[str, Any] = {}
        if plan["first"]:
            c["first"] = [_layer_cache_struct(cfg, m, B, cap, dtype)
                          for m, _ in plan["first"]]
        c["stack"] = _stackc(
            _layer_cache_struct(cfg, plan["mixer"], B, cap, dtype),
            plan["n"])
        return c
    if plan["kind"] == "hybrid":
        sub = {}
        for i in range(cfg.block_period):
            mixer = "gqa" if i in cfg.attn_positions else "mamba"
            sub[f"sub{i}"] = _layer_cache_struct(cfg, mixer, B, cap, dtype)
        return {"stack": _stackc(sub, plan["groups"])}
    # encdec: decoder self cache + cross kv cache
    enc_len = min(WHISPER_ENCODER_FRAMES, cap)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "dec": _stackc(_layer_cache_struct(cfg, "gqa", B, cap, dtype),
                       cfg.num_layers),
        "cross": _stackc({"k": ((B, enc_len, kv, hd), dtype),
                          "v": ((B, enc_len, kv, hd), dtype)},
                         cfg.num_layers),
    }


def cache_axes(cfg: ModelConfig):
    plan = stack_plan(cfg)
    pre = ("layers",)
    if plan["kind"] == "uniform":
        ax1 = _cache_axes_one(cfg, plan["mixer"])
        c: Dict[str, Any] = {"stack": jax.tree.map(
            lambda a: pre + a, ax1,
            is_leaf=lambda x: isinstance(x, tuple))}
        if plan["first"]:
            c["first"] = [_cache_axes_one(cfg, m) for m, _ in plan["first"]]
        return c
    if plan["kind"] == "hybrid":
        sub = {}
        for i in range(cfg.block_period):
            mixer = "gqa" if i in cfg.attn_positions else "mamba"
            sub[f"sub{i}"] = jax.tree.map(
                lambda a: pre + a, _cache_axes_one(cfg, mixer),
                is_leaf=lambda x: isinstance(x, tuple))
        return {"stack": sub}
    ax = pre + ("act_batch", "act_kvseq", "act_heads", None)
    return {"dec": {"k": ax, "v": ax}, "cross": {"k": ax, "v": ax}}


def make_cache(cfg: ModelConfig, B: int, capacity: int,
               dtype=jnp.bfloat16, abstract: bool = False):
    return _materialize(cache_struct(cfg, B, capacity, dtype), abstract)


def supports_paged_cache(cfg: ModelConfig) -> bool:
    """True iff decode can run over a paged block pool: every cache leaf
    carries adjacent (act_batch, act_kvseq) axes — pure-attention GQA/MLA
    stacks.  SSM/hybrid state caches have no per-position KV; encoder-
    decoder carries a fixed cross cache; vision-prefixed models key their
    cache on non-token inputs.  All of those keep the dense per-slot path.
    """
    if getattr(cfg, "is_encoder_decoder", False):
        return False
    if getattr(cfg, "frontend", "text") == "vision":
        return False
    if stack_plan(cfg)["kind"] != "uniform":
        return False
    leaves = jax.tree.leaves(cache_axes(cfg),
                             is_leaf=lambda x: isinstance(x, tuple))
    for ax in leaves:
        if "act_kvseq" not in ax or "act_batch" not in ax:
            return False
        if ax.index("act_kvseq") != ax.index("act_batch") + 1:
            return False
    return True


def make_paged_pool(cfg: ModelConfig, num_blocks: int, block_size: int,
                    dtype=jnp.bfloat16, abstract: bool = False):
    """Shared physical KV pool for paged decode.

    Structurally this *is* a cache with ``batch == num_blocks`` and
    ``capacity == block_size``: each batch row is one physical block, and
    block tables map (sequence, logical block) -> row.  Every layer leaf
    indexes rows identically, so one block id spans the whole stack and
    allocation is accounted in token blocks, not per-layer bytes.
    """
    if not supports_paged_cache(cfg):
        raise ValueError("architecture has no position-sliceable KV cache")
    return make_cache(cfg, num_blocks, block_size, dtype, abstract)


def _quantized_layer_pool_struct(cfg: ModelConfig, mixer: str, nb: int,
                                 bs: int):
    """One layer of an int8 paged pool: the int8 data leaves plus f32
    ``<name>_scale`` siblings (symmetric, per-block — and per-KV-head for
    leaves that carry a head axis; MLA's latent leaves get one scalar
    scale per block)."""
    base = _layer_cache_struct(cfg, mixer, nb, bs, jnp.int8)
    ax = _cache_axes_one(cfg, mixer)
    out: Dict[str, Any] = {}
    for name, leaf in base.items():
        shape, _ = leaf
        out[name] = leaf
        if "act_heads" in ax[name]:
            heads = shape[ax[name].index("act_heads")]
            out[name + "_scale"] = ((nb, heads), jnp.float32)
        else:
            out[name + "_scale"] = ((nb,), jnp.float32)
    return out


def _quantized_pool_axes_one(cfg: ModelConfig, mixer: str):
    ax = _cache_axes_one(cfg, mixer)
    out: Dict[str, Any] = {}
    for name, a in ax.items():
        out[name] = a
        # scales shard with the KV-head axis under TP (or replicate when
        # the leaf has no head axis, e.g. MLA latents)
        out[name + "_scale"] = (("act_batch", "act_heads")
                                if "act_heads" in a else ("act_batch",))
    return out


def make_quantized_paged_pool(cfg: ModelConfig, num_blocks: int,
                              block_size: int, abstract: bool = False):
    """Int8 paged pool: same layer/stack layout as :func:`make_paged_pool`
    but with int8 block data and f32 per-block scale leaves riding inside
    each layer dict — so scan threading, donation, export/import and byte
    accounting all treat scales as ordinary pool leaves."""
    if not supports_paged_cache(cfg):
        raise ValueError("architecture has no position-sliceable KV cache")
    plan = stack_plan(cfg)
    c: Dict[str, Any] = {}
    if plan["first"]:
        c["first"] = [_quantized_layer_pool_struct(cfg, m, num_blocks,
                                                   block_size)
                      for m, _ in plan["first"]]
    c["stack"] = _stackc(
        _quantized_layer_pool_struct(cfg, plan["mixer"], num_blocks,
                                     block_size),
        plan["n"])
    return _materialize(c, abstract)


def paged_pool_axes(cfg: ModelConfig, kv_dtype: str = "bf16"):
    """Logical sharding axes for a paged pool.  ``bf16`` pools share the
    plain cache axes; ``int8`` pools add the ``*_scale`` leaves."""
    if kv_dtype != "int8":
        return cache_axes(cfg)
    plan = stack_plan(cfg)
    pre = ("layers",)
    ax1 = _quantized_pool_axes_one(cfg, plan["mixer"])
    c: Dict[str, Any] = {"stack": jax.tree.map(
        lambda a: pre + a, ax1, is_leaf=lambda x: isinstance(x, tuple))}
    if plan["first"]:
        c["first"] = [_quantized_pool_axes_one(cfg, m)
                      for m, _ in plan["first"]]
    return c


def pad_cache(cfg: ModelConfig, cache, capacity: int):
    """Pad the KV-sequence dim of every cache entry up to ``capacity``
    (prefill returns caches sized to the prompt; the engine/serve loop
    re-pads them to generation capacity)."""
    axes = cache_axes(cfg)

    def one(arr, ax):
        if "act_kvseq" not in ax:
            return arr
        i = ax.index("act_kvseq")
        if arr.shape[i] >= capacity:
            return arr
        pad = [(0, 0)] * arr.ndim
        pad[i] = (0, capacity - arr.shape[i])
        return jnp.pad(arr, pad)

    # cross-attention caches keep their (fixed) encoder length
    def walk(c, a, path=()):
        if isinstance(c, dict):
            return {k: walk(c[k], a[k], path + (k,)) for k in c}
        if isinstance(c, list):
            return [walk(x, y, path) for x, y in zip(c, a)]
        if path and path[0] == "cross":
            return c
        return one(c, a)

    return walk(cache, axes)


# --------------------------------------------------------------- stacks
def _maybe_remat(cfg, fn, mode):
    if cfg.remat != "none" and mode == "train":
        return jax.checkpoint(fn)
    return fn


def _scan_stack(cfg: ModelConfig, stack_p, x, positions, *, mixer, ffn,
                mode, cache=None, lengths=None, causal=True, enc_out=None,
                cross_cache=None, block_tables=None, lora=None,
                adapter_ids=None):
    """Scan a homogeneous stacked layer group.  ``lora`` leaves carry the
    same leading layer axis as the stacked params, so the scan slices a
    per-layer adapter stack alongside each layer's weights."""
    xs: Dict[str, Any] = {"p": stack_p}
    if cache is not None:
        xs["cache"] = cache
    if cross_cache is not None:
        xs["cross"] = cross_cache
    if lora is not None:
        xs["lora"] = lora
    is_dec = "cross" in stack_p

    def body(carry, layer_in):
        h, aux = carry
        cl = layer_in.get("cache")
        crl = layer_in.get("cross")
        h, nc, ncross, a = apply_layer(
            cfg, layer_in["p"], h, positions, mixer=mixer, ffn=ffn,
            mode=mode, cache=cl, lengths=lengths, causal=causal,
            enc_out=enc_out, cross_cache=crl, block_tables=block_tables,
            lora=layer_in.get("lora"), adapter_ids=adapter_ids)
        ys = {}
        if nc is not None:
            ys["cache"] = nc
        if ncross is not None:
            ys["cross"] = ncross
        return (h, aux + a), ys

    body = _maybe_remat(cfg, body, mode)
    (x, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, ys


def _scan_hybrid(cfg: ModelConfig, stack_p, x, positions, *, mode,
                 cache=None, lengths=None):
    xs: Dict[str, Any] = {"p": stack_p}
    if cache is not None:
        xs["cache"] = cache

    def body(carry, blk):
        h, aux = carry
        ys_cache = {}
        for i in range(cfg.block_period):
            key = f"sub{i}"
            mixer = "gqa" if i in cfg.attn_positions else "mamba"
            ffn = JAMBA_FFN[i % cfg.moe_layer_period == cfg.moe_layer_offset]
            cl = blk["cache"][key] if "cache" in blk else None
            h, nc, _, a = apply_layer(
                cfg, blk["p"][key], h, positions, mixer=mixer, ffn=ffn,
                mode=mode, cache=cl, lengths=lengths)
            aux = aux + a
            if nc is not None:
                ys_cache[key] = nc
        return (h, aux), {"cache": ys_cache} if ys_cache else {}

    body = _maybe_remat(cfg, body, mode)
    (x, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, ys


# --------------------------------------------------------------- inputs
def _embed_lm(cfg: ModelConfig, params, batch):
    """Token (+frontend) embedding for train/prefill.  Returns (x, positions)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    parts = []
    if cfg.frontend == "vision":
        ve = batch["vision_embeds"].astype(tokens_dtype(params))
        parts.append(jnp.einsum(
            "btf,fd->btd", ve, params["embed"]["frontend_proj"]))
    S_txt = tokens.shape[1]
    positions = None
    S_total = S_txt + (parts[0].shape[1] if parts else 0)
    pos = jnp.arange(S_total)[None, :].repeat(B, 0)
    tok_pos = pos[:, S_total - S_txt:]
    parts.append(embed_tokens(cfg, params["embed"], tokens, tok_pos))
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    return x, pos


def tokens_dtype(params):
    return params["embed"]["embed"].dtype


# --------------------------------------------------------------- forward
def _backbone(cfg: ModelConfig, params, x, positions, *, mode,
              cache=None, lengths=None, enc_out=None, block_tables=None,
              lora=None, adapter_ids=None):
    """Run all decoder layers.  Returns (hidden, aux, new_cache).

    ``lora`` is a stacked multi-LoRA adapter tree mirroring the params
    layout (``{"stack": ..., "first": [...]}``, see
    ``serving.adapters.AdapterPool``) and ``adapter_ids`` (B,) selects
    each row's adapter (0 = base).  Only uniform attention stacks support
    it — the same gating as the paged KV path."""
    plan = stack_plan(cfg)
    new_cache: Dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)
    if block_tables is not None and plan["kind"] != "uniform":
        raise ValueError("paged decode requires a uniform attention stack")
    if lora is not None and plan["kind"] != "uniform":
        raise ValueError("multi-LoRA requires a uniform attention stack")

    if plan["kind"] == "uniform":
        if plan["first"]:
            firsts = []
            for i, (m, f) in enumerate(plan["first"]):
                cl = cache["first"][i] if cache is not None else None
                lf = (lora["first"][i]
                      if lora is not None and "first" in lora else None)
                x, nc, _, a = apply_layer(
                    cfg, params["first"][i], x, positions, mixer=m, ffn=f,
                    mode=mode, cache=cl, lengths=lengths,
                    block_tables=block_tables, lora=lf,
                    adapter_ids=adapter_ids)
                aux += a
                firsts.append(nc)
            if firsts and firsts[0] is not None:
                new_cache["first"] = firsts
        x, a, ys = _scan_stack(
            cfg, params["stack"], x, positions, mixer=plan["mixer"],
            ffn=plan["ffn"], mode=mode,
            cache=cache["stack"] if cache is not None else None,
            lengths=lengths, block_tables=block_tables,
            lora=lora.get("stack") if lora is not None else None,
            adapter_ids=adapter_ids)
        aux += a
        if ys and "cache" in ys:
            new_cache["stack"] = ys["cache"]
    elif plan["kind"] == "hybrid":
        x, a, ys = _scan_hybrid(
            cfg, params["stack"], x, positions, mode=mode,
            cache=cache["stack"] if cache is not None else None,
            lengths=lengths)
        aux += a
        if ys and "cache" in ys:
            new_cache["stack"] = ys["cache"]
    else:  # encdec decoder
        dec_cache = cache["dec"] if cache is not None else None
        cross = cache["cross"] if (cache is not None and mode == "decode") \
            else None
        x, a, ys = _scan_stack(
            cfg, params["dec_stack"], x, positions, mixer="gqa", ffn="mlp",
            mode=mode, cache=dec_cache, lengths=lengths, causal=True,
            enc_out=enc_out, cross_cache=cross)
        aux += a
        if ys and "cache" in ys:
            new_cache["dec"] = ys["cache"]
        if ys and "cross" in ys:
            new_cache["cross"] = ys["cross"]
        elif mode == "decode":
            new_cache["cross"] = cache["cross"]  # carry through unchanged

    x = apply_norm(cfg, params["ln_f"], x)
    return x, aux, (new_cache or None)


def _encode(cfg: ModelConfig, params, frames):
    """Whisper encoder over stub frame embeddings (B,F,frontend_dim)."""
    x = jnp.einsum("btf,fd->btd", frames.astype(tokens_dtype(params)),
                   params["embed"]["frontend_proj"])
    F = x.shape[1]
    pos = jnp.arange(F)[None, :]
    x = x + jnp.take(params["embed"]["pos_embed"], pos[0], axis=0)[None]
    x = x.astype(tokens_dtype(params))
    x, _, _ = _scan_stack(cfg, params["enc_stack"], x, pos, mixer="gqa",
                          ffn="mlp", mode="train", causal=False)
    return apply_norm(cfg, params["ln_enc"], x)


# --------------------------------------------------------------- losses
def _chunked_ce(cfg: ModelConfig, params, x, targets, mask,
                chunk: int = 512):
    """Cross-entropy with z-loss, scanning over sequence chunks so the
    (B,S,V) logits are never materialized."""
    B, S, d = x.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    nc = S // chunk
    xs = (jnp.moveaxis(x.reshape(B, nc, chunk, d), 1, 0),
          jnp.moveaxis(targets.reshape(B, nc, chunk), 1, 0),
          jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0))

    def body(carry, inp):
        nll_s, z_s, n_s, correct = carry
        xc, tc, mc = inp
        logits = unembed(cfg, params["embed"], xc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        mcf = mc.astype(jnp.float32)
        nll_s += jnp.sum((lse - ll) * mcf)
        z_s += jnp.sum(jnp.square(lse) * mcf)
        n_s += jnp.sum(mcf)
        correct += jnp.sum((jnp.argmax(logits, -1) == tc) * mcf)
        return (nll_s, z_s, n_s, correct), ()

    body = jax.checkpoint(body)
    zero = jnp.zeros((), jnp.float32)
    (nll, z, n, correct), _ = jax.lax.scan(
        body, (zero, zero, zero, zero), xs)
    return nll, z, n, correct


def train_loss(cfg: ModelConfig, params, batch,
               z_coef: float = 1e-4) -> Tuple[jax.Array, Dict[str, Any]]:
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"])
        tokens = batch["tokens"]
        pos = jnp.arange(tokens.shape[1])[None, :].repeat(tokens.shape[0], 0)
        x = embed_tokens(cfg, params["embed"], tokens, pos)
        x, aux, _ = _backbone(cfg, params, x, pos, mode="train",
                              enc_out=enc_out)
    else:
        x, pos = _embed_lm(cfg, params, batch)
        x, aux, _ = _backbone(cfg, params, x, pos, mode="train")
    nll, z, n, correct = _chunked_ce(
        cfg, params, x, batch["targets"], batch["mask"])
    n = jnp.maximum(n, 1.0)
    n_moe = max(len(cfg.moe_layer_ids()), 1)
    loss = nll / n + z_coef * z / n + cfg.router_aux_coef * aux / n_moe
    metrics = {"loss": nll / n, "z_loss": z / n, "aux_loss": aux / n_moe,
               "accuracy": correct / n, "tokens": n}
    return loss, metrics


def sequence_logprob(cfg: ModelConfig, params, batch) -> jax.Array:
    """Summed log p(target) per sequence under the mask — used by DPO."""
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"])
        tokens = batch["tokens"]
        pos = jnp.arange(tokens.shape[1])[None, :].repeat(tokens.shape[0], 0)
        x = embed_tokens(cfg, params["embed"], tokens, pos)
        x, _, _ = _backbone(cfg, params, x, pos, mode="train",
                            enc_out=enc_out)
    else:
        x, pos = _embed_lm(cfg, params, batch)
        x, _, _ = _backbone(cfg, params, x, pos, mode="train")
    B, S, d = x.shape
    chunk = min(512, S)
    if S % chunk:
        chunk = S
    nc = S // chunk
    xs = (jnp.moveaxis(x.reshape(B, nc, chunk, d), 1, 0),
          jnp.moveaxis(batch["targets"].reshape(B, nc, chunk), 1, 0),
          jnp.moveaxis(batch["mask"].reshape(B, nc, chunk), 1, 0))

    def body(acc, inp):
        xc, tc, mc = inp
        logits = unembed(cfg, params["embed"], xc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((ll - lse) * mc, axis=1), ()

    body = jax.checkpoint(body)
    acc, _ = jax.lax.scan(body, jnp.zeros((B,), jnp.float32), xs)
    return acc


# --------------------------------------------------------------- serving
def prefill(cfg: ModelConfig, params, batch, *, lora=None,
            adapter_ids=None):
    """Returns (next-token logits (B,V), cache, lengths).

    batch: tokens (B,S) (+ vision_embeds / frames), prompt_lengths (B,).
    Cache entries are sized to S (the engine re-pads to capacity).
    ``lora`` + ``adapter_ids`` (B,) apply per-row multi-LoRA adapters
    (id 0 = base) — see :func:`_backbone`.
    """
    lengths = batch["prompt_lengths"]
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"])
        tokens = batch["tokens"]
        pos = jnp.arange(tokens.shape[1])[None, :].repeat(tokens.shape[0], 0)
        x = embed_tokens(cfg, params["embed"], tokens, pos)
        x, aux, cache = _backbone(cfg, params, x, pos, mode="prefill",
                                  enc_out=enc_out)
    else:
        x, pos = _embed_lm(cfg, params, batch)
        x, aux, cache = _backbone(cfg, params, x, pos, mode="prefill",
                                  lora=lora, adapter_ids=adapter_ids)
    # next-token logits at the last valid position of each sequence
    idx = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None].astype(jnp.int32)
                                 .repeat(x.shape[-1], -1), axis=1)[:, 0]
    logits = unembed(cfg, params["embed"], x_last).astype(jnp.float32)
    return logits, cache, aux


def decode_step(cfg: ModelConfig, params, tokens, cache, lengths, *,
                lora=None, adapter_ids=None):
    """One decode step.  tokens (B,1) int32; lengths (B,) counts valid
    entries including this token.  Returns (logits (B,V), new_cache).
    ``lora`` + ``adapter_ids`` (B,) select a per-row LoRA adapter (0 =
    base), so one fused step serves a batch mixing tenants."""
    pos = (lengths - 1)[:, None]
    x = embed_tokens(cfg, params["embed"], tokens, pos)
    x, _, new_cache = _backbone(cfg, params, x, pos, mode="decode",
                                cache=cache, lengths=lengths,
                                lora=lora, adapter_ids=adapter_ids)
    logits = unembed(cfg, params["embed"], x[:, 0]).astype(jnp.float32)
    return logits, new_cache


def decode_step_paged(cfg: ModelConfig, params, tokens, pool, block_tables,
                      lengths, *, lora=None, adapter_ids=None):
    """One decode step over a paged KV pool (see :func:`make_paged_pool`).

    tokens (B,1) int32; block_tables (B, max_blocks) int32 physical block
    ids; lengths (B,) valid tokens including this one.  The new token's KV
    is scattered into block ``block_tables[b, (len-1) // block_size]`` at
    offset ``(len-1) % block_size``; attention reads through the table.
    ``lora`` + ``adapter_ids`` (B,) select a per-row LoRA adapter (0 =
    base).  Returns (logits (B,V), new_pool).
    """
    pos = (lengths - 1)[:, None]
    x = embed_tokens(cfg, params["embed"], tokens, pos)
    x, _, new_pool = _backbone(cfg, params, x, pos, mode="decode",
                               cache=pool, lengths=lengths,
                               block_tables=block_tables,
                               lora=lora, adapter_ids=adapter_ids)
    logits = unembed(cfg, params["embed"], x[:, 0]).astype(jnp.float32)
    return logits, new_pool


def verify_step(cfg: ModelConfig, params, tokens, cache, lengths, *,
                lora=None, adapter_ids=None):
    """Multi-token speculative verify: score a T-token tail in ONE launch.

    tokens (B,T) int32 — ``tokens[:, 0]`` is the last emitted token
    (whose KV is not yet written), ``tokens[:, 1:]`` are drafted
    continuations; lengths (B,) counts valid cache entries *including*
    all T tail tokens, so token t sits at position ``lengths - T + t``.
    Writes KV for all T positions and returns (logits (B,T,V),
    new_cache): ``logits[:, t]`` is the target distribution for the
    token *after* position t — exactly what speculative accept/reject
    compares draft t+1 against (and ``logits[:, -1]`` samples the bonus
    token).  The engine rolls back rejected positions by shrinking
    ``lengths``; stale KV past a row's length is never read (attention
    masks by position) and is overwritten when decoding resumes there.
    For T == 1 this is :func:`decode_step` with a (B,1,V) logit shape.
    """
    T = tokens.shape[1]
    pos = lengths[:, None] - T + jnp.arange(T)[None, :]
    x = embed_tokens(cfg, params["embed"], tokens, pos)
    x, _, new_cache = _backbone(cfg, params, x, pos, mode="decode",
                                cache=cache, lengths=lengths,
                                lora=lora, adapter_ids=adapter_ids)
    logits = unembed(cfg, params["embed"], x).astype(jnp.float32)
    return logits, new_cache


def verify_step_paged(cfg: ModelConfig, params, tokens, pool, block_tables,
                      lengths, *, lora=None, adapter_ids=None):
    """:func:`verify_step` over a paged KV pool: each tail token's KV is
    scattered into its sequence's block (``block_tables[b, pos // bs]``
    at offset ``pos % bs`` — a tail may straddle a block boundary) and
    the T queries attend causally through the table.  Returns
    (logits (B,T,V), new_pool)."""
    T = tokens.shape[1]
    pos = lengths[:, None] - T + jnp.arange(T)[None, :]
    x = embed_tokens(cfg, params["embed"], tokens, pos)
    x, _, new_pool = _backbone(cfg, params, x, pos, mode="decode",
                               cache=pool, lengths=lengths,
                               block_tables=block_tables,
                               lora=lora, adapter_ids=adapter_ids)
    logits = unembed(cfg, params["embed"], x).astype(jnp.float32)
    return logits, new_pool


def supports_speculative(cfg: ModelConfig) -> bool:
    """True iff the engine can run speculative decoding: rollback of
    rejected tokens requires per-position KV that can simply be
    length-masked and overwritten — the same position-sliceable caches
    the paged path needs (uniform GQA/MLA stacks).  SSM/hybrid recurrent
    state cannot be rolled back without checkpointing it per token;
    encoder-decoder and vision-prefixed models keep the plain engine."""
    return supports_paged_cache(cfg)


# --------------------------------------------------------------- specs
def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                cache_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a dry-run cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok_batch(S_txt):
        d: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((B, S_txt), i32)}
        if cfg.frontend == "vision":
            d["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
        if cfg.is_encoder_decoder:
            d["frames"] = jax.ShapeDtypeStruct(
                (B, WHISPER_ENCODER_FRAMES, cfg.frontend_dim), jnp.bfloat16)
        return d

    if shape.kind == "train":
        S_txt = S - cfg.frontend_tokens if cfg.frontend == "vision" else S
        d = tok_batch(S_txt)
        d["targets"] = jax.ShapeDtypeStruct((B, S), i32)
        d["mask"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
        return d
    if shape.kind == "prefill":
        S_txt = S - cfg.frontend_tokens if cfg.frontend == "vision" else S
        d = tok_batch(S_txt)
        d["prompt_lengths"] = jax.ShapeDtypeStruct((B,), i32)
        return d
    # decode
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "lengths": jax.ShapeDtypeStruct((B,), i32),
        "cache": make_cache(cfg, B, S, cache_dtype, abstract=True),
    }


def input_axes(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Logical axes mirroring input_specs (for in_shardings)."""
    base = {
        "tokens": ("act_batch", None),
        "targets": ("act_batch", None),
        "mask": ("act_batch", None),
        "vision_embeds": ("act_batch", None, None),
        "frames": ("act_batch", None, None),
        "prompt_lengths": ("act_batch",),
        "lengths": ("act_batch",),
    }
    specs = input_specs(cfg, shape)
    out: Dict[str, Any] = {}
    for k in specs:
        if k == "cache":
            out[k] = cache_axes(cfg)
        else:
            out[k] = base[k]
    return out
