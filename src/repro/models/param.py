"""Parameter-spec machinery.

Modules describe their parameters once as ``ParamSpec`` trees (shape +
logical axes + initializer); generic functions materialize arrays,
abstract ShapeDtypeStructs, or logical-axis trees from the same source.
Logical axes feed ``repro.parallel.sharding`` which maps them to mesh axes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones | fan_in
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_array(key, spec: ParamSpec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "fan_in":
        fan_in = spec.shape[0] if len(spec.shape) == 1 else int(
            np.prod(spec.shape[:-1]))
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape, jnp.float32)
                * spec.scale).astype(dtype)
    raise ValueError(spec.init)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, key: jax.Array, dtype=jnp.float32):
    """Materialize a ParamSpec tree into arrays (deterministic per path)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_array(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrs)


def abstract_params(specs, dtype=jnp.float32):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs, is_leaf=is_spec)


def param_axes(specs):
    """Same-structure tree of logical-axis tuples."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def stack_specs(specs, n: int, axis_name: str = "layers"):
    """Prepend a stacking dim (for lax.scan over layers)."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes,
                            s.init, s.scale),
        specs, is_leaf=is_spec)


def param_count(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if hasattr(x, "astype") else x, tree)
