"""GQA attention: blockwise (flash-style, scan over KV chunks) for
train/prefill, single-token cached attention for decode.

The blockwise path is the XLA-lowerable oracle used by the dry-run; on TPU
the Pallas kernels in ``repro.kernels.flash_attention`` /
``repro.kernels.decode_attention`` implement the same math (tests assert
allclose between the two).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope
from repro.models.param import ParamSpec
from repro.parallel import sharding

NEG_INF = -1e30


def lora_shift(x, ab, adapter_ids):
    """Batched multi-LoRA delta (the Punica/S-LoRA BGMV oracle).

    x: (B,S,din); ab: stacked adapter pair {"a": (K, din, r),
    "b": (K, r, dout)} with slot 0 all-zero (= base model); adapter_ids:
    (B,) int32 per-sequence adapter indices.  Each row adds its *own*
    adapter's low-rank shift ``x @ A[id] @ B[id]`` (any alpha/rank scale
    is folded into B at registration), so one fused step serves a batch
    mixing several adapters with base-model rows.  Accumulates in fp32
    and casts back so base-row results keep the base dtype.
    """
    a = jnp.take(ab["a"], adapter_ids, axis=0).astype(jnp.float32)
    b = jnp.take(ab["b"], adapter_ids, axis=0).astype(jnp.float32)
    t = jnp.einsum("bsd,bdr->bsr", x.astype(jnp.float32), a)
    return jnp.einsum("bsr,bro->bso", t, b).astype(x.dtype)


def attn_specs(cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = {
        "wq": ParamSpec((d, h * hd), ("fsdp", "tensor"), "fan_in"),
        "wk": ParamSpec((d, kv * hd), ("fsdp", "tensor"), "fan_in"),
        "wv": ParamSpec((d, kv * hd), ("fsdp", "tensor"), "fan_in"),
        "wo": ParamSpec((h * hd, d), ("tensor", "fsdp"), "fan_in"),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((h * hd,), ("tensor",), "zeros")
        s["bk"] = ParamSpec((kv * hd,), ("tensor",), "zeros")
        s["bv"] = ParamSpec((kv * hd,), ("tensor",), "zeros")
    return s


def project_qkv(cfg: ModelConfig, p, x, positions, rope: bool = True,
                lora=None, adapter_ids=None):
    """x: (B,S,d) -> q (B,S,H,hd), k/v (B,S,KV,hd).  ``lora`` holds
    per-target stacked adapter pairs (see :func:`lora_shift`); deltas are
    added to the flat projections, before RoPE — exactly where a merged
    ``W + scale*A@B`` weight would land them."""
    B, S, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"])
    if lora:
        if "wq" in lora:
            q = q + lora_shift(x, lora["wq"], adapter_ids)
        if "wk" in lora:
            k = k + lora_shift(x, lora["wk"], adapter_ids)
        if "wv" in lora:
            v = v + lora_shift(x, lora["wv"], adapter_ids)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, h, hd)
    k = k.reshape(B, S, kv, hd)
    v = v.reshape(B, S, kv, hd)
    if rope and cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg, hd)
        k = apply_rope(k, positions, cfg, hd)
    q = sharding.constrain(q, ("act_batch", "act_qseq", "act_heads", None))
    k = sharding.constrain(k, ("act_batch", "act_kvseq", "act_heads", None))
    v = sharding.constrain(v, ("act_batch", "act_kvseq", "act_heads", None))
    return q, k, v


def _chunked(x, chunk, axis):
    n = x.shape[axis]
    chunk = min(chunk, n)
    if n % chunk:
        chunk = n  # fall back to a single chunk for ragged sizes
    shape = x.shape[:axis] + (n // chunk, chunk) + x.shape[axis + 1:]
    return x.reshape(shape), chunk


def blockwise_attention(q, k, v, *, causal: bool, q_offset=0,
                        kv_chunk: int = 1024,
                        kv_valid_len: Optional[jax.Array] = None):
    """Flash-style attention via lax.scan over KV chunks (fp32 softmax).

    q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd) with H % KV == 0.
    ``q_offset``: global position of q[0] (for causal masking of a sharded
    or cached query block).  ``kv_valid_len``: optional (B,) valid KV
    prefix (padded prefill).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    # operands stay in model dtype (bf16 on the TPU path); accumulation is
    # f32 via preferred_element_type — no materialized f32 cache copies.
    qg = q.reshape(B, Sq, KV, G, hd)
    kc, chunk = _chunked(k, kv_chunk, 1)                       # (B,N,C,KV,hd)
    vc, _ = _chunked(v, kv_chunk, 1)
    nchunks = kc.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qpos = q_offset + jnp.arange(Sq)

    def body(carry, inp):
        m, l, acc = carry
        kch, vch, ci = inp
        kvpos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kch,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask &= qpos[:, None] >= kvpos[None, :]
        if kv_valid_len is not None:
            mask &= kvpos[None, None, :] < kv_valid_len[:, None, None]
            s = jnp.where(mask[:, None, None] if mask.ndim == 3 else mask,
                          s, NEG_INF)
        else:
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p.astype(vch.dtype), vch,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    ks = jnp.moveaxis(kc, 1, 0)  # (N,B,C,KV,hd)
    vs = jnp.moveaxis(vc, 1, 0)
    # flash-style backward: recompute chunk scores instead of saving the
    # (B,KV,G,Sq,chunk) probability tensors per chunk.  The named scope
    # marks the kernel interior for the kernel-aware roofline (the Pallas
    # flash kernel keeps these tensors in VMEM on TPU).
    with jax.named_scope("flash_kernel_scope"):
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(body), (m0, l0, a0),
            (ks, vs, jnp.arange(nchunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd)
    return out


def naive_attention(q, k, v, *, causal: bool, q_offset=0,
                    kv_valid_len=None):
    """Materialized-scores oracle (tests/tiny models only)."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(hd)
    qpos = q_offset + jnp.arange(Sq)
    kvpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos[:, None] >= kvpos[None, :]
    s = jnp.where(mask, s, NEG_INF)
    if kv_valid_len is not None:
        vm = kvpos[None, :] < kv_valid_len[:, None]          # (B,Skv)
        s = jnp.where(vm[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd)


def decode_attention(q, k_cache, v_cache, lengths):
    """One-token attention against a cache.

    q: (B,1,H,hd); k_cache/v_cache: (B,Smax,KV,hd); lengths: (B,) number of
    valid cache entries (the new token's KV must already be written).
    """
    B, _, H, hd = q.shape
    _, Smax, KV, _ = k_cache.shape
    G = H // KV
    # cache operands stay bf16 (no full-cache f32 materialization); f32
    # accumulation via preferred_element_type (the Pallas decode kernel
    # implements the same contract in VMEM)
    qg = q.reshape(B, KV, G, hd).astype(k_cache.dtype)
    # interior marked for the kernel-aware roofline: the Pallas
    # flash-decode kernel keeps scores/probabilities in VMEM
    with jax.named_scope("flash_decode_kernel_scope"):
        s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                       preferred_element_type=jnp.float32)
        s = s / jnp.sqrt(hd)
        s = sharding.constrain(
            s, ("act_batch", "act_heads", None, "act_kvseq"))
        valid = jnp.arange(Smax)[None, :] < lengths[:, None]  # (B,Smax)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bkgs,bskd->bkgd",
                       (p / jnp.maximum(l, 1e-30)).astype(v_cache.dtype),
                       v_cache, preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd)


def verify_attention(q, k_cache, v_cache, lengths):
    """Multi-token tail attention against a cache (speculative verify).

    q: (B,S,H,hd) — the S newest tokens of each sequence, whose KV must
    already be written; lengths: (B,) valid cache entries *including* all
    S tail tokens, so query t of row b sits at absolute position
    ``lengths[b] - S + t`` and attends causally to positions ``<=`` its
    own.  For S == 1 this is exactly :func:`decode_attention`; the
    single-token path is kept separate so its jit signature (and the
    engine's step-for-step numerics) are untouched.
    """
    B, S, H, hd = q.shape
    _, Smax, KV, _ = k_cache.shape
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(k_cache.dtype)
    with jax.named_scope("flash_verify_kernel_scope"):
        s = jnp.einsum("bskgd,bmkd->bkgsm", qg, k_cache,
                       preferred_element_type=jnp.float32)
        s = s / jnp.sqrt(hd)
        qpos = lengths[:, None] - S + jnp.arange(S)[None, :]      # (B,S)
        valid = jnp.arange(Smax)[None, None, :] <= qpos[:, :, None]
        s = jnp.where(valid[:, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bkgsm,bmkd->bkgsd",
                       (p / jnp.maximum(l, 1e-30)).astype(v_cache.dtype),
                       v_cache, preferred_element_type=jnp.float32)
    return jnp.moveaxis(o, 3, 1).reshape(B, S, H, hd)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths):
    """One-token attention against a *paged* cache (jnp oracle).

    q: (B,1,H,hd); k_pool/v_pool: (num_blocks, block_size, KV, hd) shared
    physical pool; block_tables: (B, max_blocks) int32 physical block ids;
    lengths: (B,) valid tokens (the new token's KV must already be
    written).  Gathers each sequence's blocks into logical order and runs
    the dense decode math — the Pallas kernel
    (``repro.kernels.paged_attention``) implements the same contract on
    TPU by walking the table in SMEM instead of materializing the gather.
    """
    B = q.shape[0]
    _, blk, KV, hd = k_pool.shape
    W = block_tables.shape[1]
    # the gather moves block/position dims only; pin the head axis so a
    # TP partitioner keeps the gathered sequence head-sharded like the
    # pool (no-op without active sharding rules)
    k_seq = sharding.constrain(
        k_pool[block_tables].reshape(B, W * blk, KV, hd),
        ("act_batch", "act_kvseq", "act_heads", None))
    v_seq = sharding.constrain(
        v_pool[block_tables].reshape(B, W * blk, KV, hd),
        ("act_batch", "act_kvseq", "act_heads", None))
    return decode_attention(q, k_seq, v_seq, lengths)


def paged_verify_attention(q, k_pool, v_pool, block_tables, lengths):
    """Multi-token tail attention against a *paged* cache (jnp oracle).

    q: (B,S,H,hd) — the S newest tokens, KV already scattered into the
    pool; lengths: (B,) valid tokens including all S.  Gathers each
    sequence's blocks into logical order and runs :func:`verify_attention`
    — the Pallas kernel (``repro.kernels.paged_attention.paged_verify``)
    implements the same contract on TPU by walking the table in SMEM.
    """
    B = q.shape[0]
    _, blk, KV, hd = k_pool.shape
    W = block_tables.shape[1]
    k_seq = sharding.constrain(
        k_pool[block_tables].reshape(B, W * blk, KV, hd),
        ("act_batch", "act_kvseq", "act_heads", None))
    v_seq = sharding.constrain(
        v_pool[block_tables].reshape(B, W * blk, KV, hd),
        ("act_batch", "act_kvseq", "act_heads", None))
    return verify_attention(q, k_seq, v_seq, lengths)


def paged_decode_attention_int8(q, k_pool, v_pool, k_scale, v_scale,
                                block_tables, lengths):
    """One-token attention against an *int8* paged cache (jnp oracle).

    k_pool/v_pool: (num_blocks, block_size, KV, hd) int8; k_scale/v_scale:
    (num_blocks, KV) f32 symmetric per-block-per-head scales.  Gathers and
    dequantizes each sequence's blocks, then runs the dense decode math —
    the fused Pallas kernel keeps the HBM read int8 and dequantizes
    in-register instead.
    """
    B = q.shape[0]
    _, blk, KV, hd = k_pool.shape
    W = block_tables.shape[1]
    k_seq = sharding.constrain(
        (k_pool[block_tables].astype(jnp.float32)
         * k_scale[block_tables][:, :, None, :, None]
         ).reshape(B, W * blk, KV, hd),
        ("act_batch", "act_kvseq", "act_heads", None))
    v_seq = sharding.constrain(
        (v_pool[block_tables].astype(jnp.float32)
         * v_scale[block_tables][:, :, None, :, None]
         ).reshape(B, W * blk, KV, hd),
        ("act_batch", "act_kvseq", "act_heads", None))
    return decode_attention(q, k_seq, v_seq, lengths)


def paged_verify_attention_int8(q, k_pool, v_pool, k_scale, v_scale,
                                block_tables, lengths):
    """Multi-token tail attention against an *int8* paged cache (jnp
    oracle); same contract as :func:`paged_verify_attention` with
    gather-time dequantization."""
    B = q.shape[0]
    _, blk, KV, hd = k_pool.shape
    W = block_tables.shape[1]
    k_seq = sharding.constrain(
        (k_pool[block_tables].astype(jnp.float32)
         * k_scale[block_tables][:, :, None, :, None]
         ).reshape(B, W * blk, KV, hd),
        ("act_batch", "act_kvseq", "act_heads", None))
    v_seq = sharding.constrain(
        (v_pool[block_tables].astype(jnp.float32)
         * v_scale[block_tables][:, :, None, :, None]
         ).reshape(B, W * blk, KV, hd),
        ("act_batch", "act_kvseq", "act_heads", None))
    return verify_attention(q, k_seq, v_seq, lengths)


def quantized_scatter_token(pool, scales, x_t, pb, off):
    """Scatter one token's values into an int8 paged pool leaf.

    pool: (num_blocks, blk, *inner) int8; scales: (num_blocks,) or
    (num_blocks, heads) f32 — one symmetric scale per block (per head when
    the leaf has a head axis, reducing over everything else); x_t:
    (B, *inner) new values; pb/off: (B,) physical block and in-block slot.

    The block scale is a running max: if the new token raises it, the
    block's resident rows are requantized under the wider scale (gather
    one block per row, rescale, scatter back).  When the scale is
    unchanged the requant ratio is exactly 1.0, integers round to
    themselves, and resident codes are bit-identical — so appends within
    a block's existing dynamic range never disturb earlier tokens.
    Duplicate ``pb`` rows only occur for inert slots parked on the
    reserved null block 0, which is never read.
    """
    blk = pool.shape[1]
    per_head = scales.ndim == 2
    x = x_t.astype(jnp.float32)
    q_old = pool[pb].astype(jnp.float32)            # (B, blk, *inner)
    s_old = scales[pb]                              # (B,) or (B, heads)
    if per_head:
        s_tok = jnp.max(jnp.abs(x), axis=-1) / 127.0      # (B, heads)
        bcast = (slice(None), None, slice(None), None)    # -> (B,1,h,1)
        tokb = (slice(None), slice(None), None)           # -> (B,h,1)
    else:
        s_tok = jnp.max(jnp.abs(x), axis=-1) / 127.0      # (B,)
        bcast = (slice(None), None, None)                 # -> (B,1,1)
        tokb = (slice(None), None)                        # -> (B,1)
    s_new = jnp.maximum(s_old, s_tok)
    denom = jnp.maximum(s_new, 1e-12)
    ratio = jnp.where(s_new > 0, s_old / denom, 0.0)
    q_res = jnp.round(q_old * ratio[bcast])
    q_tok = jnp.clip(jnp.round(x / denom[tokb]), -127, 127)
    sel = jnp.arange(blk) == off[:, None]                 # (B, blk)
    sel = sel.reshape(sel.shape + (1,) * (pool.ndim - 2))
    blk_new = jnp.where(sel, q_tok[:, None], q_res)
    pool = pool.at[pb].set(blk_new.astype(pool.dtype))
    scales = scales.at[pb].set(s_new)
    return pool, scales


def attention_block(cfg: ModelConfig, p, x, positions, *,
                    mode: str, cache=None, lengths=None,
                    kv_valid_len=None, causal: bool = True,
                    block_tables=None, lora=None, adapter_ids=None):
    """Full attention sublayer.  Returns (out (B,S,d), new_cache or None).

    mode: "train" | "prefill" | "decode".
    cache (decode): dict(k=(B,Smax,KV,hd), v=...); ``lengths`` (B,) counts
    valid entries *including* the token being decoded.  With
    ``block_tables`` (B, max_blocks), cache leaves are instead pool-shaped
    (num_blocks, block_size, KV, hd) and the new token's KV is scattered
    into its sequence's current block.  ``lora`` + ``adapter_ids`` apply
    per-row multi-LoRA shifts to the targeted projections (see
    :func:`lora_shift`); adapter id 0 is the base model.
    """
    B = x.shape[0]
    dt = x.dtype
    if mode in ("train", "prefill"):
        q, k, v = project_qkv(cfg, p, x, positions,
                              lora=lora, adapter_ids=adapter_ids)
        if cfg.attn_impl == "naive":
            o = naive_attention(q, k, v, causal=causal,
                                kv_valid_len=kv_valid_len)
        else:
            o = blockwise_attention(q, k, v, causal=causal,
                                    kv_valid_len=kv_valid_len)
        o = sharding.constrain(o, ("act_batch", "act_qseq", "act_heads", None))
        new_cache = None
        if mode == "prefill":
            new_cache = {"k": k.astype(dt), "v": v.astype(dt)}
    elif block_tables is not None and "k_scale" in cache:
        # int8 pool: symmetric per-block-per-head scales, quantized at
        # write time (running-max block scale, see
        # :func:`quantized_scatter_token`); attention reads dequantize on
        # gather (the fused Pallas kernel dequantizes in-register)
        q, k, v = project_qkv(cfg, p, x, positions,
                              lora=lora, adapter_ids=adapter_ids)
        S = q.shape[1]
        blk = cache["k"].shape[1]
        k_pool, v_pool = cache["k"], cache["v"]
        k_sc, v_sc = cache["k_scale"], cache["v_scale"]
        for t in range(S):
            idx = lengths - S + t
            pb = jnp.take_along_axis(block_tables, (idx // blk)[:, None],
                                     axis=1)[:, 0]
            off = idx % blk
            k_pool, k_sc = quantized_scatter_token(k_pool, k_sc,
                                                   k[:, t], pb, off)
            v_pool, v_sc = quantized_scatter_token(v_pool, v_sc,
                                                   v[:, t], pb, off)
        k_pool = sharding.constrain(
            k_pool, ("act_batch", "act_kvseq", "act_heads", None))
        v_pool = sharding.constrain(
            v_pool, ("act_batch", "act_kvseq", "act_heads", None))
        k_sc = sharding.constrain(k_sc, ("act_batch", "act_heads"))
        v_sc = sharding.constrain(v_sc, ("act_batch", "act_heads"))
        if S == 1:
            o = paged_decode_attention_int8(q, k_pool, v_pool, k_sc, v_sc,
                                            block_tables, lengths)
        else:
            o = paged_verify_attention_int8(q, k_pool, v_pool, k_sc, v_sc,
                                            block_tables, lengths)
        new_cache = {"k": k_pool, "v": v_pool,
                     "k_scale": k_sc, "v_scale": v_sc}
    elif block_tables is not None:
        q, k, v = project_qkv(cfg, p, x, positions,
                              lora=lora, adapter_ids=adapter_ids)
        S = q.shape[1]
        blk = cache["k"].shape[1]
        k_cache, v_cache = cache["k"], cache["v"]
        # scatter the S tail tokens' KV (S > 1 = speculative verify; a
        # tail may straddle a block boundary, so resolve each position's
        # physical block separately — S is a static jit constant).  Inert
        # rows have lengths == 1, so their (clamped-negative) positions
        # resolve to table column 0 == the reserved null block.
        for t in range(S):
            idx = lengths - S + t
            pb = jnp.take_along_axis(block_tables, (idx // blk)[:, None],
                                     axis=1)[:, 0]
            off = idx % blk
            k_cache = k_cache.at[pb, off].set(
                k[:, t].astype(k_cache.dtype))
            v_cache = v_cache.at[pb, off].set(
                v[:, t].astype(v_cache.dtype))
        # pool leaves are (num_blocks, block_size, KV, hd): the block and
        # in-block dims sit in the (act_batch, act_kvseq) slots, which a
        # serving rule set maps to None — so this pins exactly the head
        # axis and the updated pool keeps the input pool's sharding
        k_cache = sharding.constrain(
            k_cache, ("act_batch", "act_kvseq", "act_heads", None))
        v_cache = sharding.constrain(
            v_cache, ("act_batch", "act_kvseq", "act_heads", None))
        if S == 1:
            o = paged_decode_attention(q, k_cache, v_cache, block_tables,
                                       lengths)
        else:
            o = paged_verify_attention(q, k_cache, v_cache, block_tables,
                                       lengths)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        q, k, v = project_qkv(cfg, p, x, positions,
                              lora=lora, adapter_ids=adapter_ids)
        S = q.shape[1]
        idx = lengths - S  # slot of the first (oldest) tail token
        k_cache = jax.vmap(lambda c, kk, i: jax.lax.dynamic_update_slice(
            c, kk, (i, 0, 0)))(cache["k"], k.astype(cache["k"].dtype), idx)
        v_cache = jax.vmap(lambda c, vv, i: jax.lax.dynamic_update_slice(
            c, vv, (i, 0, 0)))(cache["v"], v.astype(cache["v"].dtype), idx)
        k_cache = sharding.constrain(
            k_cache, ("act_batch", "act_kvseq", "act_heads", None))
        v_cache = sharding.constrain(
            v_cache, ("act_batch", "act_kvseq", "act_heads", None))
        if S == 1:
            o = decode_attention(q, k_cache, v_cache, lengths)
        else:
            o = verify_attention(q, k_cache, v_cache, lengths)
        new_cache = {"k": k_cache, "v": v_cache}
    o2 = o.reshape(B, o.shape[1], -1).astype(dt)
    out = jnp.einsum("bsq,qd->bsd", o2, p["wo"])
    if lora and "wo" in lora:
        out = out + lora_shift(o2, lora["wo"], adapter_ids)
    out = sharding.constrain(out, ("act_batch", "act_qseq", None))
    return out, new_cache


def cross_attention_block(cfg: ModelConfig, p, x, kv_cache):
    """Decoder cross-attention against precomputed encoder K/V."""
    B, S, _ = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, h, hd)
    if S == 1:
        lengths = jnp.full((B,), kv_cache["k"].shape[1], jnp.int32)
        o = decode_attention(q, kv_cache["k"], kv_cache["v"], lengths)
    else:
        o = blockwise_attention(q, kv_cache["k"], kv_cache["v"], causal=False)
    o = o.reshape(B, S, -1).astype(x.dtype)
    return jnp.einsum("bsq,qd->bsd", o, p["wo"])


def cross_kv(cfg: ModelConfig, p, enc_out):
    """Precompute encoder K/V for cross attention."""
    B, S, _ = enc_out.shape
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,dq->bsq", enc_out, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", enc_out, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return {"k": k.reshape(B, S, kv, hd), "v": v.reshape(B, S, kv, hd)}
