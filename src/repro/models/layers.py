"""Shared layers: norms, rotary embeddings, gated MLP, embeddings."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamSpec
from repro.parallel import sharding


# ----------------------------------------------------------------- norms
def norm_specs(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    s = {"scale": ParamSpec((d,), (None,), "ones")}
    if cfg.norm == "layernorm":
        s["bias"] = ParamSpec((d,), (None,), "zeros")
    return s


def apply_norm(cfg: ModelConfig, p, x):
    dt = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(dt)


# ----------------------------------------------------------------- rope
def rope_frequencies(cfg: ModelConfig, head_dim: int) -> jax.Array:
    rot = int(head_dim * cfg.rope_fraction)
    rot -= rot % 2
    inv = 1.0 / (cfg.rope_theta ** (
        jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv  # (rot/2,)


def apply_rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig,
               head_dim: Optional[int] = None) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = head_dim or x.shape[-1]
    rot = int(hd * cfg.rope_fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    inv = rope_frequencies(cfg, hd)                        # (rot/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]                       # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.concatenate([o1.astype(x.dtype), o2.astype(x.dtype), xp], -1)


# ----------------------------------------------------------------- mlp
def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "gelu" and cfg.norm == "layernorm":  # whisper-style 2-proj
        return {
            "up": ParamSpec((d, ff), ("fsdp", "tensor"), "fan_in"),
            "up_b": ParamSpec((ff,), ("tensor",), "zeros"),
            "down": ParamSpec((ff, d), ("tensor", "fsdp"), "fan_in"),
            "down_b": ParamSpec((d,), (None,), "zeros"),
        }
    return {
        "gate": ParamSpec((d, ff), ("fsdp", "tensor"), "fan_in"),
        "up": ParamSpec((d, ff), ("fsdp", "tensor"), "fan_in"),
        "down": ParamSpec((ff, d), ("tensor", "fsdp"), "fan_in"),
    }


def apply_mlp(cfg: ModelConfig, p, x):
    """x: (..., d_model)."""
    ff_axes = ("act_batch",) + (None,) * (x.ndim - 2) + ("act_ff",)
    if "gate" in p:
        g = jnp.einsum("...d,df->...f", x, p["gate"])
        u = jnp.einsum("...d,df->...f", x, p["up"])
        g = sharding.constrain(g, ff_axes)
        act = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jnp.einsum("...d,df->...f", x, p["up"]) + p["up_b"]
        h = sharding.constrain(h, ff_axes)
        h = jax.nn.gelu(h)
    y = jnp.einsum("...f,fd->...d", h, p["down"])
    # sequence-sharded output lets SPMD reduce-scatter the partial sums
    y = sharding.constrain(
        y, ("act_batch",) + ("act_qseq",) * (y.ndim - 2) + (None,))
    if "down_b" in p:
        y = y + p["down_b"]
    return y


# ----------------------------------------------------------------- embed
def embedding_specs(cfg: ModelConfig):
    # embed is sharded only on d_model (FSDP) so token lookup stays local;
    # the unembed projection is TP-sharded on (padded) vocab.
    s = {"embed": ParamSpec((cfg.vocab_padded, cfg.d_model),
                            (None, "fsdp"), "normal", 0.02)}
    if not cfg.tie_embeddings:
        s["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_padded),
                                 ("fsdp", "tensor"), "fan_in")
    if cfg.pos_emb == "learned":
        s["pos_embed"] = ParamSpec((cfg.max_position, cfg.d_model),
                                   (None, "fsdp"), "normal", 0.02)
    if cfg.frontend_dim:
        s["frontend_proj"] = ParamSpec((cfg.frontend_dim, cfg.d_model),
                                       (None, "fsdp"), "fan_in")
    return s


def embed_tokens(cfg: ModelConfig, p, tokens, positions=None):
    # mode="clip" keeps the gather in the table dtype (the default "fill"
    # path materializes an f32 copy of the whole table)
    x = jnp.take(p["embed"], tokens, axis=0, mode="clip")
    if cfg.pos_emb == "learned":
        assert positions is not None
        x = x + jnp.take(p["pos_embed"], positions, axis=0,
                         mode="clip").astype(x.dtype)
    return x


def unembed(cfg: ModelConfig, p, x):
    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    # ZeRO gather of the fsdp-sharded d_model dim (weight shards are tiny
    # next to batch-gathered activations)
    w = sharding.constrain(w, (None, "tensor") if not cfg.tie_embeddings
                           else (None, None))
    logits = jnp.einsum("...d,dv->...v", x, w)
    if cfg.vocab_padded != cfg.vocab_size:
        # mask pad-vocab logits so loss/sampling never select them
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return sharding.constrain(
        logits, ("act_batch",) + (None,) * (logits.ndim - 2) + ("act_vocab",))
