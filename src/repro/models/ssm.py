"""Mamba2 / SSD (state-space duality, arXiv:2405.21060).

Chunked SSD: within-chunk quadratic attention-like term (MXU-friendly
matmuls) + inter-chunk linear state recurrence (small scan).  This jnp
implementation is the oracle; ``repro.kernels.ssd`` provides the Pallas
TPU kernel of the chunk computation.

Tensor convention: x (B,L,H,P) head inputs, dt (B,L,H), A (H,) negative,
Bmat/Cmat (B,L,N) single-group, initial/final state (B,H,P,N).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamSpec
from repro.parallel import sharding


def ssd_chunked(x, dt, A, Bmat, Cmat, chunk: int,
                init_state: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,H,P), final_state (B,H,P,N)).  fp32 internally."""
    Bsz, L, H, P = x.shape
    N = Bmat.shape[-1]
    chunk = min(chunk, L)
    if L % chunk:
        chunk = L
    nc = L // chunk

    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    # sequence-sized tensors stay in model dtype; the small decay math
    # (B,L,H) is f32 and contractions accumulate f32
    xd = (x * dt.astype(x.dtype)[..., None]).reshape(Bsz, nc, chunk, H, P)
    dA = (dtf * Af).reshape(Bsz, nc, chunk, H)           # negative decays
    Bc = Bmat.reshape(Bsz, nc, chunk, N)
    Cc = Cmat.reshape(Bsz, nc, chunk, N)

    with jax.named_scope("ssd_kernel_scope"):
        dA_cs = jnp.cumsum(dA, axis=2)                    # (B,nc,Q,H)
        seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

        # ---- diagonal (within-chunk) term ----
        scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc,
                            preferred_element_type=jnp.float32)
        M = scores[..., None] * Lmat                      # (B,nc,i,j,H)
        y_diag = jnp.einsum("bcijh,bcjhp->bcihp", M.astype(xd.dtype), xd,
                            preferred_element_type=jnp.float32)

        # ---- chunk-final states ----
        decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (B,nc,Q,H)
        xdd = xd * decay_to_end.astype(xd.dtype)[..., None]
        S_c = jnp.einsum("bcqn,bcqhp->bchpn", Bc, xdd,
                         preferred_element_type=jnp.float32)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])             # (B,nc,H)
    h0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def body(h, inp):
        s_c, dec = inp                                    # (B,H,P,N), (B,H)
        h_out = h                                         # state entering chunk
        h_new = h * dec[..., None, None] + s_c
        return h_new, h_out

    s_seq = jnp.moveaxis(S_c, 1, 0)                       # (nc,B,H,P,N)
    d_seq = jnp.moveaxis(chunk_decay, 1, 0)               # (nc,B,H)
    h_final, h_in = jax.lax.scan(body, h0, (s_seq, d_seq))
    h_in = jnp.moveaxis(h_in, 0, 1)                       # (B,nc,H,P,N)

    # ---- off-diagonal contribution from carried state ----
    decay_from_start = jnp.exp(dA_cs)                     # (B,nc,Q,H)
    y_off = jnp.einsum("bcqn,bchpn->bcqhp", Cc,
                       h_in.astype(Cc.dtype),
                       preferred_element_type=jnp.float32)
    y_off = y_off * decay_from_start[..., None]

    y = (y_diag + y_off).reshape(Bsz, L, H, P)
    return y, h_final


def ssd_decode_step(x, dt, A, Bmat, Cmat, state):
    """One token: x (B,H,P), dt (B,H), Bmat/Cmat (B,N), state (B,H,P,N)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dec = jnp.exp(dtf * A.astype(jnp.float32))            # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", xf * dtf[..., None],
                     Bmat.astype(jnp.float32))
    new_state = state.astype(jnp.float32) * dec[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cmat.astype(jnp.float32))
    return y, new_state


# ---------------------------------------------------------------- block
def mamba_specs(cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm_d_inner
    H, N, W = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_conv_width
    return {
        "wx": ParamSpec((d, di), ("fsdp", "tensor"), "fan_in"),
        "wz": ParamSpec((d, di), ("fsdp", "tensor"), "fan_in"),
        "wB": ParamSpec((d, N), ("fsdp", None), "fan_in"),
        "wC": ParamSpec((d, N), ("fsdp", None), "fan_in"),
        "wdt": ParamSpec((d, H), ("fsdp", None), "fan_in"),
        "dt_bias": ParamSpec((H,), (None,), "zeros"),
        "A_log": ParamSpec((H,), (None,), "zeros"),
        "D": ParamSpec((H,), (None,), "ones"),
        "conv_w": ParamSpec((W, di + 2 * N), (None, None), "normal", 0.1),
        "conv_b": ParamSpec((di + 2 * N,), (None,), "zeros"),
        "gate_norm": ParamSpec((di,), (None,), "ones"),
        "wo": ParamSpec((di, d), ("tensor", "fsdp"), "fan_in"),
    }


def _causal_conv(xBC, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv, width W.  xBC: (B,L,C).
    conv_state: (B,W-1,C) previous inputs (decode) or None (train)."""
    W = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros_like(xBC[:, : W - 1])
    else:
        pad = conv_state.astype(xBC.dtype)
    full = jnp.concatenate([pad, xBC], axis=1)
    y = sum(full[:, i: i + xBC.shape[1]] * conv_w[i] for i in range(W))
    y = jax.nn.silu(y + conv_b)
    new_state = full[:, -(W - 1):] if W > 1 else None
    return y, new_state


def _gated_norm(y, z, scale, eps):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    r = yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + eps)
    return r * scale.astype(jnp.float32)


def mamba_block(cfg: ModelConfig, p, x, *, mode: str, cache=None):
    """x: (B,S,d).  cache: {"conv": (B,W-1,di+2N), "ssd": (B,H,P,N)}.
    Returns (out, new_cache)."""
    B, S, d = x.shape
    di, H, N = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_state
    P = cfg.ssm_head_dim
    dt_in = x.dtype

    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xs = jnp.einsum("bsd,de->bse", x, p["wx"])
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xBC = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_state = cache["conv"] if mode == "decode" else None
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xs, Bm, Cm = xBC[..., :di], xBC[..., di:di + N], xBC[..., di + N:]

    xh = xs.reshape(B, S, H, P)
    xh = sharding.constrain(xh, ("act_batch", None, "act_ssm_heads", None))

    if mode == "decode":
        assert S == 1
        y, new_ssd = ssd_decode_step(
            xh[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0], cache["ssd"])
        y = y[:, None]
        new_cache = {"conv": new_conv, "ssd": new_ssd}
    else:
        y, final_state = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": new_conv, "ssd": final_state}
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(B, S, di)
    y = _gated_norm(y, z, p["gate_norm"], cfg.norm_eps).astype(dt_in)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"])
    return out, new_cache
