"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Train/prefill decompress the 512-d latent into per-head K/V and run
standard attention; decode uses the *absorbed-weight* formulation so the
KV cache stores only (kv_lora_rank + qk_rope_head_dim) floats per token —
the feature that makes 32k-decode caches ~9x smaller than GQA here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (NEG_INF, blockwise_attention, lora_shift,
                                    naive_attention)
from repro.models.layers import apply_rope
from repro.models.param import ParamSpec
from repro.parallel import sharding


def mla_specs(cfg: ModelConfig):
    d, h = cfg.d_model, cfg.num_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank
    s = {
        "wdkv": ParamSpec((d, kvl + rope), ("fsdp", None), "fan_in"),
        "kv_norm": ParamSpec((kvl,), (None,), "ones"),
        "wuk": ParamSpec((kvl, h * nope), ("fsdp", "tensor"), "fan_in"),
        "wuv": ParamSpec((kvl, h * vd), ("fsdp", "tensor"), "fan_in"),
        "wo": ParamSpec((h * vd, d), ("tensor", "fsdp"), "fan_in"),
    }
    if cfg.q_lora_rank:
        s["wdq"] = ParamSpec((d, cfg.q_lora_rank), ("fsdp", None), "fan_in")
        s["q_norm"] = ParamSpec((cfg.q_lora_rank,), (None,), "ones")
        s["wuq"] = ParamSpec((cfg.q_lora_rank, h * (nope + rope)),
                             ("fsdp", "tensor"), "fan_in")
    else:
        s["wq"] = ParamSpec((d, h * (nope + rope)), ("fsdp", "tensor"),
                            "fan_in")
    return s


def _rmsnorm(x, scale, eps):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _project_q(cfg, p, x, positions, lora=None, adapter_ids=None):
    B, S, _ = x.shape
    h = cfg.num_heads
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        cq = _rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wdq"]),
                      p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rq->bsq", cq, p["wuq"])
        if lora and "wuq" in lora:
            q = q + lora_shift(cq, lora["wuq"], adapter_ids)
    else:
        q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
        if lora and "wq" in lora:
            q = q + lora_shift(x, lora["wq"], adapter_ids)
    q = q.reshape(B, S, h, nope + rope)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = apply_rope(q_pe, positions, cfg, rope)
    return q_nope, q_pe


def _latent_kv(cfg, p, x, positions):
    """Compressed c_kv (B,S,kvl) + rope key k_pe (B,S,rope)."""
    kvl, rope = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    dkv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])
    c_kv = _rmsnorm(dkv[..., :kvl], p["kv_norm"], cfg.norm_eps)
    k_pe = dkv[..., kvl:][:, :, None, :]  # (B,S,1,rope)
    k_pe = apply_rope(k_pe, positions, cfg, rope)[:, :, 0, :]
    c_kv = sharding.constrain(c_kv, ("act_batch", "act_kvseq", None))
    k_pe = sharding.constrain(k_pe, ("act_batch", "act_kvseq", None))
    return c_kv, k_pe


def mla_block(cfg: ModelConfig, p, x, positions, *, mode: str,
              cache=None, lengths=None, block_tables=None, lora=None,
              adapter_ids=None):
    """Returns (out, new_cache).  cache: {"ckv": (B,Smax,kvl),
    "kpe": (B,Smax,rope)} — or, with ``block_tables`` (B, max_blocks),
    pool-shaped {"ckv": (num_blocks, block_size, kvl), ...} with the new
    latent scattered into the sequence's current block.

    ``lora`` + ``adapter_ids`` add per-row multi-LoRA shifts.  Train/
    prefill applies them to the decompressed projections directly; decode
    folds them into the *absorbed-weight* formulation: a ``wuk`` adapter
    shifts the latent query (``q_lat += (q_nope @ B_k^T) @ A_k^T``) and a
    ``wuv`` adapter shifts the output (``o += (ctx @ A_v) @ B_v``), which
    is algebraically identical to decoding with merged weights.
    """
    B, S, _ = x.shape
    h = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank
    dt = x.dtype
    scale_dim = nope + rope

    q_nope, q_pe = _project_q(cfg, p, x, positions, lora, adapter_ids)
    c_kv, k_pe = _latent_kv(cfg, p, x, positions)

    if mode in ("train", "prefill"):
        # Decompress and run standard MHA (G=1) with concatenated heads.
        k_nope = jnp.einsum("bsr,rq->bsq", c_kv, p["wuk"])
        v = jnp.einsum("bsr,rq->bsq", c_kv, p["wuv"])
        if lora and "wuk" in lora:
            k_nope = k_nope + lora_shift(c_kv, lora["wuk"], adapter_ids)
        if lora and "wuv" in lora:
            v = v + lora_shift(c_kv, lora["wuv"], adapter_ids)
        k_nope = k_nope.reshape(B, S, h, nope)
        v = v.reshape(B, S, h, vd)
        q = jnp.concatenate([q_nope, q_pe], -1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, h, rope))],
            -1)
        # pad v to qk head size so one attention call handles both
        vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, scale_dim - vd)))
        attn = (blockwise_attention if cfg.attn_impl == "blockwise"
                else naive_attention)
        o = attn(q, k, vpad, causal=True)[..., :vd]
        o = o.reshape(B, S, h * vd).astype(dt)
        out = jnp.einsum("bsq,qd->bsd", o, p["wo"])
        if lora and "wo" in lora:
            out = out + lora_shift(o, lora["wo"], adapter_ids)
        new_cache = None
        if mode == "prefill":
            new_cache = {"ckv": c_kv.astype(dt), "kpe": k_pe.astype(dt)}
        return out, new_cache

    # ---- decode: absorbed-weight attention in latent space ----
    # S == 1 is the decode micro-step; S > 1 is the speculative verify
    # tail (the S newest tokens, written then causally attended — each
    # query t sits at position ``lengths - S + t``).
    if block_tables is not None and "ckv_scale" in cache:
        # int8 latent pool: the leaves have no head axis, so each block
        # carries one scalar f32 scale (running-max, requant-on-widen —
        # same write discipline as the GQA int8 pool)
        from repro.models.attention import quantized_scatter_token

        blk = cache["ckv"].shape[1]
        ckv_p, kpe_p = cache["ckv"], cache["kpe"]
        ckv_s, kpe_s = cache["ckv_scale"], cache["kpe_scale"]
        for t in range(S):
            idx = lengths - S + t
            pb = jnp.take_along_axis(block_tables, (idx // blk)[:, None],
                                     axis=1)[:, 0]
            off = idx % blk
            ckv_p, ckv_s = quantized_scatter_token(ckv_p, ckv_s,
                                                   c_kv[:, t], pb, off)
            kpe_p, kpe_s = quantized_scatter_token(kpe_p, kpe_s,
                                                   k_pe[:, t], pb, off)
        ckv_p = sharding.constrain(ckv_p, ("act_batch", "act_kvseq", None))
        kpe_p = sharding.constrain(kpe_p, ("act_batch", "act_kvseq", None))
        ckv_s = sharding.constrain(ckv_s, ("act_batch",))
        kpe_s = sharding.constrain(kpe_s, ("act_batch",))
        new_cache = {"ckv": ckv_p, "kpe": kpe_p,
                     "ckv_scale": ckv_s, "kpe_scale": kpe_s}
        # gather + dequantize each sequence's blocks into logical order
        W = block_tables.shape[1]
        ckv_c = (ckv_p[block_tables].astype(jnp.float32)
                 * ckv_s[block_tables][:, :, None, None]
                 ).reshape(B, W * blk, kvl)
        kpe_c = (kpe_p[block_tables].astype(jnp.float32)
                 * kpe_s[block_tables][:, :, None, None]
                 ).reshape(B, W * blk, rope)
    elif block_tables is not None:
        blk = cache["ckv"].shape[1]
        ckv_p, kpe_p = cache["ckv"], cache["kpe"]
        # a multi-token tail may straddle a block boundary: resolve each
        # position's physical block separately (S is a static constant)
        for t in range(S):
            idx = lengths - S + t
            pb = jnp.take_along_axis(block_tables, (idx // blk)[:, None],
                                     axis=1)[:, 0]
            off = idx % blk
            ckv_p = ckv_p.at[pb, off].set(c_kv[:, t].astype(ckv_p.dtype))
            kpe_p = kpe_p.at[pb, off].set(k_pe[:, t].astype(kpe_p.dtype))
        # latent pool leaves have no head axis — under serving_tp these
        # resolve to fully-replicated specs (the MLA cache is small
        # enough to replicate; scores still shard on act_heads below)
        ckv_p = sharding.constrain(ckv_p, ("act_batch", "act_kvseq", None))
        kpe_p = sharding.constrain(kpe_p, ("act_batch", "act_kvseq", None))
        new_cache = {"ckv": ckv_p, "kpe": kpe_p}
        # gather each sequence's blocks into logical order (jnp oracle;
        # a paged-MLA Pallas kernel would walk the table in SMEM instead)
        W = block_tables.shape[1]
        ckv_c = new_cache["ckv"][block_tables].reshape(B, W * blk, kvl)
        kpe_c = new_cache["kpe"][block_tables].reshape(B, W * blk, rope)
    else:
        idx = lengths - S
        ckv_c = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0)))(cache["ckv"], c_kv.astype(cache["ckv"].dtype), idx)
        kpe_c = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0)))(cache["kpe"], k_pe.astype(cache["kpe"].dtype), idx)
        ckv_c = sharding.constrain(ckv_c, ("act_batch", "act_kvseq", None))
        kpe_c = sharding.constrain(kpe_c, ("act_batch", "act_kvseq", None))
        new_cache = {"ckv": ckv_c, "kpe": kpe_c}

    if S > 1:
        return _mla_verify(cfg, p, x, q_nope, q_pe, ckv_c, kpe_c, lengths,
                           lora, adapter_ids), new_cache

    wuk = p["wuk"].reshape(kvl, h, nope)
    # absorb W_UK into q:  q_lat (B,h,kvl); cache operands stay bf16 with
    # f32 accumulation (no full-cache f32 copies)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], wuk,
                       preferred_element_type=jnp.float32)
    if lora and "wuk" in lora:
        # absorbed wuk adapter: contract the per-row B then A factor so
        # the (kvl, h*nope) weight delta is never materialized
        bk = jnp.take(lora["wuk"]["b"], adapter_ids, axis=0).reshape(
            B, -1, h, nope).astype(jnp.float32)
        ak = jnp.take(lora["wuk"]["a"], adapter_ids, axis=0).astype(
            jnp.float32)
        t = jnp.einsum("bhn,brhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       bk)
        q_lat = q_lat + jnp.einsum("bhr,bkr->bhk", t, ak)
    s_lat = jnp.einsum("bhr,bsr->bhs", q_lat.astype(ckv_c.dtype), ckv_c,
                       preferred_element_type=jnp.float32)
    s_pe = jnp.einsum("bhp,bsp->bhs", q_pe[:, 0].astype(kpe_c.dtype),
                      kpe_c, preferred_element_type=jnp.float32)
    s = (s_lat + s_pe) / jnp.sqrt(scale_dim)
    s = sharding.constrain(s, ("act_batch", "act_heads", "act_kvseq"))
    Smax = ckv_c.shape[1]
    valid = jnp.arange(Smax)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", pr.astype(ckv_c.dtype), ckv_c,
                     preferred_element_type=jnp.float32)
    wuv = p["wuv"].reshape(kvl, h, vd)
    o = jnp.einsum("bhr,rhv->bhv", ctx.astype(wuv.dtype), wuv,
                   preferred_element_type=jnp.float32)
    if lora and "wuv" in lora:
        av = jnp.take(lora["wuv"]["a"], adapter_ids, axis=0).astype(
            jnp.float32)
        bv = jnp.take(lora["wuv"]["b"], adapter_ids, axis=0).reshape(
            B, -1, h, vd).astype(jnp.float32)
        t = jnp.einsum("bhk,bkr->bhr", ctx.astype(jnp.float32), av)
        o = o + jnp.einsum("bhr,brhv->bhv", t, bv)
    o = o.reshape(B, 1, h * vd).astype(dt)
    out = jnp.einsum("bsq,qd->bsd", o, p["wo"])
    if lora and "wo" in lora:
        out = out + lora_shift(o, lora["wo"], adapter_ids)
    return out, new_cache


def _mla_verify(cfg: ModelConfig, p, x, q_nope, q_pe, ckv_c, kpe_c,
                lengths, lora, adapter_ids):
    """Absorbed-weight attention for an S-token speculative tail.

    Same math as the S == 1 decode path with a query axis added: query t
    of row b sits at position ``lengths[b] - S + t`` and attends causally
    through the (already updated) latent cache.  Multi-LoRA shifts fold
    into the absorbed ``wuk``/``wuv`` contractions exactly as in decode.
    """
    B, S, _ = x.shape
    h = cfg.num_heads
    nope, rope, vd = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                      cfg.v_head_dim)
    kvl = cfg.kv_lora_rank
    dt = x.dtype
    scale_dim = nope + rope

    wuk = p["wuk"].reshape(kvl, h, nope)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wuk,
                       preferred_element_type=jnp.float32)
    if lora and "wuk" in lora:
        bk = jnp.take(lora["wuk"]["b"], adapter_ids, axis=0).reshape(
            B, -1, h, nope).astype(jnp.float32)
        ak = jnp.take(lora["wuk"]["a"], adapter_ids, axis=0).astype(
            jnp.float32)
        t = jnp.einsum("bshn,brhn->bshr", q_nope.astype(jnp.float32), bk)
        q_lat = q_lat + jnp.einsum("bshr,bkr->bshk", t, ak)
    s_lat = jnp.einsum("bshr,bmr->bhsm", q_lat.astype(ckv_c.dtype), ckv_c,
                       preferred_element_type=jnp.float32)
    s_pe = jnp.einsum("bshp,bmp->bhsm", q_pe.astype(kpe_c.dtype), kpe_c,
                      preferred_element_type=jnp.float32)
    s = (s_lat + s_pe) / jnp.sqrt(scale_dim)
    Smax = ckv_c.shape[1]
    qpos = lengths[:, None] - S + jnp.arange(S)[None, :]          # (B,S)
    valid = jnp.arange(Smax)[None, None, :] <= qpos[:, :, None]   # (B,S,M)
    s = jnp.where(valid[:, None], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhsm,bmr->bshr", pr.astype(ckv_c.dtype), ckv_c,
                     preferred_element_type=jnp.float32)
    wuv = p["wuv"].reshape(kvl, h, vd)
    o = jnp.einsum("bshr,rhv->bshv", ctx.astype(wuv.dtype), wuv,
                   preferred_element_type=jnp.float32)
    if lora and "wuv" in lora:
        av = jnp.take(lora["wuv"]["a"], adapter_ids, axis=0).astype(
            jnp.float32)
        bv = jnp.take(lora["wuv"]["b"], adapter_ids, axis=0).reshape(
            B, -1, h, vd).astype(jnp.float32)
        t = jnp.einsum("bshk,bkr->bshr", ctx.astype(jnp.float32), av)
        o = o + jnp.einsum("bshr,brhv->bshv", t, bv)
    o = o.reshape(B, S, h * vd).astype(dt)
    out = jnp.einsum("bsq,qd->bsd", o, p["wo"])
    if lora and "wo" in lora:
        out = out + lora_shift(o, lora["wo"], adapter_ids)
    return out
