"""deepseek-v2-lite-16b  [moe]

27L d_model=2048 16H, MLA with kv_lora_rank=512 (qk_nope 128 + qk_rope 64,
v_head 128), vocab=102400.  MoE: 64 routed experts top-6 + 2 shared experts,
expert d_ff=1408, first layer dense (d_ff=10944).  [arXiv:2405.04434; hf]

Note: the assignment line reads "2 shared+160 routed"; 160 routed is the
full DeepSeek-V2 — the *Lite* model (which the 27L/2048d geometry matches)
has 64 routed experts, which we follow.
"""
from repro.configs.base import ModelConfig, register


@register("deepseek-v2-lite-16b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        num_layers=27,
        d_model=2048,
        d_ff=10944,              # dense MLP of layer 0 (first_k_dense)
        vocab_size=102400,
        attention="mla",
        num_heads=16,
        kv_lora_rank=512,
        q_lora_rank=0,           # lite has no q compression
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        num_experts=64,
        num_shared_experts=2,
        moe_top_k=6,
        moe_d_ff=1408,
        first_k_dense=1,
        rope_theta=10_000.0,
    )
