"""qwen1.5-4b  [dense] — MHA (kv == heads) with QKV bias.
[hf:Qwen/Qwen1.5 family; hf]

40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936.
"""
from repro.configs.base import ModelConfig, register


@register("qwen1.5-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        num_layers=40,
        d_model=2560,
        d_ff=6912,
        vocab_size=151936,
        attention="gqa",
        num_heads=20,
        num_kv_heads=20,
        head_dim=128,
        qkv_bias=True,
        rope_theta=5_000_000.0,
    )
