"""jamba-v0.1-52b  [hybrid] — Mamba + attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.  Period-8
super-blocks: attention at position 4, mamba elsewhere; MoE every 2nd layer
(offset 1), 16 experts top-2 with expert d_ff = 14336.

Hardware adaptation note (see DESIGN.md): Jamba v0.1 uses Mamba-1 selective
scan (d_state=16); we realize its mamba layers with the Mamba2/SSD
formulation of the same state-space family because SSD's chunked matmul
structure maps onto the TPU MXU, whereas Mamba-1's elementwise diagonal
recurrence does not.
"""
from repro.configs.base import ModelConfig, register


@register("jamba-v0.1-52b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        num_layers=32,
        d_model=4096,
        d_ff=14336,
        vocab_size=65536,
        attention="gqa",
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        pos_emb="none",          # jamba uses no positional encoding
        num_experts=16,
        moe_top_k=2,
        moe_d_ff=14336,
        moe_layer_period=2,
        moe_layer_offset=1,
        ssm_state=16,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=256,
        block_period=8,
        attn_positions=(4,),
    )
