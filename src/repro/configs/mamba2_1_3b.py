"""mamba2-1.3b  [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]

48L d_model=2048, ssm_state=128, expand=2 (d_inner=4096, 64 heads of 64),
conv_width=4, vocab=50280.
"""
from repro.configs.base import ModelConfig, register


@register("mamba2-1.3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        d_ff=0,                  # attn-free, MLP-free (mamba block only)
        vocab_size=50280,
        attention="none",
        pos_emb="none",
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=256,
        tie_embeddings=True,
    )
