"""Import side-effects: populate the arch registry."""
import repro.configs.granite_moe_3b_a800m  # noqa: F401
import repro.configs.deepseek_v2_lite_16b  # noqa: F401
import repro.configs.yi_34b                # noqa: F401
import repro.configs.qwen2_5_32b           # noqa: F401
import repro.configs.qwen1_5_4b            # noqa: F401
import repro.configs.glm4_9b               # noqa: F401
import repro.configs.mamba2_1_3b           # noqa: F401
import repro.configs.internvl2_1b          # noqa: F401
import repro.configs.jamba_v0_1_52b        # noqa: F401
import repro.configs.whisper_small         # noqa: F401
import repro.configs.apertus_8b            # noqa: F401
import repro.configs.apertus_70b           # noqa: F401

ASSIGNED = [
    "granite-moe-3b-a800m",
    "deepseek-v2-lite-16b",
    "yi-34b",
    "qwen2.5-32b",
    "qwen1.5-4b",
    "glm4-9b",
    "mamba2-1.3b",
    "internvl2-1b",
    "jamba-v0.1-52b",
    "whisper-small",
]
PAPER_OWN = ["apertus-8b", "apertus-70b"]
