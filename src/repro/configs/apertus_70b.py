"""apertus-70b — the paper's own served model (§5.2 Apertus-70B metrics).
[arXiv:2509.14233; swiss-ai/Apertus-70B]

Llama-3-70B-class geometry: 80L d_model=8192 64H (GQA kv=8) d_ff=28672,
vocab=131072.
"""
from repro.configs.base import ModelConfig, register


@register("apertus-70b")
def config() -> ModelConfig:
    return ModelConfig(
        name="apertus-70b",
        family="dense",
        num_layers=80,
        d_model=8192,
        d_ff=28672,
        vocab_size=131072,
        attention="gqa",
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=500_000.0,
    )
