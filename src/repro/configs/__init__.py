from repro.configs.base import ModelConfig, get_config, list_archs, register, scaled_down
from repro.configs.shapes import SHAPES, ShapeSpec, applicable, cells

__all__ = [
    "ModelConfig", "get_config", "list_archs", "register", "scaled_down",
    "SHAPES", "ShapeSpec", "applicable", "cells",
]
