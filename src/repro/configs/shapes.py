"""Assigned input shapes and (arch × shape) applicability.

LM transformer shapes are seq_len × global_batch.  ``decode_*``/``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len capacity),
not ``train_step``.  ``long_500k`` requires sub-quadratic attention and is
skipped for pure full-attention archs (noted in DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Whisper's encoder consumes a fixed ~30 s mel window (1500 frames; padded
# to 1536 so the TP-sharded cross-KV divides the 16-way model axis); longer
# "contexts" live in the decoder, which is how the assigned shapes are
# applied to the enc-dec backbone.
WHISPER_ENCODER_FRAMES = 1536


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.is_sub_quadratic:
        return False, (
            "long_500k skipped: pure full-attention arch (quadratic); run "
            "only for SSM/hybrid per assignment")
    return True, ""


def cells(arch_ids, shape_names=None):
    """Yield every applicable (arch_id, shape_name) cell."""
    from repro.configs.base import get_config
    shape_names = shape_names or list(SHAPES)
    for a in arch_ids:
        cfg = get_config(a)
        for s in shape_names:
            ok, _ = applicable(cfg, SHAPES[s])
            if ok:
                yield a, s
