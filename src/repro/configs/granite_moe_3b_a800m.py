"""granite-moe-3b-a800m  [moe]

32L d_model=1536 24H (GQA kv=8) vocab=49155, MoE 40 experts top-8 with
expert d_ff=512 (every layer MoE, no shared experts).
[hf:ibm-granite/granite-3.0-1b-a400m-base family; hf]
"""
from repro.configs.base import ModelConfig, register


@register("granite-moe-3b-a800m")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        num_layers=32,
        d_model=1536,
        d_ff=0,                  # all layers are MoE
        vocab_size=49155,
        attention="gqa",
        num_heads=24,
        num_kv_heads=8,
        head_dim=64,             # 1536 / 24
        num_experts=40,
        moe_top_k=8,
        moe_d_ff=512,
        moe_layer_period=1,
        rope_theta=10_000.0,
    )
