"""internvl2-1b  [vlm] — InternViT frontend (STUB) + Qwen2-0.5B-class LM
backbone.  [arXiv:2404.16821; hf]

Backbone: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
The vision frontend is a stub per the assignment: ``input_specs()``
provides precomputed patch embeddings (256 tokens, 1024-d) which the model
projects into the backbone width.
"""
from repro.configs.base import ModelConfig, register


@register("internvl2-1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        num_layers=24,
        d_model=896,
        d_ff=4864,
        vocab_size=151655,
        attention="gqa",
        num_heads=14,
        num_kv_heads=2,
        head_dim=64,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        frontend="vision",
        frontend_tokens=256,
        frontend_dim=1024,
        tie_embeddings=True,
    )
