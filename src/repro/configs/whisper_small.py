"""whisper-small  [audio] — encoder-decoder with conv frontend (STUB).
[arXiv:2212.04356]

12L encoder + 12L decoder, d_model=768 12H (kv=12) d_ff=3072 vocab=51865,
learned positional embeddings, LayerNorm, GELU.  The conv frontend is a
stub per the assignment: ``input_specs()`` provides precomputed frame
embeddings (mel frames already strided/conved into d_model-sized frames).
"""
from repro.configs.base import ModelConfig, register


@register("whisper-small")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        num_layers=12,           # decoder layers
        num_encoder_layers=12,
        is_encoder_decoder=True,
        d_model=768,
        d_ff=3072,
        vocab_size=51865,
        attention="gqa",
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        pos_emb="learned",
        act="gelu",
        norm="layernorm",
        frontend="audio",
        frontend_dim=768,        # stub frame embeddings arrive at d_model
        max_position=1 << 16,
    )
