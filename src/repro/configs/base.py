"""Model configuration covering every assigned architecture family.

One frozen dataclass describes dense GQA transformers, MoE (top-k routed +
shared experts), MLA (DeepSeek multi-head latent attention), Mamba2/SSD,
hybrid interleaves (Jamba), encoder-decoder (Whisper) and stub-fronted
VLM/audio backbones.  Configs are registered by id and looked up by the
launcher (``--arch <id>``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio

    num_layers: int = 0
    d_model: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # ---- attention ----
    attention: str = "gqa"  # gqa | mla | none
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0  # glm4 uses partial rotary (0.5)
    pos_emb: str = "rope"  # rope | learned | none

    # ---- MLA (DeepSeek-V2) ----
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # ---- MoE ----
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0          # leading dense layers (deepseek-v2)
    moe_layer_period: int = 1       # a layer l is MoE iff l % period == offset
    moe_layer_offset: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25

    # ---- SSM (Mamba2 / SSD) ----
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # ---- hybrid interleave (Jamba) ----
    block_period: int = 1           # sublayers per scanned super-block
    attn_positions: Tuple[int, ...] = ()  # positions within period using attention

    # ---- encoder-decoder ----
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # ---- modality frontend (stub: input_specs provides embeddings) ----
    frontend: str = "none"          # none | vision | audio
    frontend_tokens: int = 0        # prepended embedding tokens (vision)
    frontend_dim: int = 0           # raw embedding dim before projection

    # ---- misc ----
    act: str = "silu"               # silu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_position: int = 1 << 20

    # ---- implementation switches (perf levers, not architecture) ----
    moe_impl: str = "auto"          # auto | dense | ep (shard_map all-to-all)
    attn_impl: str = "blockwise"    # blockwise | naive
    remat: str = "block"            # none | block | full
    scan_layers: bool = True

    # ------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 so the TP-sharded unembed
        divides any mesh axis (standard Megatron/MaxText practice).  Pad
        logits are masked to -inf in ``layers.unembed``."""
        return -(-self.vocab_size // 256) * 256

    @property
    def is_attention_free(self) -> bool:
        return self.attention == "none" and not self.attn_positions

    @property
    def is_sub_quadratic(self) -> bool:
        """True if long-context decode (500k) is feasible: SSM or hybrid."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def q_dim(self) -> int:
        if self.attention == "mla":
            return self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
        return self.num_heads * self.head_dim

    @property
    def kv_cache_bytes_per_token_per_layer(self) -> int:
        """bf16 KV bytes for one token in one *attention* layer."""
        if self.attention == "mla":
            return 2 * (self.kv_lora_rank + self.qk_rope_head_dim)
        if self.attention == "gqa":
            return 2 * 2 * self.num_kv_heads * self.head_dim
        return 0

    def attn_layer_ids(self) -> Tuple[int, ...]:
        """Absolute indices of attention layers (for hybrid archs)."""
        if self.attention == "none" and not self.attn_positions:
            return ()
        if not self.attn_positions:  # all layers attend
            return tuple(range(self.num_layers))
        out = []
        for l in range(self.num_layers):
            if l % self.block_period in self.attn_positions:
                out.append(l)
        return tuple(out)

    def moe_layer_ids(self) -> Tuple[int, ...]:
        if not self.has_moe:
            return ()
        out = []
        for l in range(self.num_layers):
            if l < self.first_k_dense:
                continue
            if l % self.moe_layer_period == self.moe_layer_offset:
                out.append(l)
        return tuple(out)

    # ---- parameter counting (for roofline MODEL_FLOPS = 6·N·D) ----
    def param_count(self, active_only: bool = False) -> int:
        n = 0
        d = self.d_model
        n += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        if self.frontend_dim:
            n += self.frontend_dim * d
        for l in range(self.num_layers):
            n += self._layer_params(l, active_only)
        if self.is_encoder_decoder:
            for l in range(self.num_encoder_layers):
                n += self._enc_layer_params()
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        if self.attention == "mla":
            n = 0
            if self.q_lora_rank:
                n += d * self.q_lora_rank + self.q_lora_rank * self.q_dim
            else:
                n += d * self.q_dim
            n += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            n += self.kv_lora_rank * self.num_heads * (
                self.qk_nope_head_dim + self.v_head_dim)
            n += self.num_heads * self.v_head_dim * d
            return n
        h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        return d * hd * (h + 2 * kv) + h * hd * d

    def _mlp_params(self, ff: int) -> int:
        return 3 * self.d_model * ff  # gated (gate, up, down)

    def _ssm_params(self) -> int:
        d, di = self.d_model, self.ssm_d_inner
        nh, ds = self.ssm_heads, self.ssm_state
        n = d * (2 * di + 2 * ds + nh)        # proj -> x, z, B, C, dt (G=1)
        n += self.ssm_conv_width * (di + 2 * ds)  # conv over x,B,C
        n += nh + nh + nh + di                # A_log, D, dt_bias, gate norm
        n += di * d                           # out_proj
        return n

    def _layer_params(self, l: int, active_only: bool) -> int:
        n = 0
        is_attn = l in self.attn_layer_ids() if (
            self.attn_positions or self.attention == "none") else True
        if self.attention != "none" and is_attn:
            n += self._attn_params()
        elif self.ssm_state:
            n += self._ssm_params()
        if self.has_moe and l in self.moe_layer_ids():
            e = self.moe_top_k if active_only else self.num_experts
            n += e * self._mlp_params(self.moe_d_ff) // 1
            n += self.num_shared_experts * self._mlp_params(self.moe_d_ff)
            n += self.d_model * self.num_experts  # router
        elif self.d_ff:
            n += self._mlp_params(self.d_ff)
        n += 2 * self.d_model  # norms
        return n

    def _enc_layer_params(self) -> int:
        return self._attn_params() + 2 * self.d_model * self.d_ff + 2 * self.d_model


# ----------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_config(arch_id: str) -> ModelConfig:
    import repro.configs.all_archs  # noqa: F401  (populates registry)
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs():
    import repro.configs.all_archs  # noqa: F401
    return sorted(_REGISTRY)


def scaled_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced config of the same family for CPU smoke tests."""
    small = dict(
        num_layers=min(cfg.num_layers, 4) if not cfg.block_period > 1
        else cfg.block_period,
        d_model=128,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=32 if cfg.num_heads else cfg.head_dim,
        kv_lora_rank=32 if cfg.kv_lora_rank else 0,
        q_lora_rank=0,
        qk_nope_head_dim=32 if cfg.attention == "mla" else cfg.qk_nope_head_dim,
        qk_rope_head_dim=16 if cfg.attention == "mla" else cfg.qk_rope_head_dim,
        v_head_dim=32 if cfg.attention == "mla" else cfg.v_head_dim,
        num_experts=min(cfg.num_experts, 8),
        moe_top_k=min(cfg.moe_top_k, 2),
        moe_d_ff=min(cfg.moe_d_ff, 128) if cfg.moe_d_ff else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_chunk=32 if cfg.ssm_state else cfg.ssm_chunk,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        frontend_tokens=min(cfg.frontend_tokens, 8),
        frontend_dim=64 if cfg.frontend_dim else 0,
        max_position=4096,
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
