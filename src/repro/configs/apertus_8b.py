"""apertus-8b — the paper's own served model (§5.2 Apertus-8B metrics).
[arXiv:2509.14233; swiss-ai/Apertus-8B]

Llama-3-class geometry: 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
vocab=131072.  (Apertus uses xIELU + QK-norm; we use the SiLU-gated MLP of
the same shape — the serving/roofline characteristics are unchanged.)
"""
from repro.configs.base import ModelConfig, register


@register("apertus-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="apertus-8b",
        family="dense",
        num_layers=32,
        d_model=4096,
        d_ff=14336,
        vocab_size=131072,
        attention="gqa",
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=500_000.0,
    )
