"""Static analysis of optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` on this XLA build visits each ``while`` body
once — it does NOT multiply by trip count — so a scanned 60-layer model
would be undercounted 60x.  This walker parses the HLO text, propagates
loop trip counts (from ``backend_config known_trip_count`` with a
condition-constant fallback) through the call graph, and accumulates:

- ``flops``: 2*M*N*K for every dot (and convolutions approximately),
- ``bytes``: operand+result bytes of every top-level op (HBM traffic
  proxy, the standard HloCostAnalysis memory model),
- per-collective wire bytes using ring-algorithm cost:
    all-gather      (n-1)/n * result
    all-reduce      2*(n-1)/n * result
    reduce-scatter  (n-1)   * result     (result is the shard)
    all-to-all      (n-1)/n * result
    collective-permute       result
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e8m0fnu": 1,
    "f4e2m1fn": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# type is matched lazily: tuple types contain "/*index=N*/" comments, so we
# scan for the first lowercase "opcode(" token after the "=".
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s([a-z][a-z0-9\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|[a-z][a-z0-9]*\[[^\]]*\])")
_CALLS_RE = re.compile(r"(?:calls|body|condition|branch_computations)=")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str, tpu_dtype_model: bool = False) -> int:
    """Total bytes of a (possibly tuple) HLO type string.

    ``tpu_dtype_model``: XLA-CPU float normalization promotes bf16 compute
    (weights, caches, activations) to f32 with hoisted converts — on the
    TPU target those streams stay bf16.  The TPU dtype model counts f32
    tensors at 2 bytes (small error: genuinely-f32 optimizer moments and
    softmax stats are also discounted; they are a few % of traffic)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        sz = _DTYPE_BYTES[dt]
        if tpu_dtype_model and dt == "f32":
            sz = 2
        total += n * sz
    return total


def shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # remainder of the line (operands + attrs)


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op]
    params: Dict[str, str]  # param name -> type str


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                name = m.group(2)
                params = dict(_PARAM_RE.findall(line))
                cur = Computation(name, bool(m.group(1)), [], params)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(Op(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps


def _split_operands_attrs(rest: str) -> Tuple[str, str]:
    """Split 'operands), attrs' at the closing paren of the operand list."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def _called_computations(op: Op) -> List[str]:
    names = []
    for key in ("calls", "body", "condition", "to_apply", "branch_computations"):
        for m in re.finditer(key + r"=\{?((?:%[\w.\-]+(?:,\s*)?)+)\}?", op.rest):
            names += _OPERAND_RE.findall(m.group(1))
    return names


def _trip_count(op: Op, comps, default: int = 1) -> int:
    m = _TRIP_RE.search(op.rest)
    if m:
        return int(m.group(1))
    # fallback: constant bound in the condition computation
    mc = re.search(r"condition=%([\w.\-]+)", op.rest)
    if mc and mc.group(1) in comps:
        cond = comps[mc.group(1)]
        for o in cond.ops:
            mk = re.search(r"constant\((\d+)\)", o.rest)
            if o.opcode == "constant" and mk:
                return int(mk.group(1))
            mk2 = re.search(r"%constant[\w.\-]*\)", o.rest)
        consts = [int(x) for o in cond.ops
                  for x in re.findall(r"constant\((\d+)\)", o.type_str + o.rest)]
        if consts:
            return max(consts)
    return default


def _group_size(op: Op, num_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(op.rest)
    if m:
        g, n = int(m.group(1)), int(m.group(2))
        return max(n, 1)
    m = _GROUPS_LIST_RE.search(op.rest)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip() != ""]),
                   1)
    return num_devices


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    out_elems = shape_elems(op.type_str)
    operands, attrs = _split_operands_attrs(op.rest)
    names = _OPERAND_RE.findall(operands)
    k = 1
    mctr = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", attrs)
    if names and mctr and names[0] in symtab:
        lhs_shape = _SHAPE_RE.search(symtab[names[0]])
        if lhs_shape:
            dims = [int(d) for d in lhs_shape.group(2).split(",") if d]
            for ci in mctr.group(1).split(","):
                if ci != "" and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id"}


def analyze(text: str, num_devices: int,
            tpu_dtype_model: bool = False,
            kernel_scopes: bool = False,
            collect_top: int = 0) -> Dict[str, float]:
    """``kernel_scopes``: credit regions marked with jax.named_scope
    ("*_kernel_scope") as VMEM-resident — the validated Pallas kernels
    (flash attention/decode, SSD) replace exactly those interiors on TPU.
    Interior tensors contribute no HBM traffic; boundary reads (entry
    parameters, e.g. the KV cache) are still charged."""
    _sb = lambda t: shape_bytes(t, tpu_dtype_model)
    _in_scope = lambda op: "_kernel_scope" in op.rest
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # propagate execution multipliers through the call graph
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    mult[entry.name] = 1.0
    order = [entry.name]
    seen = {entry.name}
    # BFS in call order; HLO is a DAG of computations
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps[cname]
        for op in comp.ops:
            called = _called_computations(op)
            if not called:
                continue
            factor = mult[cname]
            if op.opcode == "while":
                factor *= _trip_count(op, comps)
            for cal in called:
                if cal in comps:
                    mult[cal] = mult.get(cal, 0.0) + factor
                    if cal not in seen:
                        seen.add(cal)
                        order.append(cal)

    # computations that are bodies/conds of kernel-scope whiles (nested
    # loops inside a kernel scope inherit membership)
    scope_comps = set()
    if kernel_scopes:
        frontier = []
        for comp in comps.values():
            for op in comp.ops:
                if op.opcode == "while" and _in_scope(op):
                    frontier += _called_computations(op)
        while frontier:
            c = frontier.pop()
            if c in scope_comps or c not in comps:
                continue
            scope_comps.add(c)
            for op in comps[c].ops:
                frontier += _called_computations(op)

    res = {
        "flops": 0.0, "bytes": 0.0, "collective_wire_bytes": 0.0,
        "collective_raw_bytes": 0.0,
        "by_collective": {c: 0.0 for c in COLLECTIVES},
        "collective_count": 0.0,
        "dot_flops_by_meta": {},
        "top_bytes": [],
    }

    def _note(amount, op):
        if collect_top and amount > 0:
            mm = re.search(r'op_name="([^"]+)"', op.rest)
            res["top_bytes"].append(
                (amount, op.opcode, op.type_str.split("{")[0][:42],
                 (mm.group(1)[-80:] if mm else "")))
    fusion_names = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                fusion_names.update(_called_computations(op))

    # fusion-body facts for traffic modeling: in-place DUS (XLA aliases
    # donated buffers, traffic = updated slice) and sparse gathers
    # (traffic = gathered rows, not the table)
    fusion_info: Dict[str, Dict[str, float]] = {}
    for comp in comps.values():
        symtab = dict(comp.params)
        for op in comp.ops:
            symtab[op.name] = op.type_str
        info = {"dus_update": 0.0, "gather_out": 0.0, "has_dus": False,
                "has_gather": False, "pure_convert": True}
        for op in comp.ops:
            if op.opcode not in ("convert", "bitcast", "copy", "parameter",
                                 "constant"):
                info["pure_convert"] = False
            if op.opcode in ("dynamic-update-slice", "scatter"):
                opnds = _OPERAND_RE.findall(
                    _split_operands_attrs(op.rest)[0])
                upd_idx = 1 if op.opcode == "dynamic-update-slice" else -1
                if len(opnds) > 1:
                    info["dus_update"] += _sb(
                        symtab.get(opnds[upd_idx], ""))
                info["has_dus"] = True
            elif op.opcode == "gather":
                info["gather_out"] += _sb(op.type_str)
                info["has_gather"] = True
            elif op.opcode == "dynamic-slice":
                info["gather_out"] += _sb(op.type_str)
                info["has_gather"] = True
        fusion_info[comp.name] = info

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        symtab = dict(comp.params)
        for op in comp.ops:
            symtab[op.name] = op.type_str
        in_fusion = comp.name in fusion_names
        scope_extra = set()
        if kernel_scopes:
            scoped = {op.name for op in comp.ops if _in_scope(op)}
            consumers = {}
            for op in comp.ops:
                for n in _OPERAND_RE.findall(
                        _split_operands_attrs(op.rest)[0]):
                    consumers.setdefault(n, []).append(op)
            for _ in range(2):  # two backward steps cover layout chains
                for op in comp.ops:
                    if op.name in scoped or op.name in scope_extra:
                        continue
                    if op.opcode not in ("copy", "transpose", "fusion",
                                         "bitcast", "convert", "reshape"):
                        continue
                    cons = consumers.get(op.name, [])
                    if cons and all(c.name in scoped or c.name in scope_extra
                                    for c in cons):
                        scope_extra.add(op.name)
        for op in comp.ops:
            if op.opcode == "dot":
                res["flops"] += m * _dot_flops(op, symtab)
            elif op.opcode == "convolution":
                res["flops"] += m * 2.0 * shape_elems(op.type_str)
            if in_fusion:
                continue  # fused internals are not HBM traffic
            if op.opcode in _SKIP_BYTES or op.opcode == "while":
                continue
            in_scope_body = kernel_scopes and comp.name in scope_comps
            in_scope_op = kernel_scopes and (_in_scope(op)
                                             or op.name in scope_extra)
            if (tpu_dtype_model and op.opcode == "copy"
                    and comp.is_entry):
                # donated-buffer copies are elided by TPU aliasing
                continue
            rb = _sb(op.type_str)
            operands, _ = _split_operands_attrs(op.rest)
            opnds = _OPERAND_RE.findall(operands)
            ob = sum(_sb(symtab.get(n, "")) for n in opnds)
            if in_scope_body:
                pass_bytes = 0.0          # VMEM interior (loop carries too)
            elif in_scope_op:
                # boundary reads only: operands fed by entry params / GTEs
                opcode_of = {o.name: o.opcode for o in comp.ops}
                pass_bytes = sum(
                    _sb(symtab.get(n, "")) for n in opnds
                    if opcode_of.get(n, "parameter") in
                    ("parameter", "get-tuple-element", "copy"))
            else:
                pass_bytes = None
            if pass_bytes is not None:
                res["bytes"] += m * pass_bytes
                _note(m * pass_bytes, op)
                # collectives inside kernels are still real wire traffic
                base = next((c for c in COLLECTIVES if op.opcode == c
                             or op.opcode == c + "-start"), None)
                if base:
                    n = _group_size(op, num_devices)
                    wire = {"all-gather": rb * (n - 1) / n,
                            "all-reduce": 2.0 * rb * (n - 1) / n,
                            "reduce-scatter": rb * (n - 1),
                            "all-to-all": rb * (n - 1) / n,
                            "collective-permute": rb}[base]
                    res["collective_wire_bytes"] += m * wire
                    res["collective_raw_bytes"] += m * rb
                    res["by_collective"][base] += m * wire
                    res["collective_count"] += m
                continue
            if op.opcode in ("dynamic-update-slice", "scatter"):
                # XLA performs DUS/scatter in place on donated/aliased
                # buffers: traffic is the updated slice, not the slab.
                ui = 1 if op.opcode == "dynamic-update-slice" else -1
                upd = _sb(symtab.get(opnds[ui], "")) if len(opnds) > 1 \
                    else 0
                res["bytes"] += m * 2 * upd
                _note(m * 2 * upd, op)
                continue
            if op.opcode in ("gather", "dynamic-slice"):
                res["bytes"] += m * 2 * rb  # rows read + result written
                _note(m * 2 * rb, op)
                continue
            if op.opcode == "fusion":
                called = _called_computations(op)
                infos = [fusion_info.get(c) for c in called
                         if c in fusion_info]
                if tpu_dtype_model and infos and all(
                        i["pure_convert"] for i in infos):
                    # dtype-normalization artifact: native-bf16 TPU fuses
                    # converts into consumers (no materialized copy)
                    continue
                if infos and any(i["has_dus"] or i["has_gather"]
                                 for i in infos):
                    # replace the slab-sized result/operand with the
                    # touched bytes: max operand assumed aliased for DUS,
                    # gather source read only at gathered rows
                    opnd_sizes = [_sb(symtab.get(n, ""))
                                  for n in opnds]
                    big = max(opnd_sizes) if opnd_sizes else 0
                    touched = sum(2 * i["dus_update"]
                                  + 2 * i["gather_out"] for i in infos)
                    adj = ob - big + touched
                    if any(i["has_dus"] for i in infos):
                        adj += 0          # result aliases the big operand
                    else:
                        adj += rb         # gather-only fusion writes result
                    res["bytes"] += m * max(adj, 0.0)
                    _note(m * max(adj, 0.0), op)
                    continue
            res["bytes"] += m * (rb + ob)
            _note(m * (rb + ob), op)
            base = next((c for c in COLLECTIVES if op.opcode == c
                         or op.opcode == c + "-start"), None)
            if base:
                n = _group_size(op, num_devices)
                if base == "all-gather":
                    wire = rb * (n - 1) / n
                elif base == "all-reduce":
                    wire = 2.0 * rb * (n - 1) / n
                elif base == "reduce-scatter":
                    wire = rb * (n - 1)
                elif base == "all-to-all":
                    wire = rb * (n - 1) / n
                else:  # collective-permute
                    wire = rb
                res["collective_wire_bytes"] += m * wire
                res["collective_raw_bytes"] += m * rb
                res["by_collective"][base] += m * wire
                res["collective_count"] += m
    if collect_top:
        res["top_bytes"] = sorted(res["top_bytes"], reverse=True)[:collect_top]
    else:
        res.pop("top_bytes")
    return res
