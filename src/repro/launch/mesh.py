"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips ("data","model").
Multi-pod: 2 pods x 256 = 512 chips ("pod","data","model").
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for tests (requires enough local/fake devices)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
