import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first backend initialization.

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, applicable, get_config
from repro.configs.all_archs import ASSIGNED, PAPER_OWN
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.param import abstract_params, param_axes
from repro.parallel import sharding as sh
from repro.training.optimizer import OptConfig, opt_init, opt_state_axes
from repro.training.train_step import make_train_step

# --- TPU v5e-like target constants (per chip) ---
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link (spec-conservative single link)

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "..", "..", "..", "benchmarks", "results",
                           "dryrun")


def _kind(shape_name: str) -> str:
    if shape_name == "long_500k":
        return "long"
    return SHAPES[shape_name].kind


def _abstract_tree(tree, dtype=None):
    def one(x):
        return jax.ShapeDtypeStruct(x.shape, dtype or x.dtype)
    return jax.tree.map(one, tree)


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               rule_overrides: Optional[Dict[str, Any]] = None,
               opt_name: str = "adamw", remat: str = "block"):
    """Returns (jitted_fn, example_args, mesh, rules, cfg)."""
    import dataclasses
    cfg = dataclasses.replace(get_config(arch), remat=remat)
    shape = SHAPES[shape_name]
    kind = _kind(shape_name)
    rules = sh.make_rules("train" if kind == "train" else kind,
                          multi_pod=multi_pod, **(rule_overrides or {}))
    mesh = make_production_mesh(multi_pod=multi_pod)

    axes = M.model_param_axes(cfg)
    p_sh = sh.tree_shardings(axes, mesh, rules)
    in_axes_tree = M.input_axes(cfg, shape)
    b_sh = sh.tree_shardings(in_axes_tree, mesh, rules)
    inputs = M.input_specs(cfg, shape)
    repl = NamedSharding(mesh, P())

    if kind == "train":
        p_abs = abstract_params(M.model_specs(cfg), jnp.float32)
        opt_cfg = OptConfig(name=opt_name)
        opt_abs = jax.eval_shape(lambda p: opt_init(opt_cfg, p), p_abs)
        o_axes = opt_state_axes(opt_cfg, axes)
        o_sh = sh.tree_shardings(o_axes, mesh, rules)
        step = make_train_step(cfg, opt_cfg)

        def wrapped(params, opt_state, batch):
            with sh.use_rules(mesh, rules):
                return step(params, opt_state, batch)

        jf = jax.jit(wrapped, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, repl),
                     donate_argnums=(0, 1))
        args = (p_abs, opt_abs, inputs)
    elif kind == "prefill":
        p_abs = abstract_params(M.model_specs(cfg), jnp.bfloat16)
        c_axes = M.cache_axes(cfg)
        c_sh = sh.tree_shardings(c_axes, mesh, rules)
        logits_sh = NamedSharding(mesh, rules.spec(("act_batch",
                                                    "act_vocab")))

        def wrapped(params, batch):
            with sh.use_rules(mesh, rules):
                logits, cache, aux = M.prefill(cfg, params, batch)
            return logits, cache

        jf = jax.jit(wrapped, in_shardings=(p_sh, b_sh),
                     out_shardings=(logits_sh, c_sh))
        args = (p_abs, inputs)
    else:  # decode / long
        p_abs = abstract_params(M.model_specs(cfg), jnp.bfloat16)
        c_axes = M.cache_axes(cfg)
        c_sh = sh.tree_shardings(c_axes, mesh, rules)
        logits_sh = NamedSharding(mesh, rules.spec(("act_batch",
                                                    "act_vocab")))
        tok_sh = b_sh["tokens"]
        len_sh = b_sh["lengths"]

        def wrapped(params, tokens, cache, lengths):
            with sh.use_rules(mesh, rules):
                return M.decode_step(cfg, params, tokens, cache, lengths)

        jf = jax.jit(wrapped,
                     in_shardings=(p_sh, tok_sh, c_sh, len_sh),
                     out_shardings=(logits_sh, c_sh),
                     donate_argnums=(2,))
        args = (p_abs, inputs["tokens"], inputs["cache"], inputs["lengths"])
    return jf, args, mesh, rules, cfg


def model_flops(cfg, shape_name: str) -> float:
    """Analytic MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd), N = active
    non-embedding params (unembed counted once)."""
    shape = SHAPES[shape_name]
    n_active = cfg.param_count(active_only=True)
    n_active -= cfg.vocab_size * cfg.d_model  # lookup is not a matmul
    if cfg.tie_embeddings:
        n_active += cfg.vocab_size * cfg.d_model  # tied unembed matmul
    kind = _kind(shape_name)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_active * tokens


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = RESULTS_DIR, tag: str = "",
             rule_overrides=None, remat: str = "block") -> Dict[str, Any]:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
    }
    cfg = get_config(arch)
    ok, why = applicable(cfg, SHAPES[shape_name])
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec
    t0 = time.time()
    try:
        jf, args, mesh, rules, cfg = build_cell(
            arch, shape_name, multi_pod, rule_overrides, remat=remat)
        lowered = jf.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        n_dev = mesh.size

        ma = compiled.memory_analysis()
        rec["memory_per_device"] = {
            "arguments_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "total_bytes": int(ma.argument_size_in_bytes
                               + ma.temp_size_in_bytes
                               + ma.output_size_in_bytes
                               - ma.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        rec["xla_cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "note": "XLA visits while bodies once (no trip multiplication); "
                    "see hlo_walk for trip-corrected numbers",
        }
        hlo_text = compiled.as_text()
        an_raw = hlo_analysis.analyze(hlo_text, n_dev)
        an_nok = hlo_analysis.analyze(hlo_text, n_dev, tpu_dtype_model=True)
        an = hlo_analysis.analyze(hlo_text, n_dev, tpu_dtype_model=True,
                                  kernel_scopes=True)
        rec["hlo_walk_raw_cpu"] = {
            k: v for k, v in an_raw.items() if k != "dot_flops_by_meta"}
        rec["hlo_walk_nokernel"] = {
            k: v for k, v in an_nok.items() if k != "dot_flops_by_meta"}
        rec["hlo_walk"] = {k: v for k, v in an.items()
                          if k != "dot_flops_by_meta"}
        rec["hlo_walk"]["note"] = (
            "TPU dtype model (f32-normalized streams at bf16 width) + "
            "Pallas-kernel VMEM credit for *_kernel_scope regions; "
            "see hlo_walk_nokernel / hlo_walk_raw_cpu for ablations")

        mf = model_flops(cfg, shape_name)
        t_comp = an["flops"] / PEAK_FLOPS
        t_mem = an["bytes"] / HBM_BW
        t_coll = an["collective_wire_bytes"] / ICI_BW
        rec["roofline"] = {
            "chips": n_dev,
            "t_compute_s": t_comp,
            "t_memory_s": t_mem,
            "t_collective_s": t_coll,
            "bound": max(
                [("compute", t_comp), ("memory", t_mem),
                 ("collective", t_coll)], key=lambda kv: kv[1])[0],
            "model_flops_total": mf,
            "hlo_flops_total": an["flops"] * n_dev,
            "useful_flops_ratio": mf / max(an["flops"] * n_dev, 1.0),
            "step_time_bound_s": max(t_comp, t_mem, t_coll),
            "roofline_fraction": t_comp / max(t_comp, t_mem, t_coll, 1e-30),
        }
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 - record and keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fn = os.path.join(
            out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all (arch x shape) cells for the chosen mesh")
    ap.add_argument("--include-paper-own", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--remat", default="block")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default=RESULTS_DIR)
    args = ap.parse_args()

    archs = ASSIGNED + (PAPER_OWN if args.include_paper_own else [])
    cells = []
    if args.all:
        for a in archs:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    for arch, shape_name in cells:
        suffix = f"__{args.tag}" if args.tag else ""
        fn = os.path.join(args.out_dir,
                          f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
        if os.path.exists(fn) and not args.force:
            print(f"[skip-cached] {arch} {shape_name} {mesh_name}")
            continue
        rec = run_cell(arch, shape_name, args.multi_pod,
                       out_dir=args.out_dir, tag=args.tag,
                       remat=args.remat)
        r = rec.get("roofline", {})
        print(f"[{rec['status']:7s}] {arch:22s} {shape_name:12s} "
              f"{mesh_name:8s} lower={rec.get('lower_s', '-')}s "
              f"compile={rec.get('compile_s', '-')}s "
              f"bound={r.get('bound', '-')} "
              f"step={r.get('step_time_bound_s', 0):.4f}s "
              f"err={rec.get('error', '')[:120]}",
              flush=True)


if __name__ == "__main__":
    main()
