"""Serving driver: one engine replica behind the governed gateway.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b \
        --requests 8 --max-tokens 16

``--tp N`` serves one *sharded* replica (tensor parallelism over a
``("model",)`` mesh, serving/README.md "Sharded serving"); the gateway
still sees exactly one endpoint.  On a single-CPU host the driver
forces N XLA host devices so the flag is demoable anywhere.

Restores weights from ``--ckpt-dir`` if present (e.g. from
``repro.launch.train``), otherwise serves random-init weights.
"""
from __future__ import annotations

import argparse
import os
import sys


def _early_tp_flag():
    """``--tp N`` on a host with fewer than N devices: force XLA host
    devices.  Must run before jax's first import — XLA reads the flag
    once at backend init, so it cannot live in main()."""
    if "jax" in sys.modules:        # too late; make_mesh will error out
        return
    try:
        n = int(sys.argv[sys.argv.index("--tp") + 1])
    except (ValueError, IndexError):
        return
    if n > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}").strip()


_early_tp_flag()

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, scaled_down
from repro.core.gateway import Gateway, GatewayError, ModelEntry
from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--scale", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--dense", action="store_true",
                    help="force the dense per-slot KV layout (default: "
                         "paged on supported architectures)")
    ap.add_argument("--pool-tokens", type=int, default=None,
                    help="paged KV pool size in tokens (default: "
                         "max_batch * capacity)")
    ap.add_argument("--kv-dtype", choices=["bf16", "int8"],
                    default="bf16",
                    help="paged KV pool storage precision: 'int8' "
                         "quantizes blocks symmetrically with per-block "
                         "f32 scales — the same --pool-tokens budget "
                         "buys ~2x the blocks (accuracy-guarded; see "
                         "serving/README.md 'Quantized serving')")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree of this replica (one "
                         "sharded engine = one gateway endpoint); KV "
                         "heads must divide N; forces N XLA host "
                         "devices on a single-device machine")
    ap.add_argument("--adapters", type=int, default=0,
                    help="serve N demo LoRA adapters (tenant0..N-1) from "
                         "one adapter pool; requests round-robin across "
                         "base and model@tenantI")
    ap.add_argument("--adapter-slots", type=int, default=None,
                    help="device-resident adapter slots (default: "
                         "min(--adapters, 4); fewer than --adapters "
                         "exercises LRU eviction)")
    ap.add_argument("--speculative", choices=["ngram", "draft"],
                    default=None,
                    help="speculative decoding: 'ngram' (prompt-lookup, "
                         "model-free) or 'draft' (small draft model via "
                         "--draft-config); greedy outputs stay "
                         "token-identical to the plain engine")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens verified per launch")
    ap.add_argument("--draft-config", default="",
                    help="architecture name of the draft model (e.g. "
                         "qwen1.5-4b drafting for qwen2.5-32b); same "
                         "--scale treatment as the target; random-init "
                         "weights unless --draft-ckpt-dir is given")
    ap.add_argument("--draft-ckpt-dir", default="",
                    help="checkpoint dir for the draft model's weights")
    ap.add_argument("--role", choices=["unified", "prefill", "decode"],
                    default="unified",
                    help="engine role (disaggregated serving): "
                         "'prefill' runs prompts to KV-handoff export "
                         "and reports the outbox (one side of a "
                         "disaggregated deployment); 'decode' alone is "
                         "an error (nothing feeds it handoffs) — use "
                         "--disagg for the full pair in one process")
    ap.add_argument("--disagg", action="store_true",
                    help="serve a disaggregated prefill/decode engine "
                         "pair behind the gateway's DisaggRouter "
                         "(prefill pool -> KV handoff -> decode pool; "
                         "token-identical to unified at temperature 0)")
    ap.add_argument("--chaos", nargs="?", const="crash@micro_step:8",
                    default=None, metavar="KIND@POINT[:AT_CALL]",
                    help="arm fault injection on the engine (e.g. "
                         "crash@micro_step:8, reject@admission:2, "
                         "hang@micro_step:5:0.25); a crashed engine "
                         "auto-recovers after two health probes — pair "
                         "with --retry-budget to watch the gateway "
                         "ride through it (docs/robustness.md)")
    ap.add_argument("--retry-budget", type=int, default=0,
                    help="gateway retries per completion after an "
                         "engine failure (exponential backoff + full "
                         "jitter)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall budget; past it the request "
                         "is evacuated and DeadlineExceeded raised")
    ap.add_argument("--metrics-out", default="",
                    help="write a Prometheus text snapshot of the "
                         "metrics registry here (enables observability)")
    ap.add_argument("--trace-out", default="",
                    help="write a Perfetto trace_event JSON here "
                         "(enables observability)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="with --metrics-out: also re-dump the snapshot "
                         "every N requests (a cheap stand-in for a "
                         "scrape endpoint)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.scale == "tiny":
        cfg = scaled_down(cfg)
    params = M.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    if args.ckpt_dir:
        from repro.checkpoint import ckpt as C
        try:
            state, manifest = C.restore(args.ckpt_dir,
                                        {"params": params, "opt": None})
        except Exception:
            target = {"params": params}
            try:
                state, manifest = C.restore(args.ckpt_dir, target)
                params = state["params"]
                print(f"restored weights from step {manifest['step']}")
            except Exception as e:  # noqa: BLE001
                print(f"no usable checkpoint ({e}); serving random init")

    if args.role == "decode" and not args.disagg:
        ap.error("--role decode has no handoff source in a single-engine "
                 "process; use --disagg for the prefill/decode pair")
    if (args.disagg or args.role != "unified") and args.dense:
        ap.error("disaggregated roles need the paged KV layout "
                 "(KV handoffs are block-granular); drop --dense")
    if args.kv_dtype == "int8" and args.dense:
        ap.error("--kv-dtype int8 needs the paged KV layout (per-block "
                 "scales live in the block pool); drop --dense")
    adapter_slots = (min(args.adapters, 4) if args.adapter_slots is None
                     else args.adapter_slots)
    if args.adapters and adapter_slots < 1:
        ap.error("--adapters requires --adapter-slots >= 1")
    draft_cfg = draft_params = None
    if args.speculative == "draft":
        if not args.draft_config:
            ap.error("--speculative draft requires --draft-config")
        draft_cfg = get_config(args.draft_config)
        if args.scale == "tiny":
            draft_cfg = scaled_down(draft_cfg)
        draft_params = M.init(draft_cfg, jax.random.PRNGKey(1),
                              jnp.float32)
        if args.draft_ckpt_dir:
            from repro.checkpoint import ckpt as C
            try:
                state, mani = C.restore(args.draft_ckpt_dir,
                                        {"params": draft_params})
                draft_params = state["params"]
                print(f"draft weights from step {mani['step']}")
            except Exception as e:  # noqa: BLE001
                print(f"no usable draft checkpoint ({e}); random init")
    obs = None
    if args.metrics_out or args.trace_out:
        from repro.obs import Observability
        obs = Observability()
    mesh = None
    if args.tp > 1:
        if jax.device_count() < args.tp:
            ap.error(f"--tp {args.tp} needs {args.tp} devices, have "
                     f"{jax.device_count()} (is jax imported before "
                     f"repro.launch.serve?)")
        mesh = jax.make_mesh((args.tp,), ("model",))
        print(f"tensor parallel: TP={args.tp} over "
              f"{[d.platform + str(d.id) for d in mesh.devices.flat]}")
    def mk_engine(name="engine", role="unified", spec=True):
        # speculative decoding only makes sense where tokens are
        # emitted, so a prefill-role engine never carries a drafter
        return InferenceEngine(
            cfg, params, max_batch=args.max_batch,
            capacity=args.capacity,
            paged=False if args.dense else None,
            pool_tokens=args.pool_tokens,
            adapter_slots=adapter_slots,
            speculative=args.speculative if spec else None,
            spec_k=args.spec_k,
            draft_cfg=draft_cfg if spec else None,
            draft_params=draft_params if spec else None,
            obs=obs, mesh=mesh, name=name, role=role,
            kv_dtype=args.kv_dtype)

    pre = None
    if args.disagg:
        pre = mk_engine("prefill0", "prefill", spec=False)
        eng = mk_engine("decode0", "decode")
        print("disaggregated pair: prefill0 -> KV handoff -> decode0")
    else:
        eng = mk_engine(role=args.role)
    names = [cfg.name]
    if args.adapters:
        from repro.finetune.lora import (LoraConfig, lora_init,
                                         lora_randomize)
        from repro.finetune.sft import publish_adapter
        lcfg = LoraConfig(rank=4)
        for i in range(args.adapters):
            ad = lora_randomize(
                lora_init(params, lcfg, jax.random.PRNGKey(100 + i)),
                jax.random.PRNGKey(200 + i))
            publish_adapter(eng, f"tenant{i}", ad, lcfg)
            if pre is not None:
                # the adapter pin transfers with the handoff, so the
                # prefill pool must hold the same adapters
                publish_adapter(pre, f"tenant{i}", ad, lcfg)
            names.append(f"{cfg.name}@tenant{i}")

    if args.role == "prefill" and not args.disagg:
        # one side of a disaggregated deployment: run the prompts to
        # handoff export and report the outbox (no decode peer in this
        # process — --disagg serves the full pair)
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            prompt = [int(x) for x in rng.integers(1, cfg.vocab_size - 1,
                                                   4 + i % 5)]
            eng.submit(Request(prompt=prompt,
                               max_new_tokens=args.max_tokens,
                               temperature=args.temperature))
        eng.run_until_idle()
        for req, ho in eng.outbox:
            print(f"handoff: rid={ho.request_id} tokens={ho.length} "
                  f"blocks={ho.n_blocks} bytes={ho.payload_bytes}")
        s = eng.metrics.summary()
        print("metrics:", {k: round(v, 4) for k, v in s.items()})
        if obs is not None:
            eng.collect_metrics()
            if args.metrics_out:
                obs.write_metrics(args.metrics_out)
                print(f"metrics snapshot -> {args.metrics_out}")
            if args.trace_out:
                obs.write_trace(args.trace_out)
        return
    endpoint = eng
    if args.chaos:
        from repro.serving.faults import (ChaosEngine, FaultInjector,
                                          parse_fault_spec)
        injector = FaultInjector([parse_fault_spec(args.chaos)])
        endpoint = ChaosEngine(eng, injector, auto_recover_probes=2)
        print(f"chaos armed: {args.chaos}")
    # short breaker cooldown so a recovered engine re-earns traffic
    # within a CLI demo run, not after 30 wall seconds
    gw = Gateway(obs=obs, retry_budget=args.retry_budget,
                 deadline_s=args.deadline_s,
                 breaker_threshold=1, breaker_cooldown_s=0.05)
    gw.vet_model(ModelEntry(cfg.name, cfg.name, 0.5, 1.5), cfg)
    if args.disagg:
        gw.bind_disagg(cfg.name, [pre], [endpoint])
    else:
        gw.bind_endpoints(cfg.name, [endpoint])
    key = gw.mint_key("cli", budget_usd=10.0)

    def dump_snapshot():
        if obs is None or not args.metrics_out:
            return
        gw.collect_metrics()          # pull engine/pool/cache state
        obs.write_metrics(args.metrics_out)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = [int(x) for x in rng.integers(1, cfg.vocab_size - 1,
                                               4 + i % 5)]
        model = names[i % len(names)]
        try:
            out = gw.completion(api_key=key.key, model=model,
                                prompt=prompt,
                                max_tokens=args.max_tokens,
                                temperature=args.temperature)
        except GatewayError as e:
            # chaos demo: a failed request is an outcome to show, not a
            # crash of the driver
            print(f"req{i}: model={model} FAILED "
                  f"{type(e).__name__}: {e}")
            continue
        print(f"req{i}: model={model} prompt={prompt} -> {out['tokens']}")
        if args.snapshot_every and (i + 1) % args.snapshot_every == 0:
            dump_snapshot()
    s = eng.metrics.summary()
    print("metrics:", {k: round(v, 4) for k, v in s.items()})
    if args.disagg:
        ps = pre.metrics.summary()
        print(f"disagg: handoffs={ps['handed_off']} "
              f"(prefill0 -> decode0)")
    if args.kv_dtype == "int8":
        kv = eng.kv_stats()
        print(f"quantized KV: dtype=int8 "
              f"blocks_total={kv['kv_blocks_total']} "
              f"block_bytes_per_device="
              f"{kv.get('kv_block_bytes_per_device', 0)} B "
              f"(~2x blocks at the same --pool-tokens budget)")
    if args.tp > 1:
        kv = eng.kv_stats()
        line = f"sharded replica: tp={kv.get('kv_tp_degree', args.tp)}"
        if "kv_peak_bytes_per_device" in kv:
            line += (f" peak_kv_per_device="
                     f"{kv['kv_peak_bytes_per_device']} B")
        print(line)
    if args.speculative:
        print(f"speculative[{args.speculative}] k={args.spec_k}: "
              f"acceptance={s['spec_acceptance_rate']:.3f} "
              f"tokens/launch={s['spec_tokens_per_launch']:.2f}")
    if args.adapters:
        print("adapter pool:", eng.adapter_stats())
        print("usage by adapter:", gw.usage_by_adapter())
    print("usage:", gw.usage_by_project())
    if obs is not None:
        dump_snapshot()
        if args.metrics_out:
            print(f"metrics snapshot -> {args.metrics_out}")
        if args.trace_out:
            obs.write_trace(args.trace_out)
            print(f"perfetto trace -> {args.trace_out} "
                  f"(open at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
