"""Training driver.

CPU-scale smoke:   PYTHONPATH=src python -m repro.launch.train \
                       --arch qwen1.5-4b --scale tiny --steps 30
Production shapes lower through the same code path as the dry-run; on a
real pod remove ``--scale tiny`` and launch one process per host with
``jax.distributed.initialize`` (the batch plane's job script does this).
"""
from __future__ import annotations

import argparse
import shutil

from repro.configs import get_config, scaled_down
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.training.optimizer import OptConfig
from repro.training.schedule import SCHEDULES
from repro.training.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--scale", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="warmup_cosine",
                    choices=sorted(SCHEDULES))
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor", "sgd"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fresh", action="store_true")
    ap.add_argument("--metrics-out", default="",
                    help="write a Prometheus text snapshot (step-time "
                         "histogram, tokens/s, est. MFU) here")
    ap.add_argument("--trace-out", default="",
                    help="write a Perfetto trace_event JSON of the "
                         "step/checkpoint/failure timeline here")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.scale == "tiny":
        cfg = scaled_down(cfg)
    if args.fresh:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    import functools
    sched = functools.partial(
        SCHEDULES[args.schedule], peak_lr=args.lr,
        warmup_steps=max(args.steps // 10, 1), total_steps=args.steps)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq_len,
                                  global_batch=args.global_batch))
    obs = None
    if args.metrics_out or args.trace_out:
        from repro.obs import Observability
        obs = Observability()
    tr = Trainer(cfg, OptConfig(name=args.optimizer, lr=args.lr), data,
                 TrainerConfig(num_steps=args.steps,
                               ckpt_every=args.ckpt_every,
                               ckpt_dir=args.ckpt_dir,
                               log_every=max(args.steps // 10, 1)),
                 schedule_fn=sched, obs=obs)
    if tr.restore_latest():
        print(f"resumed from checkpoint at step {tr.step}")
    print(f"training {cfg.name} ({cfg.param_count():,} params) "
          f"for {args.steps} steps")
    res = tr.run()
    for m in res["log"]:
        print(f"  step {m['step']:5d} loss {m['loss']:.4f} "
              f"acc {m['accuracy']:.3f} lr {m['lr']:.2e} "
              f"gnorm {m['grad_norm']:.2f}")
    print(f"done: final_step={res['final_step']} "
          f"restarts={res['restarts']}")
    if obs is not None:
        if args.metrics_out:
            obs.write_metrics(args.metrics_out)
            print(f"metrics snapshot -> {args.metrics_out}")
        if args.trace_out:
            obs.write_trace(args.trace_out)
            print(f"perfetto trace -> {args.trace_out}")


if __name__ == "__main__":
    main()
