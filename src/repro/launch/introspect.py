"""Per-op attribution over the trip-count-corrected HLO walk: the
"profiler" of the dry-run world.  Prints the top contributors to HBM
traffic and collective wire bytes (bytes x execution multiplier), with
op metadata so each line maps back to model code."""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

from repro.launch import hlo_analysis as H


def _meta(op) -> str:
    m = re.search(r'op_name="([^"]+)"', op.rest)
    return m.group(1)[-90:] if m else ""


def attribute(text: str, num_devices: int, top: int = 25):
    comps = H.parse_hlo(text)
    entry = next(c for c in comps.values() if c.is_entry)
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    mult[entry.name] = 1.0
    order, seen, i = [entry.name], {entry.name}, 0
    while i < len(order):
        comp = comps[order[i]]
        i += 1
        for op in comp.ops:
            called = H._called_computations(op)
            if not called:
                continue
            f = mult[comp.name]
            if op.opcode == "while":
                f *= H._trip_count(op, comps)
            for c in called:
                if c in comps:
                    mult[c] = mult.get(c, 0.0) + f
                    if c not in seen:
                        seen.add(c)
                        order.append(c)

    fusion_names = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                fusion_names.update(H._called_computations(op))

    traffic: List[Tuple[float, str, str, str]] = []
    coll: List[Tuple[float, str, str, str]] = []
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0 or comp.name in fusion_names:
            continue
        symtab = dict(comp.params)
        for op in comp.ops:
            symtab[op.name] = op.type_str
        for op in comp.ops:
            if op.opcode in H._SKIP_BYTES or op.opcode == "while":
                continue
            rb = H.shape_bytes(op.type_str)
            operands, _ = H._split_operands_attrs(op.rest)
            ob = sum(H.shape_bytes(symtab.get(n, ""))
                     for n in H._OPERAND_RE.findall(operands))
            traffic.append((m * (rb + ob), op.opcode,
                            op.type_str.split("{")[0][:40], _meta(op)))
            base = next((c for c in H.COLLECTIVES
                         if op.opcode in (c, c + "-start")), None)
            if base:
                n = H._group_size(op, num_devices)
                wire = {"all-gather": rb * (n - 1) / n,
                        "all-reduce": 2.0 * rb * (n - 1) / n,
                        "reduce-scatter": rb * (n - 1),
                        "all-to-all": rb * (n - 1) / n,
                        "collective-permute": rb}[base]
                coll.append((m * wire, f"{base}(n={n})x{int(m)}",
                             op.type_str.split("{")[0][:40], _meta(op)))

    traffic.sort(reverse=True)
    coll.sort(reverse=True)
    out = ["== top HBM traffic (bytes x mult; slab model) =="]
    for b, oc, ts, meta in traffic[:top]:
        out.append(f"  {b/2**30:9.2f} GiB {oc:12s} {ts:40s} {meta}")
    out.append("== top collective wire bytes ==")
    for b, oc, ts, meta in coll[:top]:
        out.append(f"  {b/2**30:9.2f} GiB {oc:22s} {ts:40s} {meta}")
    return "\n".join(out)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("hlo_file")
    ap.add_argument("--devices", type=int, default=256)
    ap.add_argument("--top", type=int, default=25)
    a = ap.parse_args()
    print(attribute(open(a.hlo_file).read(), a.devices, a.top))


if __name__ == "__main__":
    main()
