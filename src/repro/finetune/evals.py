"""Interleaved evaluation (Fig. 1: "evaluation is not a terminal step").

Perplexity + next-token accuracy on held-out streams; the capability
guard compares base-distribution perplexity before/after adaptation to
catch catastrophic forgetting (§4.3.1)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.param import cast_tree


def evaluate(cfg: ModelConfig, params, data, *, steps: int = 4,
             start_step: int = 1_000_000,
             compute_dtype=jnp.bfloat16) -> Dict[str, float]:
    pc = cast_tree(params, compute_dtype)
    loss_fn = jax.jit(lambda p, b: M.train_loss(cfg, p, b)[1])
    nll, n, correct = 0.0, 0.0, 0.0
    for i in range(steps):
        b = data.batch(start_step + i)
        b = {k: jnp.asarray(v) for k, v in b.items() if k != "source"}
        m = loss_fn(pc, b)
        nll += float(m["loss"]) * float(m["tokens"])
        correct += float(m["accuracy"]) * float(m["tokens"])
        n += float(m["tokens"])
    return {"nll": nll / n, "perplexity": float(np.exp(nll / n)),
            "accuracy": correct / n, "tokens": n}


class CapabilityGuard:
    """Safe-by-default gate: adaptation must not degrade base-distribution
    perplexity beyond ``tolerance`` (relative)."""

    def __init__(self, cfg: ModelConfig, base_data, tolerance: float = 0.10,
                 steps: int = 3):
        self.cfg = cfg
        self.base_data = base_data
        self.tolerance = tolerance
        self.steps = steps
        self.baseline: Dict[str, float] = {}

    def snapshot(self, params) -> Dict[str, float]:
        self.baseline = evaluate(self.cfg, params, self.base_data,
                                 steps=self.steps)
        return self.baseline

    def check(self, params) -> Dict[str, float]:
        after = evaluate(self.cfg, params, self.base_data, steps=self.steps)
        rel = (after["perplexity"] - self.baseline["perplexity"]) \
            / self.baseline["perplexity"]
        after["ppl_regression"] = rel
        after["passed"] = bool(rel <= self.tolerance)
        return after
