"""Release optimization (Fig. 1 "release optimizations"): per-channel
symmetric int8 weight quantization for serving artifacts."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_tree(params, min_size: int = 1024):
    """Returns one quantized tree.  2D+ leaves above ``min_size`` are
    stored as ``{"q": int8, "scale": f32 per output channel}``
    (symmetric); everything else is passed through as ``{"raw": leaf}``.
    ``dequantize_tree`` inverts it, and ``InferenceEngine`` accepts the
    quantized tree directly (dequantizing at param load)."""
    def one(leaf):
        if leaf.ndim < 2 or leaf.size < min_size:
            return {"raw": leaf}
        w = leaf.astype(jnp.float32)
        scale = jnp.max(jnp.abs(w), axis=-2, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale}
    return jax.tree.map(one, params)


def dequantize_tree(qtree, dtype=jnp.bfloat16):
    def one(leaf):
        if "raw" in leaf:
            return leaf["raw"].astype(dtype)
        return (leaf["q"].astype(jnp.float32) * leaf["scale"]).astype(dtype)
    return jax.tree.map(
        one, qtree,
        is_leaf=lambda x: isinstance(x, dict) and ("raw" in x or "q" in x))


def quantized_bytes(qtree) -> int:
    total = 0
    for leaf in jax.tree.leaves(qtree):
        total += leaf.size * leaf.dtype.itemsize
    return total
