"""Direct Preference Optimization — the alignment stage of the lifecycle
(Fig. 1 "alignment"; RL-free preference tuning suits the one-click tier)."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.finetune.lora import LoraConfig, lora_merge
from repro.models import model as M
from repro.models.param import cast_tree
from repro.training.optimizer import OptConfig, clip_by_global_norm, opt_update


def dpo_loss(cfg: ModelConfig, policy_params, ref_params, batch,
             beta: float = 0.1):
    """batch: {"chosen": lm-batch, "rejected": lm-batch}."""
    lp_c = M.sequence_logprob(cfg, policy_params, batch["chosen"])
    lp_r = M.sequence_logprob(cfg, policy_params, batch["rejected"])
    ref_c = jax.lax.stop_gradient(
        M.sequence_logprob(cfg, ref_params, batch["chosen"]))
    ref_r = jax.lax.stop_gradient(
        M.sequence_logprob(cfg, ref_params, batch["rejected"]))
    margin = beta * ((lp_c - ref_c) - (lp_r - ref_r))
    loss = -jnp.mean(jax.nn.log_sigmoid(margin))
    acc = jnp.mean((margin > 0).astype(jnp.float32))
    return loss, {"dpo_loss": loss, "preference_accuracy": acc,
                  "margin": jnp.mean(margin)}


def make_lora_dpo_step(cfg: ModelConfig, opt_cfg: OptConfig, base_params,
                       lcfg: LoraConfig, beta: float = 0.1,
                       schedule_fn: Optional[Callable] = None,
                       compute_dtype=jnp.bfloat16):
    """LoRA-DPO: the frozen base doubles as the reference policy, so no
    second model copy is materialized (memory-safe for the service tier)."""
    base_c = cast_tree(base_params, compute_dtype)

    def step(adapters, opt_state, batch):
        lr = (schedule_fn(opt_state["step"]) if schedule_fn
              else jnp.asarray(opt_cfg.lr, jnp.float32))

        def loss_fn(ad):
            merged = lora_merge(base_c, ad, lcfg, compute_dtype)
            return dpo_loss(cfg, merged, base_c, batch, beta)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(adapters)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        adapters, opt_state = opt_update(opt_cfg, grads, opt_state,
                                         adapters, lr)
        return adapters, opt_state, dict(metrics, grad_norm=gnorm, lr=lr)

    return step
