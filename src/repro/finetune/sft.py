"""Supervised fine-tuning: masked-CE over responses, full-parameter or
LoRA.  The LoRA step differentiates only the adapter tree (base frozen).

A trained adapter tree goes straight to serving via
:func:`publish_adapter` — no weight merge, no per-tenant model replica
(the shared-platform economics the paper is about)."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.finetune.lora import LoraConfig, lora_merge
from repro.models import model as M
from repro.models.param import cast_tree
from repro.training.optimizer import OptConfig, clip_by_global_norm, opt_update


def make_lora_sft_step(cfg: ModelConfig, opt_cfg: OptConfig,
                       base_params, lcfg: LoraConfig,
                       schedule_fn: Optional[Callable] = None,
                       compute_dtype=jnp.bfloat16):
    """Step over (adapters, opt_state, batch); base params are closed over
    and never updated."""
    base_c = cast_tree(base_params, compute_dtype)

    def step(adapters, opt_state, batch):
        lr = (schedule_fn(opt_state["step"]) if schedule_fn
              else jnp.asarray(opt_cfg.lr, jnp.float32))

        def loss_fn(ad):
            merged = lora_merge(base_c, ad, lcfg, compute_dtype)
            return M.train_loss(cfg, merged, batch)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(adapters)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        adapters, opt_state = opt_update(opt_cfg, grads, opt_state,
                                         adapters, lr)
        return adapters, opt_state, dict(metrics, grad_norm=gnorm, lr=lr)

    return step


def publish_adapter(pool, name: str, adapters, lcfg: LoraConfig) -> str:
    """Export a trained LoRA adapter tree directly into a serving
    adapter pool (``serving.adapters.AdapterPool`` or an engine with
    ``adapter_slots > 0``) — the fine-tune -> serve handoff without
    ``lora_merge``.  Returns ``name`` (the id requests use)."""
    register = getattr(pool, "register_adapter", None) or pool.register
    register(name, adapters, lcfg)
    return name


class LoraSFTData:
    """Adapter for Trainer-style .batch() over an SFT dataset."""

    def __init__(self, ds):
        self.ds = ds

    def batch(self, step, shard=0, num_shards=1):
        return self.ds.batch(step, shard, num_shards)
