"""Supervised fine-tuning: masked-CE over responses, full-parameter or
LoRA.  The LoRA step differentiates only the adapter tree (base frozen).

A trained adapter tree goes straight to serving via
:func:`publish_adapter` — no weight merge, no per-tenant model replica
(the shared-platform economics the paper is about)."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.finetune.lora import LoraConfig, lora_merge
from repro.models import model as M
from repro.models.param import cast_tree
from repro.training.optimizer import OptConfig, clip_by_global_norm, opt_update


def make_lora_sft_step(cfg: ModelConfig, opt_cfg: OptConfig,
                       base_params, lcfg: LoraConfig,
                       schedule_fn: Optional[Callable] = None,
                       compute_dtype=jnp.bfloat16):
    """Step over (adapters, opt_state, batch); base params are closed over
    and never updated."""
    base_c = cast_tree(base_params, compute_dtype)

    def step(adapters, opt_state, batch):
        lr = (schedule_fn(opt_state["step"]) if schedule_fn
              else jnp.asarray(opt_cfg.lr, jnp.float32))

        def loss_fn(ad):
            merged = lora_merge(base_c, ad, lcfg, compute_dtype)
            return M.train_loss(cfg, merged, batch)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(adapters)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        adapters, opt_state = opt_update(opt_cfg, grads, opt_state,
                                         adapters, lr)
        return adapters, opt_state, dict(metrics, grad_norm=gnorm, lr=lr)

    return step


def instrument_sft_step(step_fn, cfg: ModelConfig, obs,
                        peak_flops: float = 197e12,
                        clock: Optional[Callable[[], float]] = None):
    """Wrap an SFT step with host-side observability: step-time
    histogram, token counters, throughput + estimated-MFU gauges, and
    one trace span per step on the ``finetune`` track.

    The wrapper sits *outside* the jit (the step itself is untouched),
    so it times dispatch wall like the trainer loop and adds no device
    syncs.  MFU counts the full merged forward/backward (6*N*tokens) —
    LoRA still pays the base model's FLOPs even though only the adapter
    tree gets gradients."""
    import numpy as np
    reg = obs.registry
    h_step = reg.histogram("repro_finetune_step_seconds",
                           "SFT step wall time")
    c_steps = reg.counter("repro_finetune_steps_total",
                          "SFT optimizer steps completed")
    c_tokens = reg.counter("repro_finetune_tokens_total",
                           "SFT tokens consumed")
    g_tps = reg.gauge("repro_finetune_tokens_per_s",
                      "SFT throughput, last step")
    g_mfu = reg.gauge("repro_finetune_mfu_ratio",
                      "est. model FLOPs utilisation of the SFT step")
    n_params = cfg.param_count(active_only=True)
    clk = clock if clock is not None else obs.clock
    state = {"step": 0}

    def wrapped(params, opt_state, batch):
        t0 = clk()
        sp = obs.tracer.begin("finetune", f"sft_step {state['step']}",
                              cat="finetune")
        out = step_fn(params, opt_state, batch)
        wall = clk() - t0
        obs.tracer.end(sp)
        state["step"] += 1
        tok = batch.get("tokens") if hasattr(batch, "get") else None
        n_tok = int(np.prod(tok.shape)) if tok is not None else 0
        h_step.observe(wall)
        c_steps.inc()
        c_tokens.inc(n_tok)
        if wall > 0 and n_tok:
            g_tps.set(n_tok / wall)
            g_mfu.set(6.0 * n_params * n_tok / (wall * peak_flops))
        return out

    return wrapped


def publish_adapter(pool, name: str, adapters, lcfg: LoraConfig) -> str:
    """Export a trained LoRA adapter tree directly into a serving
    adapter pool (``serving.adapters.AdapterPool`` or an engine with
    ``adapter_slots > 0``) — the fine-tune -> serve handoff without
    ``lora_merge``.  Returns ``name`` (the id requests use)."""
    register = getattr(pool, "register_adapter", None) or pool.register
    register(name, adapters, lcfg)
    return name


class LoraSFTData:
    """Adapter for Trainer-style .batch() over an SFT dataset."""

    def __init__(self, ds):
        self.ds = ds

    def batch(self, step, shard=0, num_shards=1):
        return self.ds.batch(step, shard, num_shards)
