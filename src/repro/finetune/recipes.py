"""Curated fine-tuning recipe catalog (paper §4.3).

Two tiers, mirroring the paper's user dichotomy:
- "one-click" recipes: safe-by-default (LoRA, bounded lr/rank, capability
  guard ON).  Tenants may override only whitelisted knobs within bounds.
- "expert" recipes: full-parameter, guard advisory only — the Slurm-direct
  crowd.

Applicability is family-aware (DESIGN.md §7): attention-targeted LoRA is
inapplicable to attention-free archs; mamba archs get in/out-projection
targets instead.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.finetune.lora import (DEFAULT_TARGETS, MAMBA_TARGETS, MLP_TARGETS,
                                 LoraConfig)
from repro.training.optimizer import OptConfig


@dataclasses.dataclass(frozen=True)
class Recipe:
    name: str
    description: str
    stage: str                     # sft | align
    tier: str                      # one-click | expert
    families: Tuple[str, ...]      # applicable model families
    lora: Optional[LoraConfig]     # None = full-parameter
    opt: OptConfig = OptConfig(lr=1e-4, weight_decay=0.0)
    guard_tolerance: Optional[float] = 0.10  # None = guard advisory
    # whitelisted overrides: name -> (min, max)
    tunable: Dict[str, Tuple[float, float]] = dataclasses.field(
        default_factory=lambda: {"lr": (1e-6, 3e-4), "rank": (2, 64),
                                 "steps": (1, 10_000)})


def _targets_for(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.family == "ssm":
        return MAMBA_TARGETS
    if cfg.family == "hybrid":
        return tuple(set(DEFAULT_TARGETS) | set(MAMBA_TARGETS))
    return DEFAULT_TARGETS


CATALOG: Dict[str, Recipe] = {}


def _register(r: Recipe):
    CATALOG[r.name] = r
    return r


_register(Recipe(
    name="sft_lora_safe",
    description="One-click SFT: LoRA r=8 on attention projections, "
                "cosine lr<=1e-4, capability guard enforced.",
    stage="sft", tier="one-click",
    families=("dense", "moe", "vlm", "audio", "hybrid", "ssm"),
    lora=LoraConfig(rank=8, alpha=16.0),
))

_register(Recipe(
    name="sft_lora_wide",
    description="SFT with LoRA on attention+MLP (higher capacity, still "
                "guard-enforced).",
    stage="sft", tier="one-click",
    families=("dense", "moe", "vlm", "audio"),
    lora=LoraConfig(rank=16, alpha=32.0,
                    targets=tuple(set(DEFAULT_TARGETS) | set(MLP_TARGETS))),
))

_register(Recipe(
    name="dpo_lora_safe",
    description="One-click preference alignment: LoRA-DPO beta=0.1; the "
                "frozen base doubles as the reference policy.",
    stage="align", tier="one-click",
    families=("dense", "moe", "vlm", "audio", "hybrid", "ssm"),
    lora=LoraConfig(rank=8, alpha=16.0),
    opt=OptConfig(lr=5e-5, weight_decay=0.0),
))

_register(Recipe(
    name="sft_full_expert",
    description="Expert-tier full-parameter SFT (Slurm-direct users); "
                "guard advisory only.",
    stage="sft", tier="expert",
    families=("dense", "moe", "vlm", "audio", "hybrid", "ssm"),
    lora=None,
    opt=OptConfig(lr=2e-5, weight_decay=0.0),
    guard_tolerance=None,
))


class RecipeError(ValueError):
    pass


def resolve(name: str, cfg: ModelConfig,
            overrides: Optional[Dict[str, Any]] = None
            ) -> Tuple[Recipe, LoraConfig, OptConfig, Dict[str, Any]]:
    """Validate applicability + clamp overrides to the whitelist."""
    if name not in CATALOG:
        raise RecipeError(f"unknown recipe {name!r}; catalog: "
                          f"{sorted(CATALOG)}")
    r = CATALOG[name]
    if cfg.family not in r.families:
        raise RecipeError(
            f"recipe {name} not applicable to family {cfg.family!r}")
    overrides = dict(overrides or {})
    extra: Dict[str, Any] = {"steps": 20}
    opt = r.opt
    lora = r.lora
    for k, v in overrides.items():
        if k not in r.tunable:
            raise RecipeError(
                f"override {k!r} is not tunable in {name} "
                f"(allowed: {sorted(r.tunable)})")
        lo, hi = r.tunable[k]
        if not (lo <= float(v) <= hi):
            raise RecipeError(
                f"override {k}={v} outside safe bounds [{lo}, {hi}]")
        if k == "lr":
            opt = dataclasses.replace(opt, lr=float(v))
        elif k == "rank" and lora is not None:
            lora = dataclasses.replace(lora, rank=int(v),
                                       alpha=2.0 * int(v))
        else:
            extra[k] = v
    if lora is not None:
        # family-aware targets (attention LoRA inapplicable to SSM archs)
        lora = dataclasses.replace(lora, targets=tuple(
            t for t in (set(lora.targets) | set(_targets_for(cfg)))
            if cfg.family not in ("ssm",) or t in MAMBA_TARGETS))
    return r, lora, opt, extra
