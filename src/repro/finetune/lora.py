"""LoRA adapters (paper §4.3: "safe-by-default" fine-tuning blueprints).

Adapters are a sparse pytree mirroring selected 2-D (or stacked 3-D)
parameter leaves; ``merge`` materializes W + (alpha/r)·A·B in compute
dtype.  Training differentiates only the adapter tree, so the base model
cannot be damaged — the mechanism behind the catastrophic-forgetting
guarantee for non-expert tenants.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro._compat import tree_flatten_with_path

# default targets per mixer family; attention-specific entries are simply
# absent in attention-free archs (see recipes.applicability)
DEFAULT_TARGETS = ("wq", "wk", "wv", "wo", "wuk", "wuv", "wuq")
MAMBA_TARGETS = ("wx", "wz", "wo")
MLP_TARGETS = ("gate", "up", "down")


@dataclasses.dataclass(frozen=True)
class LoraConfig:
    rank: int = 8
    alpha: float = 16.0
    targets: Tuple[str, ...] = DEFAULT_TARGETS

    @property
    def scale(self) -> float:
        return self.alpha / self.rank


def _leaf_name(path) -> str:
    last = path[-1]
    return getattr(last, "key", str(last))


def lora_init(params, lcfg: LoraConfig, key: jax.Array,
              dtype=jnp.float32):
    """Adapters {path_str: {"a": (..., din, r), "b": (..., r, dout)}}."""
    adapters = {}
    leaves = tree_flatten_with_path(params)[0]
    keys = jax.random.split(key, max(len(leaves), 1))
    for (path, leaf), k in zip(leaves, keys):
        if _leaf_name(path) not in lcfg.targets or leaf.ndim < 2:
            continue
        *batch, din, dout = leaf.shape
        a = jax.random.normal(k, (*batch, din, lcfg.rank), jnp.float32)
        a = (a / jnp.sqrt(din)).astype(dtype)
        b = jnp.zeros((*batch, lcfg.rank, dout), dtype)
        adapters[jax.tree_util.keystr(path)] = {"a": a, "b": b}
    return adapters


def lora_merge(params, adapters, lcfg: LoraConfig, dtype=None):
    """Materialize merged weights; non-target leaves pass through."""
    flat = tree_flatten_with_path(params)
    out = []
    for path, leaf in flat[0]:
        ks = jax.tree_util.keystr(path)
        if ks in adapters:
            ab = adapters[ks]
            delta = jnp.einsum("...ir,...ro->...io",
                               ab["a"].astype(jnp.float32),
                               ab["b"].astype(jnp.float32))
            leaf = (leaf.astype(jnp.float32)
                    + lcfg.scale * delta).astype(dtype or leaf.dtype)
        elif dtype is not None:
            leaf = leaf.astype(dtype)
        out.append(leaf)
    return jax.tree.unflatten(jax.tree.structure(params), out)


def lora_param_count(adapters) -> int:
    return sum(x.size for x in jax.tree.leaves(adapters))


def lora_export(adapters) -> Dict[str, jnp.ndarray]:
    """Flat dict for artifact storage (registered as an 'adapter')."""
    out = {}
    for k, ab in adapters.items():
        out[f"{k}.a"] = ab["a"]
        out[f"{k}.b"] = ab["b"]
    return out


def lora_randomize(adapters, key: jax.Array, scale: float = 0.05):
    """Give the zero-init B matrices small random values.

    A freshly ``lora_init``'d adapter is an *exact* zero delta (that is
    the identity-at-init guarantee); demos, benchmarks, and tests need
    adapters that actually shift outputs without running an SFT loop —
    this stands in for training."""
    out = {}
    for name, ab in adapters.items():
        key, k2 = jax.random.split(key)
        out[name] = {"a": ab["a"],
                     "b": scale * jax.random.normal(k2, ab["b"].shape,
                                                    ab["b"].dtype)}
    return out


def lora_unflatten(flat: Dict[str, jnp.ndarray]):
    """Invert :func:`lora_export`: flat ``{"<path>.a": arr}`` back to the
    nested ``{path: {"a", "b"}}`` adapter tree (so a stored artifact can
    be trained further or registered with a serving ``AdapterPool``)."""
    out: Dict[str, Dict[str, jnp.ndarray]] = {}
    for k, v in flat.items():
        if not (k.endswith(".a") or k.endswith(".b")):
            raise ValueError(f"not an exported adapter leaf: {k!r}")
        out.setdefault(k[:-2], {})[k[-1]] = v
    return out
