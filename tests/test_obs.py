"""Observability: metrics registry, tracer, collector edge cases, and
end-to-end instrumentation through the engine, gateway, and trainer."""
import itertools
import json
import math

import pytest

from repro.obs import Observability, MetricsRegistry, Tracer
from repro.obs.registry import validate_metric_name
from repro.serving.metrics import MetricsCollector, TracingMetricsCollector


def _vclock(step=1.0):
    t = itertools.count()
    return lambda: next(t) * step


# --------------------------------------------------------------- registry
def test_counter_inc_and_labels():
    reg = MetricsRegistry()
    c = reg.counter("repro_kv_hits_total", "h", labelnames=("kind",))
    c.labels(kind="a").inc()
    c.labels(kind="a").inc(2)
    c.labels(kind="b").inc()
    snap = reg.snapshot()
    assert snap['repro_kv_hits_total{kind="a"}'] == 3
    assert snap['repro_kv_hits_total{kind="b"}'] == 1
    with pytest.raises(ValueError):
        c.labels(kind="a").inc(-1)          # counters only go up
    with pytest.raises(ValueError):
        c.labels(wrong="a")                 # label names must match


def test_gauge_set_dec():
    reg = MetricsRegistry()
    g = reg.gauge("repro_kv_used_blocks")
    g.set(7)
    g.dec(2)
    assert reg.snapshot()["repro_kv_used_blocks"] == 5


def test_histogram_bucket_boundaries():
    reg = MetricsRegistry()
    h = reg.histogram("repro_sched_tick_seconds", buckets=(1.0, 2.0, 5.0))
    for v in (0.5, 1.0, 1.5, 2.0, 7.0):
        h.observe(v)
    snap = reg.snapshot()["repro_sched_tick_seconds"]
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(12.0)
    # Prometheus le semantics: a value exactly on a boundary counts in
    # that le bucket (le = less-or-equal), buckets are cumulative
    assert snap["buckets"] == [(1.0, 2), (2.0, 4), (5.0, 4), ("+Inf", 5)]


def test_histogram_buckets_must_increase():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.histogram("repro_sched_bad_seconds", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        reg.histogram("repro_sched_dup_seconds", buckets=(1.0, 1.0))


def test_name_validation():
    assert validate_metric_name("repro_kv_used_blocks") is None
    assert validate_metric_name("repro_sched_preemptions_total",
                                "counter") is None
    # not our prefix / wrong case / missing unit suffix
    assert validate_metric_name("kv_used_blocks") is not None
    assert validate_metric_name("repro_KV_used_blocks") is not None
    assert validate_metric_name("repro_kv_used") is not None
    # kind rules: counters end _total, gauges/histograms must not
    assert validate_metric_name("repro_kv_used_blocks",
                                "counter") is not None
    assert validate_metric_name("repro_kv_hits_total",
                                "gauge") is not None
    assert validate_metric_name("repro_kv_hits_total",
                                "histogram") is not None
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.counter("repro_kv_used_blocks")


def test_reregistration_is_get_or_create():
    reg = MetricsRegistry()
    a = reg.counter("repro_kv_hits_total")
    a.inc(3)
    b = reg.counter("repro_kv_hits_total")   # same family back
    assert b.value == 3
    with pytest.raises(ValueError):
        reg.gauge("repro_kv_hits_total")     # kind changed
    with pytest.raises(ValueError):
        reg.counter("repro_kv_hits_total", labelnames=("x",))


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("repro_kv_hits_total", "cache hits",
                labelnames=("kind",)).labels(kind="radix").inc(4)
    reg.gauge("repro_kv_used_blocks", "blocks in use").set(float("nan"))
    reg.histogram("repro_sched_tick_seconds",
                  buckets=(0.5, 1.0)).observe(0.25)
    text = reg.to_prometheus()
    assert "# HELP repro_kv_hits_total cache hits" in text
    assert "# TYPE repro_kv_hits_total counter" in text
    assert 'repro_kv_hits_total{kind="radix"} 4' in text
    assert "repro_kv_used_blocks NaN" in text
    assert "# TYPE repro_sched_tick_seconds histogram" in text
    assert 'repro_sched_tick_seconds_bucket{le="0.5"} 1' in text
    assert 'repro_sched_tick_seconds_bucket{le="+Inf"} 1' in text
    assert "repro_sched_tick_seconds_sum 0.25" in text
    assert "repro_sched_tick_seconds_count 1" in text
    # JSON surface parses and carries the same families
    doc = json.loads(reg.to_json())
    assert {m["name"] for m in doc["metrics"]} == {
        "repro_kv_hits_total", "repro_kv_used_blocks",
        "repro_sched_tick_seconds"}


# ----------------------------------------------------------------- tracer
def test_tracer_spans_nest_by_containment():
    tr = Tracer(clock=_vclock())
    with tr.span("scheduler", "tick", cat="sched", queued=2):
        with tr.span("scheduler", "micro_step"):
            pass
    evs = tr.events_for("scheduler")
    inner = next(e for e in evs if e["name"] == "micro_step")
    outer = next(e for e in evs if e["name"] == "tick")
    assert outer["ts"] <= inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]
    assert outer["args"] == {"queued": 2} and outer["cat"] == "sched"


def test_tracer_end_idempotent_and_instants():
    tr = Tracer(clock=_vclock())
    s = tr.begin("req", "decode")
    tr.end(s, n=3)
    tr.end(s, n=99)                          # double-end ignored
    tr.instant("req", "finish", cat="request")
    evs = tr.events_for("req")
    assert [e["ph"] for e in evs] == ["X", "i"]
    assert evs[0]["args"] == {"n": 3}
    assert evs[1]["s"] == "t"


def test_tracer_event_cap_counts_drops():
    tr = Tracer(clock=_vclock(), max_events=2)
    for _ in range(4):
        tr.instant("t", "e")
    assert tr.n_events == 2 and tr.dropped == 2
    assert tr.to_perfetto()["otherData"]["dropped_events"] == 2


def test_perfetto_round_trip():
    tr = Tracer(clock=_vclock(), process="test-proc")
    with tr.span("scheduler", "tick"):
        pass
    tr.counter("scheduler", "queue", depth=3)
    doc = json.loads(tr.to_json())
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    procs = [e for e in evs if e["ph"] == "M"
             and e["name"] == "process_name"]
    assert procs[0]["args"]["name"] == "test-proc"
    threads = {e["args"]["name"]: e["tid"] for e in evs
               if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "scheduler" in threads
    x = next(e for e in evs if e["ph"] == "X")
    assert x["tid"] == threads["scheduler"] and x["dur"] >= 0
    assert any(e["ph"] == "C" for e in evs)


# ----------------------------------------------- collector edge cases
def test_summary_empty_collector():
    s = MetricsCollector().summary()
    assert s["completed"] == 0 and s["rejected"] == 0
    assert s["preempted"] == 0
    assert math.isnan(s["qps"]) and math.isnan(s["ttft_p50_s"])
    assert math.isnan(s["preempt_to_resume_mean_s"])
    assert s["prefix_hit_rate"] == 0.0
    assert s["generated_tokens"] == 0


def test_summary_rejected_only():
    mc = MetricsCollector()
    mc.arrival("r1", 0.0, 10)
    mc.reject("r1", 1.0)
    s = mc.summary()
    assert s["rejected"] == 1 and s["completed"] == 0
    # rejections must not pollute latency quantiles / token accounting
    assert math.isnan(s["e2el_mean_s"]) and math.isnan(s["ttft_p50_s"])
    assert s["prompt_tokens"] == 0


def test_summary_all_preempted_never_resumed():
    mc = MetricsCollector()
    mc.arrival("r1", 0.0, 4)
    mc.prefill_start("r1", 1.0)
    mc.preempt("r1", 3.0)
    s = mc.summary()
    assert s["preempted"] == 1 and s["completed"] == 0
    # the preempt interval never closed: no resume delay to average
    assert math.isnan(s["preempt_to_resume_mean_s"])


def test_preempt_timestamps_surface_time_to_resume():
    """The old ``preempt(rid, t)`` dropped ``t`` on the floor; it must
    now pair with the next ``prefill_start`` into a resume delay."""
    mc = MetricsCollector()
    mc.arrival("r1", 0.0, 4)
    mc.prefill_start("r1", 1.0)
    mc.preempt("r1", 3.0)
    mc.prefill_start("r1", 8.0)      # re-admitted 5s later
    mc.preempt("r1", 10.0)
    mc.prefill_start("r1", 11.0)     # and again, 1s later
    mc.token("r1", 12.0)
    mc.finish("r1", 12.0)
    r = mc.requests["r1"]
    assert r.preempt_times == [3.0, 10.0]
    assert r.resume_times == [8.0, 11.0]
    assert r.resume_delays == [5.0, 1.0]
    assert mc.summary()["preempt_to_resume_mean_s"] == pytest.approx(3.0)


def test_tracing_collector_lifecycle_and_resume_histogram():
    obs = Observability(clock=_vclock())
    mc = TracingMetricsCollector(obs)
    mc.arrival("r1", 0.0, 4)
    mc.prefill_start("r1", 1.0)
    mc.preempt("r1", 2.0)
    mc.prefill_start("r1", 6.0)
    mc.token("r1", 7.0)
    mc.token("r1", 8.0)
    mc.finish("r1", 8.5)
    names = [e["name"] for e in obs.tracer.events_for("req r1")]
    # spans close in lifecycle order; finish instant last
    assert names == ["queued", "prefill", "preempted", "prefill",
                     "decode", "finish"]
    snap = obs.registry.snapshot()
    assert snap["repro_sched_admitted_requests_total"] == 2
    assert snap["repro_sched_preemptions_total"] == 1
    assert snap["repro_serving_preempt_resume_seconds"]["count"] == 1
    assert snap["repro_serving_preempt_resume_seconds"]["sum"] == 4.0
    assert snap["repro_serving_ttft_seconds"]["count"] == 1
    assert snap["repro_serving_itl_seconds"]["count"] == 1
    # summary behaviour identical to the plain collector
    assert mc.summary()["completed"] == 1


# ------------------------------------------------------------ integration
def test_engine_instrumented_end_to_end(tiny_cfg, tiny_params):
    from repro.serving.engine import InferenceEngine, Request
    t = itertools.count()
    obs = Observability(clock=lambda: float(next(t)))
    eng = InferenceEngine(tiny_cfg, tiny_params, max_batch=2, capacity=64,
                          clock=obs.clock, obs=obs)
    for p in ([1, 2, 3], [4, 5, 6, 7]):
        eng.submit(Request(prompt=p, max_new_tokens=4))
    s = eng.run_until_idle()
    assert s["completed"] == 2
    eng.collect_metrics()
    snap = obs.registry.snapshot()
    assert snap["repro_serving_finished_requests_total"] == 2
    assert snap["repro_serving_generated_tokens_total"] == 8
    assert snap["repro_sched_admitted_requests_total"] == 2
    assert snap["repro_sched_tick_seconds"]["count"] > 0
    assert snap["repro_sched_batch_occupancy_ratio"]["count"] > 0
    assert snap["repro_kv_capacity_blocks"] > 0
    # every request's lifecycle reconstructs on its own track
    doc = json.loads(obs.tracer.to_json())
    tracks = {e["args"]["name"] for e in doc["traceEvents"]
              if e.get("ph") == "M" and e["name"] == "thread_name"}
    req_tracks = {n for n in tracks if n.startswith("req ")}
    assert len(req_tracks) == 2 and "scheduler" in tracks
    for rt in req_tracks:
        names = [e["name"] for e in obs.tracer.events_for(rt)
                 if e["ph"] == "X"]
        assert names[0] == "queued" and "prefill" in names \
            and "decode" in names


def test_engine_without_obs_unchanged(tiny_cfg, tiny_params):
    from repro.serving.engine import InferenceEngine, Request
    from repro.serving.metrics import MetricsCollector
    eng = InferenceEngine(tiny_cfg, tiny_params, max_batch=2, capacity=64)
    assert eng.obs is None
    assert type(eng.metrics) is MetricsCollector
    eng.submit(Request(prompt=[1, 2], max_new_tokens=2))
    assert eng.run_until_idle()["completed"] == 1
    with pytest.raises(ValueError):
        eng.collect_metrics()            # no registry anywhere


def test_gateway_rejections_counted():
    from repro.core.gateway import Gateway, Unauthorized
    obs = Observability(clock=_vclock())
    gw = Gateway(clock=obs.clock, obs=obs)
    k = gw.mint_key("acme")
    with pytest.raises(Unauthorized):
        gw.completion(api_key=k.key, model="no-such-model", prompt=[1])
    with pytest.raises(Unauthorized):
        gw.completion(api_key="sk-bogus", model="no-such-model",
                      prompt=[1])
    snap = obs.registry.snapshot()
    assert snap[
        'repro_gateway_rejected_requests_total{kind="Unauthorized"}'] == 2


def test_trainer_emits_step_and_mfu_series(tiny_cfg, tmp_path):
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.training.optimizer import OptConfig
    from repro.training.trainer import Trainer, TrainerConfig
    obs = Observability()
    data = SyntheticLM(DataConfig(vocab_size=tiny_cfg.vocab_size,
                                  seq_len=16, global_batch=2))
    tr = Trainer(tiny_cfg, OptConfig(lr=1e-3), data,
                 TrainerConfig(num_steps=3, ckpt_every=100,
                               ckpt_dir=str(tmp_path), log_every=1),
                 obs=obs)
    tr.run()
    snap = obs.registry.snapshot()
    assert snap["repro_train_steps_total"] == 3
    assert snap["repro_train_tokens_total"] == 3 * 2 * 16
    assert snap["repro_train_step_seconds"]["count"] == 3
    assert snap["repro_train_tokens_per_s"] > 0
    assert 0 < snap["repro_train_mfu_ratio"] < 1
    steps = [e for e in obs.tracer.events_for("train")
             if e["ph"] == "X"]
    assert len(steps) == 3
    text = obs.registry.to_prometheus()
    assert "repro_train_mfu_ratio" in text
