"""Golden-token regression net: one tiny model per architecture family
runs the full serve path greedily and must reproduce the committed
tokens exactly.

The fixtures pin serve-path *numerics* end to end (forward pass, KV
bookkeeping, fused decode sampling): a refactor that perturbs logits
becomes a loud token diff here instead of a silent quality drop in real
checkpoints.  If a change breaks these on purpose, regenerate with

    PYTHONPATH=src python tools/regen_goldens.py

and justify the fixture update in the same commit (see the script's
docstring for the determinism rules).
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, scaled_down
from repro.finetune.lora import LoraConfig, lora_init, lora_randomize
from repro.models import model as M
from repro.serving.adapters import supports_multi_lora
from repro.serving.engine import InferenceEngine, Request

GOLDEN = json.loads(
    (Path(__file__).parent / "golden" / "golden_tokens.json").read_text())


def _served(g):
    cfg = scaled_down(get_config(g["arch"]))
    return cfg, M.init(cfg, jax.random.PRNGKey(0), jnp.float32)


def _run(cfg, params, prompts, lens, adapter="", **kw):
    eng = InferenceEngine(cfg, params, max_batch=4, capacity=128, **kw)
    reqs = [Request(prompt=list(p), max_new_tokens=n, adapter=adapter)
            for p, n in zip(prompts, lens)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    return [r.generated for r in reqs], eng


@pytest.mark.parametrize("family", sorted(GOLDEN))
def test_golden_tokens(family):
    g = GOLDEN[family]
    cfg, params = _served(g)
    got, eng = _run(cfg, params, g["prompts"],
                    [len(w) for w in g["generated"]])
    assert eng.paged == g["paged"], "KV layout auto-select changed"
    assert got == g["generated"], (
        f"{family} ({g['arch']}) greedy tokens drifted; if intentional, "
        f"rerun tools/regen_goldens.py and commit the new fixture")


@pytest.mark.parametrize("kind", ["ngram", "draft"])
@pytest.mark.parametrize("family", sorted(GOLDEN))
def test_golden_speculative_tokens(family, kind):
    """Both drafters must reproduce the committed greedy stream: the
    fixture pins the verify/accept numerics AND their identity with the
    plain decode path (one drift shows up as two distinct diffs)."""
    g = GOLDEN[family]
    if "spec_generated" not in g:
        pytest.skip(f"{family} does not support speculative decoding")
    cfg, params = _served(g)
    kw = ({"draft_cfg": cfg, "draft_params": params}
          if kind == "draft" else {})
    got, _ = _run(cfg, params, g["spec_prompts"],
                  [len(w) for w in g["spec_generated"]],
                  speculative=kind, spec_k=3, **kw)
    assert got == g["spec_generated"], (
        f"{family} spec({kind}) tokens drifted from the plain-path "
        f"golden; rerun tools/regen_goldens.py if intentional")


@pytest.mark.parametrize("family", sorted(GOLDEN))
def test_golden_lora_tokens(family):
    """Adapter'd decode is pinned with a deterministic randomized LoRA
    (seeds 1/2, rank from the fixture) — drift in the factored-weight
    batched decode path lands here."""
    g = GOLDEN[family]
    if "lora_generated" not in g:
        pytest.skip(f"{family} does not support multi-LoRA serving")
    cfg, params = _served(g)
    assert supports_multi_lora(cfg)
    lcfg = LoraConfig(rank=g["lora_rank"])
    ad = lora_randomize(lora_init(params, lcfg, jax.random.PRNGKey(1)),
                        jax.random.PRNGKey(2))
    eng = InferenceEngine(cfg, params, max_batch=4, capacity=128,
                          adapter_slots=2)
    eng.register_adapter("golden", ad, lcfg)
    reqs = [Request(prompt=list(p), max_new_tokens=len(w),
                    adapter="golden")
            for p, w in zip(g["prompts"], g["lora_generated"])]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    got = [r.generated for r in reqs]
    assert got == g["lora_generated"], (
        f"{family} LoRA tokens drifted; rerun tools/regen_goldens.py "
        f"if intentional")
    assert got != g["generated"]         # the adapter is not a no-op


def test_golden_fixture_shape():
    # the fixture itself stays well-formed (regen script contract)
    assert set(GOLDEN) == {"gqa", "mla_moe", "ssm", "hybrid_moe"}
    for g in GOLDEN.values():
        assert len(g["prompts"]) == len(g["generated"]) == 3
        assert all(len(t) > 0 for t in g["generated"])
        # variant nets ride on the same fixture where supported
        if "spec_generated" in g:
            assert len(g["spec_prompts"]) == len(g["spec_generated"]) == 3
            assert all(len(t) > 0 for t in g["spec_generated"])
        if "lora_generated" in g:
            assert len(g["lora_generated"]) == len(g["generated"])
            assert g["lora_rank"] > 0
    # the two attention families carry both variant nets
    for fam in ("gqa", "mla_moe"):
        assert "spec_generated" in GOLDEN[fam]
        assert "lora_generated" in GOLDEN[fam]
