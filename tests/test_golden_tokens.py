"""Golden-token regression net: one tiny model per architecture family
runs the full serve path greedily and must reproduce the committed
tokens exactly.

The fixtures pin serve-path *numerics* end to end (forward pass, KV
bookkeeping, fused decode sampling): a refactor that perturbs logits
becomes a loud token diff here instead of a silent quality drop in real
checkpoints.  If a change breaks these on purpose, regenerate with

    PYTHONPATH=src python tools/regen_goldens.py

and justify the fixture update in the same commit (see the script's
docstring for the determinism rules).
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, scaled_down
from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request

GOLDEN = json.loads(
    (Path(__file__).parent / "golden" / "golden_tokens.json").read_text())


@pytest.mark.parametrize("family", sorted(GOLDEN))
def test_golden_tokens(family):
    g = GOLDEN[family]
    cfg = scaled_down(get_config(g["arch"]))
    params = M.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = InferenceEngine(cfg, params, max_batch=4, capacity=128)
    assert eng.paged == g["paged"], "KV layout auto-select changed"
    reqs = [Request(prompt=list(p), max_new_tokens=len(want))
            for p, want in zip(g["prompts"], g["generated"])]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    got = [r.generated for r in reqs]
    assert got == g["generated"], (
        f"{family} ({g['arch']}) greedy tokens drifted; if intentional, "
        f"rerun tools/regen_goldens.py and commit the new fixture")


def test_golden_fixture_shape():
    # the fixture itself stays well-formed (regen script contract)
    assert set(GOLDEN) == {"gqa", "mla_moe", "ssm", "hybrid_moe"}
    for g in GOLDEN.values():
        assert len(g["prompts"]) == len(g["generated"]) == 3
        assert all(len(t) > 0 for t in g["generated"])
