"""Attention: blockwise == naive oracle; decode == teacher forcing."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import (blockwise_attention, decode_attention,
                                    naive_attention)


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=12, deadline=None)
@given(
    B=st.integers(1, 3),
    KV=st.sampled_from([1, 2, 4]),
    G=st.sampled_from([1, 2, 7]),
    S=st.sampled_from([8, 33, 64, 100]),
    D=st.sampled_from([8, 32]),
    chunk=st.sampled_from([16, 32, 1024]),
    causal=st.booleans(),
)
def test_blockwise_matches_naive(B, KV, G, S, D, chunk, causal):
    H = KV * G
    q = _rand(1, B, S, H, D)
    k = _rand(2, B, S, KV, D)
    v = _rand(3, B, S, KV, D)
    got = blockwise_attention(q, k, v, causal=causal, kv_chunk=chunk)
    want = naive_attention(q, k, v, causal=causal)
    assert jnp.max(jnp.abs(got - want)) < 1e-4


def test_kv_valid_len_masks_padding():
    B, S, KV, G, D = 2, 32, 2, 2, 16
    H = KV * G
    q = _rand(1, B, S, H, D)
    k = _rand(2, B, S, KV, D)
    v = _rand(3, B, S, KV, D)
    valid = jnp.asarray([20, 32])
    got = blockwise_attention(q, k, v, causal=True, kv_chunk=8,
                              kv_valid_len=valid)
    # sequence 0: results at q<20 must equal the truncated computation
    got_trunc = blockwise_attention(q[:1, :20], k[:1, :20], v[:1, :20],
                                    causal=True, kv_chunk=8)
    assert jnp.max(jnp.abs(got[0, :20] - got_trunc[0])) < 1e-4


def test_decode_matches_last_row_of_full():
    B, S, KV, G, D = 2, 24, 2, 3, 16
    H = KV * G
    q_all = _rand(1, B, S, H, D)
    k = _rand(2, B, S, KV, D)
    v = _rand(3, B, S, KV, D)
    full = naive_attention(q_all, k, v, causal=True)
    # decode the last position with the cache filled to S
    lengths = jnp.full((B,), S, jnp.int32)
    got = decode_attention(q_all[:, -1:], k, v, lengths)
    assert jnp.max(jnp.abs(got[:, 0] - full[:, -1])) < 1e-4


def test_decode_respects_lengths():
    B, S, KV, G, D = 2, 16, 1, 2, 8
    H = KV * G
    q = _rand(1, B, 1, H, D)
    k = _rand(2, B, S, KV, D)
    v = _rand(3, B, S, KV, D)
    lengths = jnp.asarray([5, 16])
    got = decode_attention(q, k, v, lengths)
    # zeroing the cache beyond the valid length must not change results
    mask = (jnp.arange(S) < 5)[None, :, None, None]
    got2 = decode_attention(q[:1], k[:1] * mask, v[:1] * mask, lengths[:1])
    assert jnp.max(jnp.abs(got[0] - got2[0])) < 1e-5
