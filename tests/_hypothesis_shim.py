"""Deterministic stand-in for ``hypothesis`` (installed by conftest.py
only when the real package is missing).

The container the tier-1 suite runs in does not always ship hypothesis;
CI installs the real thing.  This shim implements the small API surface
the test suite uses — ``given`` with keyword strategies, ``settings``,
``assume``, and the ``integers`` / ``floats`` / ``booleans`` /
``sampled_from`` / ``text`` / ``lists`` strategies — drawing examples
from a fixed-seed PRNG so runs are reproducible.  It does no shrinking
and no adaptive search; it is a property *sampler*, not a property
*explorer*.
"""
from __future__ import annotations

import functools
import inspect
import random
import string
import sys

DEFAULT_MAX_EXAMPLES = 20
__version__ = "0.0.0-shim"


class _Rejected(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise _Rejected()
    return True


class HealthCheck:  # accessed as attributes only; values are opaque
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    function_scoped_fixture = "function_scoped_fixture"
    all = classmethod(lambda cls: [])


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda r: fn(self._draw(r)))

    def filter(self, pred):
        def d(r):
            for _ in range(1000):
                x = self._draw(r)
                if pred(x):
                    return x
            raise _Rejected()
        return _Strategy(d)


def integers(min_value=0, max_value=1 << 16):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda r: r.random() < 0.5)


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def text(alphabet=None, min_size=0, max_size=20):
    pool = list(alphabet) if alphabet else list(
        string.ascii_letters + string.digits + string.punctuation + " \n\t"
        + "éüλЖ中🙂")
    hi = max_size if max_size is not None else min_size + 20

    def d(r):
        return "".join(r.choice(pool)
                       for _ in range(r.randint(min_size, hi)))
    return _Strategy(d)


def lists(elements, min_size=0, max_size=10):
    def d(r):
        return [elements.draw(r)
                for _ in range(r.randint(min_size, max_size))]
    return _Strategy(d)


def given(*args, **strategies):
    if args:
        raise TypeError("hypothesis shim supports keyword strategies only")

    def deco(fn):
        sig = inspect.signature(fn)
        keep = [p for name, p in sig.parameters.items()
                if name not in strategies]

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            n = getattr(wrapper, "_shim_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(0x5EED)
            ran = 0
            for _ in range(n * 4):
                if ran >= n:
                    break
                try:
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*a, **kw, **drawn)
                    ran += 1
                except _Rejected:
                    continue

        wrapper.__signature__ = sig.replace(parameters=keep)
        wrapper._shim_given = True
        return wrapper
    return deco


def settings(*_args, **kw):
    max_examples = kw.get("max_examples")

    def deco(fn):
        if max_examples is not None:
            fn._shim_max_examples = max_examples
        return fn
    return deco


# ``from hypothesis import strategies as st`` resolves this attribute.
strategies = sys.modules[__name__]
