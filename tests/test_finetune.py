"""LoRA, recipes, guard, quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.data.pipeline import DataConfig, SFTDataset, SyntheticLM
from repro.finetune.evals import CapabilityGuard, evaluate
from repro.finetune.lora import LoraConfig, lora_init, lora_merge, lora_param_count
from repro.finetune.quantize import dequantize_tree, quantize_tree
from repro.finetune.recipes import CATALOG, RecipeError, resolve
from repro.finetune.sft import make_lora_sft_step
from repro.models import model as M
from repro.training.optimizer import OptConfig, opt_init


def test_lora_identity_at_init(tiny_cfg, tiny_params):
    lcfg = LoraConfig(rank=4)
    ad = lora_init(tiny_params, lcfg, jax.random.PRNGKey(1))
    merged = lora_merge(tiny_params, ad, lcfg)
    for a, b in zip(jax.tree.leaves(tiny_params), jax.tree.leaves(merged)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_lora_targets_attention_only(tiny_cfg, tiny_params):
    lcfg = LoraConfig(rank=4)
    ad = lora_init(tiny_params, lcfg, jax.random.PRNGKey(1))
    names = {k.split("'")[-2] for k in ad}
    assert names == {"wq", "wk", "wv", "wo"}
    # far fewer params than the base
    base_n = sum(x.size for x in jax.tree.leaves(tiny_params))
    assert lora_param_count(ad) < base_n / 10


def test_lora_sft_learns(tiny_cfg, tiny_params):
    lcfg = LoraConfig(rank=8)
    ad = lora_init(tiny_params, lcfg, jax.random.PRNGKey(1))
    dc = DataConfig(vocab_size=tiny_cfg.vocab_size, seq_len=32,
                    global_batch=8)
    sft = SFTDataset(dc, prompt_len=8)
    opt = OptConfig(lr=3e-3, weight_decay=0.0)
    step = jax.jit(make_lora_sft_step(tiny_cfg, opt, tiny_params, lcfg))
    st = opt_init(opt, ad)
    first = last = None
    for i in range(20):
        b = {k: jnp.asarray(v) for k, v in sft.batch(i).items()}
        ad, st, m = step(ad, st, b)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.5


def test_recipe_bounds_enforced(tiny_cfg):
    with pytest.raises(RecipeError):
        resolve("sft_lora_safe", tiny_cfg, {"lr": 1.0})      # out of bounds
    with pytest.raises(RecipeError):
        resolve("sft_lora_safe", tiny_cfg, {"nuke": True})   # not tunable
    with pytest.raises(RecipeError):
        resolve("nonexistent", tiny_cfg)
    r, lora, opt, extra = resolve("sft_lora_safe", tiny_cfg, {"rank": 16})
    assert lora.rank == 16 and opt.lr == pytest.approx(1e-4)


def test_recipe_family_awareness():
    mamba = scaled_down(get_config("mamba2-1.3b"))
    r, lora, _, _ = resolve("sft_lora_safe", mamba)
    assert set(lora.targets) == {"wx", "wz", "wo"}
    with pytest.raises(RecipeError):
        resolve("sft_lora_wide", mamba)       # attention+MLP recipe: N/A


def test_capability_guard_detects_regression(tiny_cfg, tiny_params):
    dc = DataConfig(vocab_size=tiny_cfg.vocab_size, seq_len=16,
                    global_batch=4)
    guard = CapabilityGuard(tiny_cfg, SyntheticLM(dc), tolerance=0.05,
                            steps=2)
    guard.snapshot(tiny_params)
    ok = guard.check(tiny_params)
    assert ok["passed"] and abs(ok["ppl_regression"]) < 1e-6
    # break the model: blow up the unembed (raises perplexity sharply)
    broken = jax.tree.map(lambda x: x, tiny_params)
    noise = jax.random.normal(jax.random.PRNGKey(9),
                              broken["embed"]["unembed"].shape) * 10.0
    broken["embed"]["unembed"] = (broken["embed"]["unembed"]
                                  + noise.astype(
                                      broken["embed"]["unembed"].dtype))
    bad = guard.check(broken)
    assert not bad["passed"]
    assert bad["ppl_regression"] > 0.5


def test_quantize_roundtrip(tiny_params):
    q = quantize_tree(tiny_params)
    deq = dequantize_tree(q, jnp.float32)
    for a, b in zip(jax.tree.leaves(tiny_params), jax.tree.leaves(deq)):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        if a.ndim >= 2 and a.size >= 1024:
            scale = np.abs(a).max(axis=-2, keepdims=True) / 127.0
            assert np.max(np.abs(a - b) - scale) < 1e-5  # within 1 LSB
        else:
            np.testing.assert_allclose(a, b, atol=1e-6)


def test_quantized_bytes_halves_bf16(tiny_params):
    """The serving artifact is ~half the bf16 footprint: int8 payload +
    per-output-channel f32 scales on quantized leaves, raw passthrough
    for the small ones."""
    from repro.finetune.quantize import quantized_bytes
    q = quantize_tree(tiny_params)
    bf16 = sum(x.size * 2 for x in jax.tree.leaves(tiny_params))
    ratio = quantized_bytes(q) / bf16
    assert 0.4 < ratio < 0.75
    # the quantized leaves themselves sit at ~1/2 exactly
    qb = rb = 0
    for leaf in jax.tree.leaves(
            q, is_leaf=lambda x: isinstance(x, dict)
            and ("raw" in x or "q" in x)):
        if "q" in leaf:
            qb += leaf["q"].nbytes + leaf["scale"].nbytes
            rb += leaf["q"].size * 2
    assert rb and 0.45 < qb / rb < 0.6
