"""Property tests for the paged-KV bookkeeping invariants.

Driven by hypothesis (the real package in CI; tests/_hypothesis_shim.py
in containers without it — keyword strategies only, deterministic seed).
Each test interprets a generated op script against the allocator and
checks the documented invariants after *every* op, not just at the end:

- refcounts are never negative (structurally: a tracked block's count is
  always >= 1, and the multiset of outstanding holds equals ``refs``);
- free + used + null == capacity, always;
- a block is never simultaneously free and allocated, and the null
  block is never handed out;
- ``adopt_prefix``/``trim``/``release`` round-trip: adopted (shared)
  blocks survive trim and release, privately grown tails are returned.
"""
from collections import Counter

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, scaled_down
from repro.serving.kvcache import (NULL_BLOCK, BlockLedger, BlockPool,
                                   PagedCacheSlots)

CFG = scaled_down(get_config("qwen1.5-4b"), num_layers=2, d_model=32,
                  d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                  head_dim=8)


def _pool_invariants(bp: BlockPool, held: Counter):
    # free + used + null == capacity
    assert bp.num_free + bp.num_used + 1 == bp.num_blocks
    # refcounts never negative / never zero-but-tracked
    assert all(r >= 1 for r in bp.refs.values())
    # the allocator's view matches the holders' view exactly
    assert dict(held) == bp.refs
    # no block is both free and allocated; null is neither
    free = set(bp.free)
    assert not (free & set(bp.refs))
    assert NULL_BLOCK not in free and NULL_BLOCK not in bp.refs
    assert bp.peak_used >= bp.num_used


@settings(max_examples=30)
@given(num_blocks=st.integers(min_value=2, max_value=33),
       ops=st.lists(st.integers(min_value=0, max_value=10_000),
                    min_size=1, max_size=80))
def test_blockpool_random_walk(num_blocks, ops):
    bp = BlockPool(num_blocks)
    held = Counter()          # multiset of (block -> outstanding refs)
    order = []                # flat list for pseudo-random pick
    for op in ops:
        kind = op % 3
        if kind == 0:                                   # alloc n blocks
            n = (op // 3) % 4 + 1
            ids = bp.alloc(n)
            if ids is None:
                # all-or-nothing: a failed alloc changed nothing
                assert n > bp.num_free
            else:
                assert len(ids) == len(set(ids)) == n
                assert NULL_BLOCK not in ids
                held.update(ids)
                order.extend(ids)
        elif kind == 1 and order:                       # incref a holder
            b = order[op % len(order)]
            bp.incref([b])
            held[b] += 1
            order.append(b)
        elif kind == 2 and order:                       # decref a holder
            b = order.pop(op % len(order))
            bp.decref([b])
            held[b] -= 1
            if not held[b]:
                del held[b]
        _pool_invariants(bp, held)
    # drain every outstanding ref: the pool must come back whole
    bp.decref(list(order))
    assert bp.num_used == 0
    assert bp.num_free == bp.num_blocks - 1


@settings(max_examples=20)
@given(num_blocks=st.integers(min_value=2, max_value=9),
       extra=st.integers(min_value=0, max_value=5))
def test_blockpool_alloc_all_or_nothing(num_blocks, extra):
    bp = BlockPool(num_blocks)
    assert bp.alloc(bp.num_free + 1 + extra) is None
    assert bp.num_free == num_blocks - 1        # failed alloc is a no-op
    ids = bp.alloc(bp.num_free)                 # exact drain succeeds
    assert ids is not None and bp.num_free == 0
    bp.decref(ids)
    assert bp.num_free == num_blocks - 1


def test_blockpool_unallocated_ids_raise():
    bp = BlockPool(4)
    with pytest.raises(ValueError):
        bp.incref([2])
    with pytest.raises(ValueError):
        bp.decref([2])
    with pytest.raises(ValueError):
        bp.incref([NULL_BLOCK])
    with pytest.raises(ValueError):
        BlockPool(1)                            # nothing allocatable


@settings(max_examples=30)
@given(capacity=st.integers(min_value=1, max_value=40),
       block=st.sampled_from([1, 4, 16]),
       ops=st.lists(st.integers(min_value=0, max_value=10_000),
                    min_size=1, max_size=60))
def test_blockledger_random_walk(capacity, block, ops):
    led = BlockLedger(capacity * block, block_size=block)
    shadow = {}                                 # rid -> blocks held
    for op in ops:
        rid = f"r{op % 5}"
        kind = op % 3
        tokens = (op // 7) % (capacity * block + 1)
        if kind == 0:
            if led.can_admit(rid, tokens):
                led.admit(rid, tokens)
                shadow[rid] = led.blocks_for(tokens)
            else:
                with pytest.raises(RuntimeError):
                    led.admit(rid, tokens)
        elif kind == 1:
            need = led.blocks_for(tokens)
            held = shadow.get(rid, 0)
            if need - held <= led.free_blocks:
                led.grow(rid, tokens)
                if need > held:        # grow-to-less is a recorded no-op
                    shadow[rid] = need
            else:
                with pytest.raises(RuntimeError):
                    led.grow(rid, tokens)
        else:
            led.release(rid)
            shadow.pop(rid, None)
        # never over-committed, and accounting matches the shadow model
        assert led.free_blocks >= 0
        assert led.free_blocks == led.total_blocks - sum(shadow.values())
        assert led.used == shadow
        assert led.peak_blocks <= led.total_blocks


def _slots(pool_blocks=12, block_size=4):
    return PagedCacheSlots(CFG, max_batch=2, capacity=32,
                           block_size=block_size,
                           pool_tokens=pool_blocks * block_size)


@settings(max_examples=15)
@given(grow_to=st.integers(min_value=1, max_value=32),
       trim_to=st.integers(min_value=1, max_value=32))
def test_paged_slots_grow_trim_roundtrip(grow_to, trim_to):
    s = _slots()
    slot = s.allocate("req")
    assert s.ensure_capacity(slot, grow_to)
    bp = s.bp
    assert len(s.seq_blocks[slot]) == s.blocks_for(grow_to)
    s.trim(slot, min(trim_to, grow_to))
    keep = s.blocks_for(max(min(trim_to, grow_to), 1))
    kept = s.seq_blocks[slot]
    # trim keeps exactly the blocks covering the surviving length...
    assert len(kept) == min(keep, s.blocks_for(grow_to))
    # ...nulls the vacated table tail, and keeps table/seq_blocks aligned
    assert list(s.tables[slot, :len(kept)]) == kept
    assert all(b == NULL_BLOCK for b in s.tables[slot, len(kept):])
    assert bp.num_free + bp.num_used + 1 == bp.num_blocks
    s.release(slot)
    assert bp.num_used == 0                     # release returns it all
    assert s.lengths[slot] == 1                 # inert again


@settings(max_examples=15)
@given(nadopt=st.integers(min_value=1, max_value=4),
       extra_tokens=st.integers(min_value=0, max_value=16))
def test_paged_slots_adopt_is_refcounted_and_trim_safe(nadopt, extra_tokens):
    s = _slots()
    bp = s.bp
    # simulate the radix tree holding nadopt whole prompt blocks
    tree_ids = bp.alloc(nadopt)
    adopted_len = nadopt * s.block_size
    slot = s.allocate("req")
    s.adopt_prefix(slot, tree_ids, adopted_len)
    assert all(bp.refs[b] == 2 for b in tree_ids)     # tree + slot
    assert s.lengths[slot] == adopted_len
    # grow privately past the adopted prefix, then trim back to it:
    # shared blocks must never be freed by a speculative rollback
    assert s.ensure_capacity(slot, adopted_len + extra_tokens)
    s.trim(slot, adopted_len)
    assert s.seq_blocks[slot] == list(tree_ids)
    assert all(bp.refs[b] == 2 for b in tree_ids)
    # release drops the slot's ref; the tree's ref keeps the blocks live
    s.release(slot)
    assert all(bp.refs[b] == 1 for b in tree_ids)
    assert bp.num_used == nadopt
    bp.decref(tree_ids)                                # tree eviction
    assert bp.num_used == 0
    assert bp.num_free + bp.num_used + 1 == bp.num_blocks


@settings(max_examples=10)
@given(lens=st.lists(st.integers(min_value=1, max_value=24),
                     min_size=1, max_size=2))
def test_paged_slots_exhaustion_is_explicit(lens):
    s = _slots(pool_blocks=4, block_size=4)
    slots = []
    for i, ln in enumerate(lens):
        sl = s.allocate(f"r{i}")
        ok = s.ensure_capacity(sl, ln)
        if not ok:
            # a refused grow changed nothing: invariant still holds and
            # the slot can still be released cleanly
            assert s.bp.num_free + s.bp.num_used + 1 == s.bp.num_blocks
        slots.append(sl)
    for sl in slots:
        s.release(sl)
    assert s.bp.num_used == 0


# ---------------------------------------------------------------- int8
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


def _slots8(pool_blocks=12, block_size=4):
    return PagedCacheSlots(CFG, max_batch=2, capacity=32,
                           block_size=block_size,
                           pool_tokens=pool_blocks * block_size,
                           kv_dtype="int8")


def _pool_bytes(s):
    return sum(x.nbytes for x in jax.tree.leaves(s.pool))


@pytest.mark.parametrize("pool_blocks,block_size", [(12, 4), (8, 8)])
def test_int8_pool_accounting(pool_blocks, block_size):
    """Same pool_tokens budget: int8 carries 2x the allocatable blocks
    at ~half the per-block bytes (int8 payload + f32 scale sliver)."""
    b16 = _slots(pool_blocks, block_size)
    i8 = _slots8(pool_blocks, block_size)
    assert i8.bp.num_blocks - 1 == 2 * (b16.bp.num_blocks - 1)
    ratio = ((_pool_bytes(i8) / i8.bp.num_blocks)
             / (_pool_bytes(b16) / b16.bp.num_blocks))
    assert 0.45 < ratio < 0.6
    # payload leaves are int8, every one paired with a f32 scale leaf
    seen_scale = False
    for part in i8.pool.values():
        for k, leaf in part.items():
            if k.endswith("_scale"):
                assert leaf.dtype == jnp.float32
                seen_scale = True
            else:
                assert leaf.dtype == jnp.int8
                assert f"{k}_scale" in part
    assert seen_scale


@settings(max_examples=10)
@given(grow_to=st.integers(min_value=1, max_value=32),
       trim_to=st.integers(min_value=1, max_value=32))
def test_int8_slots_grow_trim_roundtrip(grow_to, trim_to):
    """Allocator invariants are dtype-blind: the bf16 grow/trim/release
    round-trip holds verbatim on an int8 pool."""
    s = _slots8()
    slot = s.allocate("req")
    assert s.ensure_capacity(slot, grow_to)
    bp = s.bp
    assert len(s.seq_blocks[slot]) == s.blocks_for(grow_to)
    s.trim(slot, min(trim_to, grow_to))
    kept = s.seq_blocks[slot]
    assert list(s.tables[slot, :len(kept)]) == kept
    assert all(b == NULL_BLOCK for b in s.tables[slot, len(kept):])
    assert bp.num_free + bp.num_used + 1 == bp.num_blocks
    s.release(slot)
    assert bp.num_used == 0
    assert s.lengths[slot] == 1


def test_int8_prefill_gather_roundtrip():
    """insert_prefill quantizes; export_kv gathers the int8 blocks plus
    scales; dequantizing recovers the source within the symmetric
    per-block error bound (<= block_scale / 2 <= global_max / 254)."""
    L, bs = 12, 4
    params = M.init(CFG, jax.random.PRNGKey(0), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, L), 1,
                              CFG.vocab_size).astype(jnp.int32)
    _, cache, _ = M.prefill(CFG, params, {
        "tokens": toks, "prompt_lengths": jnp.full((1,), L, jnp.int32)})
    s = _slots8(block_size=bs)
    slot = s.allocate("req")
    assert s.ensure_capacity(slot, L)
    s.insert_prefill(slot, cache, L)
    hand = s.export_kv("req")
    assert hand.length == L
    for part in hand.blocks.values():
        for k, leaf in part.items():
            assert leaf.dtype == (jnp.float32 if k.endswith("_scale")
                                  else jnp.int8)
    st_blocks = hand.blocks["stack"]
    for name in ("k", "v"):
        q = np.asarray(st_blocks[name], np.float32)      # (nb,l,bs,KV,D)
        sc = np.asarray(st_blocks[f"{name}_scale"])      # (nb,l,KV)
        deq = (q * sc[:, :, None, :, None]).transpose(1, 0, 2, 3, 4)
        deq = deq.reshape(q.shape[1], -1, q.shape[3], q.shape[4])[:, :L]
        src = np.asarray(cache["stack"][name][:, 0, :L], np.float32)
        err = float(np.max(np.abs(deq - src)))
        assert err <= float(np.max(np.abs(src))) / 250.0
