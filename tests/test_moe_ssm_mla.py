"""MoE routing/combine, SSD equivalences, MLA absorbed decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, scaled_down
from repro.models import moe as moe_mod
from repro.models import model as M
from repro.models.param import init_params
from repro.models.ssm import ssd_chunked, ssd_decode_step


def _moe_cfg(**kw):
    base = scaled_down(get_config("granite-moe-3b-a800m"), d_model=32,
                       moe_d_ff=64, num_experts=4, moe_top_k=2,
                       vocab_size=64)
    import dataclasses
    return dataclasses.replace(base, **kw)


def test_moe_dense_combines_topk_only():
    cfg = _moe_cfg()
    specs = moe_mod.moe_specs(cfg)
    p = init_params(specs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe_mod.moe_dense(cfg, p, x)
    # manual: router top-k, weighted sum of expert MLPs
    top_p, top_i, _ = moe_mod._router(cfg, p["router"], x)
    ye = []
    for e in range(cfg.num_experts):
        pe = {k: v[e] for k, v in p.items() if k.startswith("w_")}
        g = jnp.einsum("bsd,df->bsf", x, pe["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, pe["w_up"])
        ye.append(jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                             pe["w_down"]))
    ye = jnp.stack(ye)
    want = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(cfg.moe_top_k):
        sel = jnp.take_along_axis(
            jnp.moveaxis(ye, 0, -1), top_i[..., k][..., None, None],
            axis=-1)[..., 0]
        want += top_p[..., k][..., None] * sel
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-2, atol=2e-3)
    assert float(aux) >= 1.0 - 1e-3  # switch aux loss lower bound ~1


@settings(max_examples=6, deadline=None)
@given(E=st.sampled_from([4, 8]), k=st.integers(1, 3),
       T=st.sampled_from([8, 33]))
def test_moe_router_properties(E, k, T):
    cfg = _moe_cfg(num_experts=E, moe_top_k=k)
    w = jax.random.normal(jax.random.PRNGKey(0), (cfg.d_model, E))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, T, cfg.d_model))
    top_p, top_i, aux = moe_mod._router(cfg, w, x)
    assert top_p.shape == (1, T, k)
    s = np.asarray(jnp.sum(top_p, -1))
    np.testing.assert_allclose(s, np.ones_like(s), rtol=1e-5)
    assert int(jnp.max(top_i)) < E
    # each token's selected experts are distinct
    for row in np.asarray(top_i).reshape(-1, k):
        assert len(set(row.tolist())) == k


def test_dispatch_local_capacity_drops():
    cfg = _moe_cfg(num_experts=2, moe_top_k=1)
    T, d, C = 8, cfg.d_model, 2
    x = jax.random.normal(jax.random.PRNGKey(0), (T, d))
    # route everything to expert 0 -> only C survive
    top_i = jnp.zeros((T, 1), jnp.int32)
    top_p = jnp.ones((T, 1), jnp.float32)
    xe, wt, back = moe_mod._dispatch_local(cfg, x, top_p, top_i, 2, C)
    assert xe.shape == (2, C, d)
    kept = int(jnp.sum(wt > 0))
    assert kept == C                           # capacity enforced
    dropped = int(jnp.sum(back == 2 * C))
    assert dropped == T - C


# ------------------------------------------------------------ ssd
def test_ssd_decode_chain_matches_chunked():
    B, L, H, P, N = 2, 16, 2, 8, 4
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
    dt = jnp.abs(jax.random.normal(ks[1], (B, L, H))) * 0.3
    A = -jnp.abs(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, L, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, L, N)) * 0.5
    y_full, h_full = ssd_chunked(x, dt, A, Bm, Cm, chunk=4)
    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(L):
        y_t, h = ssd_decode_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], h)
        ys.append(y_t)
    y_seq = jnp.stack(ys, 1)
    assert float(jnp.max(jnp.abs(y_seq - y_full))) < 1e-4
    assert float(jnp.max(jnp.abs(h - h_full))) < 1e-4


# ------------------------------------------------------------ mla
def test_mla_cache_is_latent_sized():
    cfg = scaled_down(get_config("deepseek-v2-lite-16b"))
    cache = M.make_cache(cfg, B=2, capacity=16)
    stacked = cache["stack"]
    assert set(stacked) == {"ckv", "kpe"}
    assert stacked["ckv"].shape[-1] == cfg.kv_lora_rank
    assert stacked["kpe"].shape[-1] == cfg.qk_rope_head_dim
    # vs what a GQA cache of the same geometry would cost
    latent = cfg.kv_lora_rank + cfg.qk_rope_head_dim
    mha = 2 * cfg.num_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    assert latent * 3 < mha
