"""Paged flash-decode kernel: interpret-mode parity against the dense
decode oracle across variable lengths, permuted/non-contiguous block
tables, GQA group sizes, and block-size edge cases (lengths that are not
a multiple of the block size)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention.ref import decode_ref
from repro.kernels.paged_attention.kernel import paged_decode_attention
from repro.kernels.paged_attention.ops import paged_decode
from repro.kernels.paged_attention.ref import paged_decode_ref


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32
                             ).astype(dtype)


def _paged_layout(k, v, bs, seed=0, extra_blocks=0, shuffle=True):
    """Scatter dense per-sequence caches (B,S,KV,D) into a physical pool
    with a (optionally permuted) block table.  Block 0 stays null."""
    B, S, KV, D = k.shape
    assert S % bs == 0
    W = S // bs
    nb = 1 + B * W + extra_blocks
    rng = np.random.default_rng(seed)
    ids = np.arange(1, 1 + B * W)
    if shuffle:
        ids = rng.permutation(np.arange(1, nb))[:B * W]
    kp = np.zeros((nb, bs, KV, D), np.float32)
    vp = np.zeros((nb, bs, KV, D), np.float32)
    bt = np.zeros((B, W), np.int32)
    it = iter(ids)
    for b in range(B):
        for j in range(W):
            pid = int(next(it))
            kp[pid] = np.asarray(k[b, j * bs:(j + 1) * bs])
            vp[pid] = np.asarray(v[b, j * bs:(j + 1) * bs])
            bt[b, j] = pid
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(bt)


@pytest.mark.parametrize("B,KV,G,W,bs,D", [
    (2, 2, 2, 4, 16, 64),
    (3, 1, 8, 3, 32, 32),     # MQA-style wide groups
    (1, 8, 2, 8, 16, 128),
    (2, 2, 1, 2, 64, 16),     # MHA (G=1)
])
def test_paged_matches_dense_ref(B, KV, G, W, bs, D):
    H = KV * G
    S = W * bs
    q = _rand(1, (B, H, D))
    k = _rand(2, (B, S, KV, D))
    v = _rand(3, (B, S, KV, D))
    # variable lengths incl. non-multiples of the block size and a
    # single-token sequence
    lens = [S, max(1, S - bs // 2 - 1), 1][:B] + [S // 2] * max(0, B - 3)
    lengths = jnp.asarray(lens[:B], jnp.int32)
    kp, vp, bt = _paged_layout(k, v, bs, seed=B, extra_blocks=5)
    got = paged_decode_attention(q, kp, vp, bt, lengths, interpret=True)
    want = decode_ref(q, k, v, lengths)
    assert float(jnp.max(jnp.abs(got - want))) < 2e-5
    # the jnp oracle agrees with both
    ref = paged_decode_ref(q, kp, vp, bt, lengths)
    assert float(jnp.max(jnp.abs(ref - want))) < 2e-5


def test_paged_table_permutation_invariant():
    """The same logical sequences through two different physical layouts
    (contiguous vs permuted pool) produce identical outputs."""
    B, KV, G, W, bs, D = 2, 2, 3, 4, 16, 32
    H = KV * G
    S = W * bs
    q = _rand(11, (B, H, D))
    k = _rand(12, (B, S, KV, D))
    v = _rand(13, (B, S, KV, D))
    lengths = jnp.asarray([S - 3, S // 2 + 1], jnp.int32)
    out = []
    for shuffle in (False, True):
        kp, vp, bt = _paged_layout(k, v, bs, seed=7, extra_blocks=9,
                                   shuffle=shuffle)
        out.append(paged_decode_attention(q, kp, vp, bt, lengths,
                                          interpret=True))
    assert float(jnp.max(jnp.abs(out[0] - out[1]))) == 0.0


def test_paged_null_tail_blocks_ignored():
    """Table entries past ceil(len/bs) may point at the null block (or
    anything) without affecting the output."""
    B, KV, G, W, bs, D = 1, 2, 2, 4, 16, 32
    H = KV * G
    S = W * bs
    q = _rand(21, (B, H, D))
    k = _rand(22, (B, S, KV, D))
    v = _rand(23, (B, S, KV, D))
    length = bs + 3                       # only the first 2 blocks matter
    lengths = jnp.asarray([length], jnp.int32)
    kp, vp, bt = _paged_layout(k, v, bs, seed=3)
    want = paged_decode_attention(q, kp, vp, bt, lengths, interpret=True)
    bt2 = np.asarray(bt).copy()
    bt2[0, 2:] = 0                        # null out the unused tail
    got = paged_decode_attention(q, kp, vp, jnp.asarray(bt2), lengths,
                                 interpret=True)
    assert float(jnp.max(jnp.abs(got - want))) == 0.0
    assert float(jnp.max(jnp.abs(
        got - decode_ref(q, k, v, lengths)))) < 2e-5


def test_paged_ops_wrapper_model_layout():
    B, KV, G, W, bs, D = 2, 1, 4, 2, 16, 32
    H = KV * G
    S = W * bs
    q = _rand(31, (B, 1, H, D))
    k = _rand(32, (B, S, KV, D))
    v = _rand(33, (B, S, KV, D))
    lengths = jnp.asarray([S, S - 5], jnp.int32)
    kp, vp, bt = _paged_layout(k, v, bs, seed=5)
    got = paged_decode(q, kp, vp, bt, lengths)
    want = decode_ref(q[:, 0], k, v, lengths)[:, None]
    assert got.shape == (B, 1, H, D)
    assert float(jnp.max(jnp.abs(got - want))) < 2e-5


@settings(max_examples=8, deadline=None)
@given(B=st.integers(1, 3), KV=st.sampled_from([1, 2]),
       G=st.sampled_from([1, 4]), W=st.integers(1, 4),
       bs=st.sampled_from([8, 16]), length_frac=st.floats(0.05, 1.0))
def test_paged_property(B, KV, G, W, bs, length_frac):
    H, D = KV * G, 16
    S = W * bs
    q = _rand(41, (B, H, D))
    k = _rand(42, (B, S, KV, D))
    v = _rand(43, (B, S, KV, D))
    lengths = jnp.full((B,), max(1, int(S * length_frac)), jnp.int32)
    kp, vp, bt = _paged_layout(k, v, bs, seed=W, extra_blocks=3)
    got = paged_decode_attention(q, kp, vp, bt, lengths, interpret=True)
    want = decode_ref(q, k, v, lengths)
    assert float(jnp.max(jnp.abs(got - want))) < 2e-5


# ---------------------------------------------------------------- int8
from repro.kernels.paged_attention.kernel import (  # noqa: E402
    paged_decode_attention_int8, paged_verify_attention_int8)
from repro.kernels.paged_attention.ops import (  # noqa: E402
    paged_decode_int8, paged_verify_int8)
from repro.kernels.paged_attention.ref import (  # noqa: E402
    paged_decode_int8_ref, paged_verify_int8_ref, paged_verify_ref)


def _quantize_pool(kp, vp):
    """Symmetric per-block-per-head int8 quantization of a f32 pool."""
    kp, vp = np.asarray(kp), np.asarray(vp)
    ks = (np.max(np.abs(kp), axis=(1, 3)) / 127.0).astype(np.float32)
    vs = (np.max(np.abs(vp), axis=(1, 3)) / 127.0).astype(np.float32)
    kq = np.clip(np.round(kp / np.maximum(ks, 1e-12)[:, None, :, None]),
                 -127, 127).astype(np.int8)
    vq = np.clip(np.round(vp / np.maximum(vs, 1e-12)[:, None, :, None]),
                 -127, 127).astype(np.int8)
    return (jnp.asarray(kq), jnp.asarray(vq),
            jnp.asarray(ks), jnp.asarray(vs))


@pytest.mark.parametrize("B,KV,G,W,bs,D", [
    (2, 2, 2, 4, 16, 64),
    (3, 1, 8, 3, 32, 32),     # MQA-style wide groups
    (2, 2, 1, 2, 64, 16),     # MHA (G=1)
])
def test_paged_int8_matches_ref(B, KV, G, W, bs, D):
    """Fused-dequant decode kernel vs the dequantize-then-attend oracle
    on permuted tables, GQA groups, and ragged lengths."""
    H = KV * G
    S = W * bs
    q = _rand(51, (B, H, D))
    k = _rand(52, (B, S, KV, D))
    v = _rand(53, (B, S, KV, D))
    lens = [S, max(1, S - bs // 2 - 1), 1][:B] + [S // 2] * max(0, B - 3)
    lengths = jnp.asarray(lens[:B], jnp.int32)
    kp, vp, bt = _paged_layout(k, v, bs, seed=B + 7, extra_blocks=5)
    kq, vq, ks, vs = _quantize_pool(kp, vp)
    got = paged_decode_attention_int8(q, kq, vq, ks, vs, bt, lengths,
                                      interpret=True)
    want = paged_decode_int8_ref(q, kq, vq, ks, vs, bt, lengths)
    assert float(jnp.max(jnp.abs(got - want))) < 5e-5
    # the quantized output tracks the fp path within int8 error
    fp = decode_ref(q, k, v, lengths)
    assert float(jnp.max(jnp.abs(got - fp))) < 0.1


def test_paged_int8_table_permutation_invariant():
    B, KV, G, W, bs, D = 2, 2, 3, 4, 16, 32
    H = KV * G
    S = W * bs
    q = _rand(61, (B, H, D))
    k = _rand(62, (B, S, KV, D))
    v = _rand(63, (B, S, KV, D))
    lengths = jnp.asarray([S - 3, S // 2 + 1], jnp.int32)
    out = []
    for shuffle in (False, True):
        kp, vp, bt = _paged_layout(k, v, bs, seed=9, extra_blocks=9,
                                   shuffle=shuffle)
        kq, vq, ks, vs = _quantize_pool(kp, vp)
        out.append(paged_decode_attention_int8(q, kq, vq, ks, vs, bt,
                                               lengths, interpret=True))
    assert float(jnp.max(jnp.abs(out[0] - out[1]))) == 0.0


def test_paged_verify_int8_block_straddling_tail():
    """Multi-token verify with the T tail queries straddling a block
    boundary (length % bs < T), against the int8 verify oracle and the
    fp verify oracle."""
    B, KV, G, W, bs, D, T = 2, 2, 2, 3, 8, 32, 3
    H = KV * G
    S = W * bs
    q = _rand(71, (B, T, H, D))
    k = _rand(72, (B, S, KV, D))
    v = _rand(73, (B, S, KV, D))
    # row 0: tail straddles blocks 0/1 (positions 7,8,9); row 1: tail
    # entirely inside the last block
    lengths = jnp.asarray([bs + 2, S - 1], jnp.int32)
    kp, vp, bt = _paged_layout(k, v, bs, seed=4, extra_blocks=4)
    kq, vq, ks, vs = _quantize_pool(kp, vp)
    got = paged_verify_attention_int8(q, kq, vq, ks, vs, bt, lengths,
                                      interpret=True)
    want = paged_verify_int8_ref(q, kq, vq, ks, vs, bt, lengths)
    assert got.shape == (B, T, H, D)
    assert float(jnp.max(jnp.abs(got - want))) < 5e-5
    fp = paged_verify_ref(q, kp, vp, bt, lengths)
    assert float(jnp.max(jnp.abs(got - fp))) < 0.1


def test_paged_int8_ops_wrappers_model_layout():
    B, KV, G, W, bs, D, T = 2, 1, 4, 2, 16, 32, 2
    H = KV * G
    S = W * bs
    k = _rand(82, (B, S, KV, D))
    v = _rand(83, (B, S, KV, D))
    lengths = jnp.asarray([S, S - 5], jnp.int32)
    kp, vp, bt = _paged_layout(k, v, bs, seed=6)
    kq, vq, ks, vs = _quantize_pool(kp, vp)
    q1 = _rand(81, (B, 1, H, D))
    got = paged_decode_int8(q1, kq, vq, ks, vs, bt, lengths)
    want = paged_decode_int8_ref(q1[:, 0], kq, vq, ks, vs, bt,
                                 lengths)[:, None]
    assert got.shape == (B, 1, H, D)
    assert float(jnp.max(jnp.abs(got - want))) < 5e-5
    qt = _rand(84, (B, T, H, D))
    gotv = paged_verify_int8(qt, kq, vq, ks, vs, bt, lengths)
    wantv = paged_verify_int8_ref(qt, kq, vq, ks, vs, bt, lengths)
    assert gotv.shape == (B, T, H, D)
    assert float(jnp.max(jnp.abs(gotv - wantv))) < 5e-5


@settings(max_examples=8, deadline=None)
@given(B=st.integers(1, 3), KV=st.sampled_from([1, 2]),
       G=st.sampled_from([1, 4]), W=st.integers(1, 4),
       bs=st.sampled_from([8, 16]), length_frac=st.floats(0.05, 1.0))
def test_paged_int8_property(B, KV, G, W, bs, length_frac):
    H, D = KV * G, 16
    S = W * bs
    q = _rand(91, (B, H, D))
    k = _rand(92, (B, S, KV, D))
    v = _rand(93, (B, S, KV, D))
    lengths = jnp.full((B,), max(1, int(S * length_frac)), jnp.int32)
    kp, vp, bt = _paged_layout(k, v, bs, seed=W + 1, extra_blocks=3)
    kq, vq, ks, vs = _quantize_pool(kp, vp)
    got = paged_decode_attention_int8(q, kq, vq, ks, vs, bt, lengths,
                                      interpret=True)
    want = paged_decode_int8_ref(q, kq, vq, ks, vs, bt, lengths)
    assert float(jnp.max(jnp.abs(got - want))) < 5e-5
