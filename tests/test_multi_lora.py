"""Multi-tenant LoRA serving: adapter-pool LRU/refcount semantics and
token-exactness of batched multi-LoRA decode vs ``lora_merge`` baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.finetune.lora import (LoraConfig, lora_export, lora_init,
                                 lora_merge, lora_randomize, lora_unflatten)
from repro.models import model as M
from repro.serving.adapters import (AdapterPool, adapter_namespace,
                                    supports_multi_lora)
from repro.serving.engine import InferenceEngine, Request

LCFG = LoraConfig(rank=4)


def _mk_adapter(params, seed):
    return lora_randomize(lora_init(params, LCFG, jax.random.PRNGKey(seed)),
                          jax.random.PRNGKey(seed + 1000))


def _engine_generate(cfg, params, prompts, n, cap=128, **kw):
    """Single-tenant baseline: the same engine machinery on (merged)
    weights.  The acceptance bar is token-identity between the mixed
    multi-LoRA batch and a ``lora_merge``d single-tenant *run* — both
    sides go through identical bucketing/scheduling, so the only delta
    is factored-vs-merged weights."""
    eng = InferenceEngine(cfg, params, max_batch=4, capacity=cap, **kw)
    reqs = [Request(prompt=list(p), max_new_tokens=n) for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    return [r.generated for r in reqs]


@pytest.fixture(scope="module")
def tenants(tiny_cfg, tiny_params):
    return {f"t{i}": _mk_adapter(tiny_params, i) for i in range(4)}


# ------------------------------------------------------------------ pool
def test_pool_lru_eviction_order(tiny_cfg, tiny_params, tenants):
    pool = AdapterPool(tiny_cfg, tiny_params, slots=2)
    for n, ad in tenants.items():
        pool.register(n, ad, LCFG)
    pool.acquire("t0"), pool.release("t0")
    pool.acquire("t1"), pool.release("t1")
    assert pool.resident == ["t0", "t1"]
    pool.acquire("t2")                      # evicts LRU = t0
    pool.release("t2")
    assert pool.resident == ["t1", "t2"]
    assert pool.evictions == 1
    pool.acquire("t1"), pool.release("t1")  # touch t1 -> t2 becomes LRU
    pool.acquire("t3")                      # evicts t2, not t1
    pool.release("t3")
    assert pool.resident == ["t1", "t3"]


def test_pool_refcount_pins_resident(tiny_cfg, tiny_params, tenants):
    pool = AdapterPool(tiny_cfg, tiny_params, slots=1)
    pool.register("t0", tenants["t0"], LCFG)
    pool.register("t1", tenants["t1"], LCFG)
    idx = pool.acquire("t0")
    assert idx == 1
    # the only slot is pinned: t1 cannot displace it
    assert pool.acquire("t1") is None
    assert pool.resident == ["t0"]
    # double-pin then single-release still pins
    assert pool.acquire("t0") == idx
    pool.release("t0")
    assert pool.acquire("t1") is None
    pool.release("t0")
    assert pool.acquire("t1") == 1          # unpinned -> evictable
    assert pool.resident == ["t1"]
    assert pool.evictions == 1
    # unbalanced release is a refcount bug and must surface immediately
    with pytest.raises(ValueError, match="unpinned"):
        pool.release("t0")


def test_pool_reregister_evicted(tiny_cfg, tiny_params, tenants):
    pool = AdapterPool(tiny_cfg, tiny_params, slots=1)
    pool.register("t0", tenants["t0"], LCFG)
    pool.register("t1", tenants["t1"], LCFG)
    pool.acquire("t0"), pool.release("t0")
    pool.acquire("t1"), pool.release("t1")  # evicts t0
    assert pool.resident == ["t1"]
    loads0 = pool.loads
    # re-register the evicted id with *different* weights; re-acquire
    # must reload the new host copy
    pool.register("t0", tenants["t2"], LCFG)
    assert pool.acquire("t0") == 1
    assert pool.loads == loads0 + 1
    tree = pool.lora_tree()
    got = np.asarray(tree["stack"]["mixer"]["wq"]["b"][:, 1, :4, :])
    want = np.asarray(tenants["t2"]
                      ["['stack']['mixer']['wq']"]["b"]) * LCFG.scale
    np.testing.assert_allclose(got, want, rtol=1e-6)
    pool.release("t0")


def test_pool_rejects_unsupported_targets(tiny_cfg, tiny_params):
    pool = AdapterPool(tiny_cfg, tiny_params, slots=1)
    bad = {"['stack']['mlp']['gate']": {
        "a": np.zeros((2, 64, 4), np.float32),
        "b": np.zeros((2, 4, 128), np.float32)}}
    with pytest.raises(ValueError, match="does not serve"):
        pool.register("bad", bad, LCFG)
    big_cfg = LoraConfig(rank=64)   # exceeds the pool's rank bucket (8)
    big = lora_init(tiny_params, big_cfg, jax.random.PRNGKey(9))
    with pytest.raises(ValueError, match="rank"):
        pool.register("toobig", big, big_cfg)


def test_pool_accepts_exported_form(tiny_cfg, tiny_params, tenants):
    pool = AdapterPool(tiny_cfg, tiny_params, slots=1)
    flat = lora_export(tenants["t0"])
    pool.register("t0", flat, LCFG)
    assert pool.acquire("t0") == 1
    pool.release("t0")
    # and the artifact round-trip reproduces the nested tree
    nested = lora_unflatten(flat)
    assert set(nested) == set(tenants["t0"])


def test_supports_multi_lora_gating():
    assert not supports_multi_lora(scaled_down(
        get_config("mamba2-1.3b"), num_layers=2, d_model=64, d_ff=128,
        vocab_size=128))
    assert adapter_namespace("proj", "") == "proj"
    assert adapter_namespace("proj", "t0") != adapter_namespace("proj", "t1")


# ------------------------------------------------------------------ engine
def _run_mix(cfg, params, tenants, *, paged, slots, gen=6):
    eng = InferenceEngine(cfg, params, max_batch=4, capacity=128,
                          paged=paged, adapter_slots=slots)
    for n, ad in tenants.items():
        eng.register_adapter(n, ad, LCFG)
    rng = np.random.default_rng(3)
    names = list(tenants) + ["", ""]       # >= 4 adapters + base rows
    prompts = [list(map(int, rng.integers(1, cfg.vocab_size - 1, 5 + i)))
               for i in range(len(names))]
    reqs = [Request(prompt=list(p), max_new_tokens=gen, adapter=nm)
            for p, nm in zip(prompts, names)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    return eng, names, prompts, reqs


def _check_vs_merged(cfg, base_params, tenants, names, prompts, reqs,
                     gen, paged=None, cap=128):
    merged = {"": base_params}
    merged.update({n: lora_merge(base_params, ad, LCFG)
                   for n, ad in tenants.items()})
    for variant in sorted(set(names)):
        idxs = [i for i, nm in enumerate(names) if nm == variant]
        refs = _engine_generate(cfg, merged[variant],
                                [prompts[i] for i in idxs], gen,
                                cap=cap, paged=paged)
        for i, ref in zip(idxs, refs):
            assert reqs[i].generated == ref, (variant, prompts[i])


def test_mixed_batch_matches_merged_paged(tiny_cfg, tiny_params, tenants):
    eng, names, prompts, reqs = _run_mix(tiny_cfg, tiny_params, tenants,
                                         paged=None, slots=4)
    assert eng.paged
    _check_vs_merged(tiny_cfg, tiny_params, tenants, names, prompts,
                     reqs, 6)


def test_mixed_batch_matches_merged_dense(tiny_cfg, tiny_params, tenants):
    _, names, prompts, reqs = _run_mix(tiny_cfg, tiny_params, tenants,
                                       paged=False, slots=4)
    _check_vs_merged(tiny_cfg, tiny_params, tenants, names, prompts,
                     reqs, 6, paged=False)


def test_slot_pressure_pins_and_completes(tiny_cfg, tiny_params, tenants):
    # 4 distinct adapters through 2 device slots: admission must wait for
    # pins to release, evict LRU residents, and still finish token-exact
    eng, names, prompts, reqs = _run_mix(tiny_cfg, tiny_params, tenants,
                                         paged=None, slots=2)
    assert all(r.done for r in reqs)
    st = eng.adapter_stats()
    assert st["evictions"] >= 1 and st["loads"] >= 4
    _check_vs_merged(tiny_cfg, tiny_params, tenants, names, prompts,
                     reqs, 6)


def test_unknown_adapter_rejected(tiny_cfg, tiny_params):
    eng = InferenceEngine(tiny_cfg, tiny_params, max_batch=2, capacity=64,
                          adapter_slots=1)
    req = Request(prompt=[1, 2, 3], max_new_tokens=4, adapter="nope")
    eng.submit(req)
    s = eng.run_until_idle()
    assert req.done and req.generated == []
    assert s["rejected"] == 1


def test_prefix_cache_isolated_per_adapter(tiny_cfg, tiny_params, tenants):
    # identical prompts under base / t0 / t1 share *no* cached KV: each
    # variant's output must match its own merged-weights reference even
    # after another variant prefilled the same tokens first
    eng = InferenceEngine(tiny_cfg, tiny_params, max_batch=2, capacity=128,
                          adapter_slots=2)
    for n in ("t0", "t1"):
        eng.register_adapter(n, tenants[n], LCFG)
    prompt = list(range(1, 40))            # long enough to index blocks
    outs = {}
    for nm in ("", "t0", "t1", "", "t0"):
        r = Request(prompt=list(prompt), max_new_tokens=5, adapter=nm)
        eng.submit(r)
        eng.run_until_idle()
        outs.setdefault(nm, []).append(r.generated)
    merged = {n: lora_merge(tiny_params, tenants[n], LCFG)
              for n in ("t0", "t1")}
    assert outs[""][0] == outs[""][1] == _engine_generate(
        tiny_cfg, tiny_params, [prompt], 5)[0]
    assert outs["t0"][0] == outs["t0"][1] == _engine_generate(
        tiny_cfg, merged["t0"], [prompt], 5)[0]
    assert outs["t1"][0] == _engine_generate(
        tiny_cfg, merged["t1"], [prompt], 5)[0]
    # the three variants genuinely decode differently...
    assert len({tuple(outs[""][0]), tuple(outs["t0"][0]),
                tuple(outs["t1"][0])}) == 3
    # ...and the repeat visits *were* cache hits within their own
    # namespace
    assert eng.metrics.summary()["prefill_tokens_saved"] > 0


def test_gateway_adapter_ownership(tiny_cfg, tiny_params, tenants):
    from repro.core.gateway import Gateway, ModelEntry, Unauthorized
    eng = InferenceEngine(tiny_cfg, tiny_params, max_batch=2, capacity=64,
                          adapter_slots=2)
    eng.register_adapter("t0", tenants["t0"], LCFG)
    gw = Gateway()
    gw.vet_model(ModelEntry("m", tiny_cfg.name, 0.1, 0.3), tiny_cfg)
    gw.bind_endpoints("m", [eng])
    gw.own_adapter("t0", "tenant-b")
    key_a = gw.mint_key("tenant-a")
    key_b = gw.mint_key("tenant-b")
    with pytest.raises(Unauthorized, match="not available") as e_owned:
        gw.completion(api_key=key_a.key, model="m@t0", prompt=[1, 2, 3],
                      max_tokens=2)
    # a private adapter is indistinguishable from a nonexistent one (no
    # enumeration oracle), and the owner's project is never leaked
    with pytest.raises(Unauthorized) as e_missing:
        gw.completion(api_key=key_a.key, model="m@ghost", prompt=[1, 2],
                      max_tokens=2)
    assert str(e_owned.value).replace("t0", "X") \
        == str(e_missing.value).replace("ghost", "X")
    assert "tenant-b" not in str(e_owned.value)
    out = gw.completion(api_key=key_b.key, model="m@t0", prompt=[1, 2, 3],
                        max_tokens=2)
    assert len(out["tokens"]) == 2
    assert "m@t0" in gw.usage_by_adapter()
    # base-model calls are unaffected by adapter ownership
    assert len(gw.completion(api_key=key_a.key, model="m",
                             prompt=[4, 5], max_tokens=2)["tokens"]) == 2


def test_mla_mixed_batch_matches_merged():
    cfg = scaled_down(get_config("deepseek-v2-lite-16b"), num_layers=2,
                      d_model=64, d_ff=128, vocab_size=128, num_heads=4)
    params = M.init(cfg, jax.random.PRNGKey(0))
    tenants = {f"m{i}": _mk_adapter(params, 20 + i) for i in range(2)}
    eng = InferenceEngine(cfg, params, max_batch=3, capacity=96,
                          adapter_slots=2)
    for n, ad in tenants.items():
        eng.register_adapter(n, ad, LCFG)
    rng = np.random.default_rng(5)
    names = ["", "m0", "m1"]
    prompts = [list(map(int, rng.integers(1, 127, 6 + i)))
               for i in range(3)]
    reqs = [Request(prompt=list(p), max_new_tokens=5, adapter=nm)
            for p, nm in zip(prompts, names)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    _check_vs_merged(cfg, params, tenants, names, prompts, reqs, 5,
                     cap=96)
