"""Sharding rules + HLO analyzer unit tests (no fake devices needed)."""
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import hlo_analysis as H
from repro.parallel import sharding as sh


def norm(spec):
    """Version-proof PartitionSpec comparison key.

    jax >= 0.5 normalizes ``P(("data",)) == P("data")``; 0.4.x does not,
    which is the only thing the old blanket xfail on test_rules_train
    actually covered — the rule table itself is version-independent.
    Collapsing singleton tuples makes the *real* assertions run (and
    fail loudly) on every jax we support instead of being skipped."""
    out = []
    for p in spec:
        if isinstance(p, (list, tuple)):
            p = p[0] if len(p) == 1 else tuple(p)
        out.append(p)
    return tuple(out)


def test_rules_train():
    r = sh.make_rules("train")
    assert norm(r.spec(("fsdp", "tensor"))) == norm(P("data", "model"))
    assert norm(r.spec(("act_batch", "act_qseq", None))) \
        == norm(P(("data",), "model", None))


def test_rules_serving_tp():
    r = sh.make_rules("serving_tp")
    # pure TP params: fsdp dim replicated, tensor dim over "model"
    assert norm(r.spec(("fsdp", "tensor"))) == norm(P(None, "model"))
    # paged pool leaf (num_blocks, block_size, KV, hd): only the KV-head
    # axis shards, so block ids/tables are layout-invariant host state
    assert norm(r.spec(("act_batch", "act_kvseq", "act_heads", None))) \
        == norm(P(None, None, "model", None))
    # MLA latent pool (no head axis) stays replicated
    assert norm(r.spec(("act_batch", "act_kvseq", None))) == P(None, None,
                                                               None)
    # logits replicated (act_vocab -> None): sampling is identical on
    # every device, no host round-trip to reconcile
    assert norm(r.spec(("act_batch", None, "act_vocab"))) == P(None, None,
                                                               None)
    # dense-MoE dispatch: no expert axis, shared experts still TP
    assert r.resolve("expert") is None
    assert r.resolve("act_ff") == "model"
    assert r.resolve("act_qseq") is None


def test_rules_dedup_same_axis():
    r = sh.make_rules("long")
    # kvseq takes (data, model); ssm_heads would also want model -> dropped
    spec = r.spec(("act_batch", "act_kvseq", "act_ssm_heads", None))
    assert spec == P(None, ("data", "model"), None, None)


def test_rules_decode():
    r = sh.make_rules("decode", multi_pod=True)
    assert r.spec(("act_batch",)) == P(("pod", "data"))
    assert r.spec(("fsdp", "tensor")) == P(None, "model")
    assert r.spec(("layers", "act_batch", "act_kvseq", "act_heads", None)) \
        == P(None, ("pod", "data"), "model", None, None)


SAMPLE_HLO = """
HloModule test, num_partitions=8

%body (p: (s32[], f32[16,64])) -> (s32[], f32[16,64]) {
  %p = (s32[], f32[16,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[16,64] get-tuple-element(%p), index=1
  %w = f32[64,64] constant({...})
  %ag = f32[16,128]{1,0} all-gather(%x), channel_id=1, replica_groups=[4,2]<=[8], dimensions={1}
  %d = f32[16,64]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[16,64]) tuple(%i2, %d)
}

%cond (p: (s32[], f32[16,64])) -> pred[] {
  %p = (s32[], f32[16,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[16,64]) -> f32[16,64] {
  %a = f32[16,64] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[16,64]) tuple(%z, %a)
  %w = (s32[], f32[16,64]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  %ar = f32[16,64]{1,0} all-reduce(%a), channel_id=2, replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%cond
  ROOT %o = f32[16,64] get-tuple-element(%w), index=1
}
"""


def test_hlo_shape_bytes():
    assert H.shape_bytes("f32[16,64]{1,0}") == 16 * 64 * 4
    assert H.shape_bytes("bf16[2,3]") == 12
    assert H.shape_bytes("(f32[4], s32[2])") == 24
    assert H.shape_bytes("pred[]") == 1


def test_hlo_walker_trip_counts_and_collectives():
    res = H.analyze(SAMPLE_HLO, 8)
    # dot: 2*16*64*64 flops, executed 12x in the loop
    assert res["flops"] == pytest.approx(12 * 2 * 16 * 64 * 64)
    # all-gather in loop: result 16*128*4 bytes * (n-1)/n with n=2, 12x
    ag = 12 * (16 * 128 * 4) * 0.5
    assert res["by_collective"]["all-gather"] == pytest.approx(ag)
    # all-reduce at entry: 2*(n-1)/n * bytes with n=8
    ar = 2 * (7 / 8) * 16 * 64 * 4
    assert res["by_collective"]["all-reduce"] == pytest.approx(ar)


def test_hlo_group_size_list_format():
    op = H.Op("x", "f32[4]", "all-reduce",
              "%a), replica_groups={{0,1,2,3}}, to_apply=%s")
    assert H._group_size(op, 16) == 4
