"""Disaggregated prefill/decode serving (KV-cache handoff): role
gating, export/import metadata, token-identity vs. the unified engine
on GQA and MLA (plain, speculative, and multi-LoRA decode), decode-pool
exhaustion deferral, preemption of imported requests, peak-accounting
of imported blocks, decode-side prefix adoption, gateway pairing with
crash recovery on both phases, unified fallback, and the handoff
metric/span surface."""
import numpy as np
import jax
import pytest

from repro.configs import get_config, scaled_down
from repro.core.gateway import (Gateway, ModelEntry, NoHealthyEndpoint)
from repro.finetune.lora import LoraConfig, lora_init, lora_randomize
from repro.models import model as M
from repro.obs import Observability
from repro.serving.engine import InferenceEngine, Request
from repro.serving.faults import (EngineFailure, FaultInjector, FaultSpec,
                                  VirtualClock)
from repro.serving.scheduler import SchedulerConfig

PROMPT = [5, 7, 11, 13, 17, 19, 23, 29]
GEN = 8


@pytest.fixture(scope="module")
def served(tiny_cfg):
    return tiny_cfg, M.init(tiny_cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def served_mla():
    cfg = scaled_down(get_config("deepseek-v2-lite-16b"), num_layers=2,
                      d_model=64, d_ff=128, vocab_size=128, num_heads=4)
    return cfg, M.init(cfg, jax.random.PRNGKey(1))


def _sched(**kw):
    kw.setdefault("prefix_block", 4)
    kw.setdefault("prefill_chunk", 8)
    return SchedulerConfig(**kw)


def _engine(cfg, params, role="unified", **kw):
    kw.setdefault("sched", _sched())
    kw.setdefault("max_batch", 3)
    kw.setdefault("capacity", 128)
    return InferenceEngine(cfg, params, role=role, **kw)


def _run_unified(cfg, params, prompts, gen=GEN, **kw):
    eng = _engine(cfg, params, **kw)
    reqs = [Request(prompt=list(p), max_new_tokens=gen) for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    return [list(r.generated) for r in reqs], eng


def _drive(pre, dec, reqs):
    """Minimal disagg driver: prefill to completion, walk every exported
    (req, handoff) pair over to the decode engine, decode to idle."""
    for r in reqs:
        pre.submit(r)
    pre.run_until_idle()
    while pre.outbox:
        dec.submit_handoff(*pre.outbox.popleft())
    dec.run_until_idle()
    return [list(r.generated) for r in reqs]


def _prompts(vocab, n=4, lo=6, hi=20, seed=3):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(1, vocab - 1, int(k))))
            for k in rng.integers(lo, hi, n)]


# ------------------------------------------------------------------ roles
def test_role_gating(served):
    cfg, params = served
    with pytest.raises(ValueError, match="unknown engine role"):
        _engine(cfg, params, role="draft")
    with pytest.raises(ValueError, match="paged"):
        InferenceEngine(cfg, params, role="prefill", paged=False)
    pre = _engine(cfg, params, role="prefill")
    dec = _engine(cfg, params, role="decode")
    with pytest.raises(EngineFailure) as ei:
        dec.submit(Request(prompt=list(PROMPT)))
    assert ei.value.kind == "role"
    with pytest.raises(EngineFailure) as ei:
        pre.submit_handoff(Request(prompt=list(PROMPT)), None)
    assert ei.value.kind == "role"


def test_export_metadata_and_handed_off_status(served):
    cfg, params = served
    pre = _engine(cfg, params, role="prefill")
    req = Request(prompt=list(PROMPT), max_new_tokens=GEN)
    pre.submit(req)
    pre.run_until_idle()
    assert not req.done and req.generated == []   # zero decode on prefill
    assert len(pre.outbox) == 1
    r, ho = pre.outbox[0]
    assert r is req
    assert ho.length == len(PROMPT)
    assert ho.prompt_tokens == list(PROMPT)
    assert ho.n_blocks == pre.slots.blocks_for(len(PROMPT))
    # the payload is a host pytree with a leading block axis
    assert all(leaf.shape[0] == ho.n_blocks
               for leaf in jax.tree.leaves(ho.blocks))
    assert ho.payload_bytes > 0
    s = pre.metrics.summary()
    assert s["handed_off"] == 1 and s["completed"] == 0
    # the slot is released after export (the radix tree may keep the
    # prompt blocks cached — evictable, like any finished request's)
    assert not pre.running and pre.slots.active_slots == []
    # num_active excludes the outbox: the export is the router's work now
    assert pre.num_active == 0


# --------------------------------------------------------- token identity
def test_disagg_token_identity_gqa(served):
    cfg, params = served
    prompts = _prompts(cfg.vocab_size)
    prompts.append(prompts[0][:10] + [3, 1, 4])   # shared-prefix tail
    ref, _ = _run_unified(cfg, params, prompts)
    pre = _engine(cfg, params, role="prefill")
    dec = _engine(cfg, params, role="decode")
    reqs = [Request(prompt=list(p), max_new_tokens=GEN) for p in prompts]
    out = _drive(pre, dec, reqs)
    assert out == ref
    assert all(r.done for r in reqs)
    assert dec.metrics.summary()["completed"] == len(prompts)


def test_disagg_token_identity_mla(served_mla):
    cfg, params = served_mla
    prompts = _prompts(cfg.vocab_size, seed=5)
    ref, _ = _run_unified(cfg, params, prompts)
    pre = _engine(cfg, params, role="prefill")
    dec = _engine(cfg, params, role="decode")
    out = _drive(pre, dec, [Request(prompt=list(p), max_new_tokens=GEN)
                            for p in prompts])
    assert out == ref


def test_disagg_speculative_decode_identity(served):
    """The decode pool may run speculative decoding — greedy output must
    still equal the plain unified engine (repetitive prompts so the
    n-gram drafter actually drafts)."""
    cfg, params = served
    rng = np.random.default_rng(9)
    pat = list(map(int, rng.integers(1, cfg.vocab_size - 1, 5)))
    prompts = [pat * 3 + list(map(int, rng.integers(1, cfg.vocab_size - 1,
                                                    2)))
               for _ in range(3)]
    ref, _ = _run_unified(cfg, params, prompts)
    pre = _engine(cfg, params, role="prefill")
    dec = _engine(cfg, params, role="decode", speculative="ngram",
                  spec_k=2)
    out = _drive(pre, dec, [Request(prompt=list(p), max_new_tokens=GEN)
                            for p in prompts])
    assert out == ref


def test_disagg_lora_adapter_pin_transfer(served):
    """An adapter'd request keeps its adapter across the handoff: the
    prefill engine pins it for prefill, the handoff names it, and the
    decode engine re-pins it at import — output identical to a unified
    multi-LoRA engine."""
    cfg, params = served
    lcfg = LoraConfig(rank=4)
    ads = {n: lora_randomize(
        lora_init(params, lcfg, jax.random.PRNGKey(i)),
        jax.random.PRNGKey(i + 100)) for i, n in enumerate(("t0", "t1"))}
    prompts = _prompts(cfg.vocab_size, n=4, seed=11)
    names = ["t0", "t1", "t0", "t1"]

    def mk(role):
        eng = _engine(cfg, params, role=role, adapter_slots=2)
        for n, ad in ads.items():
            eng.register_adapter(n, ad, lcfg)
        return eng

    reqs = [Request(prompt=list(p), max_new_tokens=GEN, adapter=n)
            for p, n in zip(prompts, names)]
    uni = mk("unified")
    urs = [Request(prompt=list(p), max_new_tokens=GEN, adapter=n)
           for p, n in zip(prompts, names)]
    for r in urs:
        uni.submit(r)
    uni.run_until_idle()
    pre, dec = mk("prefill"), mk("decode")
    for r in reqs:
        pre.submit(r)
    pre.run_until_idle()
    assert all(ho.adapter == r.adapter for r, ho in pre.outbox)
    while pre.outbox:
        dec.submit_handoff(*pre.outbox.popleft())
    dec.run_until_idle()
    assert [r.generated for r in reqs] == [r.generated for r in urs]
    # all pins released on both sides once drained
    assert pre.adapter_stats()["pinned"] == 0
    assert dec.adapter_stats()["pinned"] == 0


# ------------------------------------------------- capacity and accounting
def test_decode_pool_exhaustion_defers_not_drops(served):
    """When the decode pool cannot hold another import, the handoff
    waits in the admission queue (a defer) — it is never rejected — and
    completes token-exactly once blocks free up."""
    cfg, params = served
    prompts = [list(map(int, np.random.default_rng(s).integers(
        1, cfg.vocab_size - 1, 16))) for s in (21, 22)]
    ref, _ = _run_unified(cfg, params, prompts, gen=6)
    pre = _engine(cfg, params, role="prefill")
    # 8 allocatable blocks of 4 tokens: one 16-tok import + its growth
    # fits, a second concurrent one cannot
    dec = _engine(cfg, params, role="decode", max_batch=2, capacity=32,
                  pool_tokens=32,
                  sched=_sched(enable_prefix_cache=False))
    reqs = [Request(prompt=list(p), max_new_tokens=6) for p in prompts]
    for r in reqs:
        pre.submit(r)
    pre.run_until_idle()
    while pre.outbox:
        dec.submit_handoff(*pre.outbox.popleft())
    deferred = False
    for _ in range(200):
        if dec.scheduler.drained():
            break
        dec.step()
        deferred |= bool(dec.running) and bool(dec.handoffs)
    assert deferred                       # second import actually waited
    assert [list(r.generated) for r in reqs] == ref
    assert dec.metrics.summary()["rejected"] == 0


def test_preempted_import_requeues_as_handoff(served):
    """Pool pressure mid-decode preempts the youngest request; on a
    decode-role engine it re-enters the *handoff* queue (there is no raw
    prompt to re-prefill) and re-imports token-exactly."""
    cfg, params = served
    rng = np.random.default_rng(33)
    prompts = [list(map(int, rng.integers(1, cfg.vocab_size - 1, 12)))
               for _ in range(2)]
    ref, _ = _run_unified(cfg, params, prompts, gen=10)
    pre = _engine(cfg, params, role="prefill")
    # both imports fit initially (3+3 of 8 blocks) but growth to
    # 12+10=22 tokens each (6+6 blocks) overflows -> preemption
    dec = _engine(cfg, params, role="decode", max_batch=2, capacity=32,
                  pool_tokens=32,
                  sched=_sched(enable_prefix_cache=False))
    reqs = [Request(prompt=list(p), max_new_tokens=10) for p in prompts]
    out = _drive(pre, dec, reqs)
    assert out == ref
    assert dec.metrics.summary()["preempted"] >= 1


def test_peak_accounting_includes_imported_blocks(served):
    """Regression: blocks that enter the pool via import_kv must charge
    peak accounting exactly like locally-prefilled ones — the decode
    engine's peak matches a unified engine running the same request."""
    cfg, params = served
    ref, uni = _run_unified(cfg, params, [PROMPT], gen=GEN,
                            sched=_sched(enable_prefix_cache=False))
    pre = _engine(cfg, params, role="prefill")
    dec = _engine(cfg, params, role="decode",
                  sched=_sched(enable_prefix_cache=False))
    req = Request(prompt=list(PROMPT), max_new_tokens=GEN)
    assert _drive(pre, dec, [req]) == ref
    ds, us = dec.kv_stats(), uni.kv_stats()
    assert ds["kv_blocks_peak"] == us["kv_blocks_peak"]
    # the import alone reserves the handoff's footprint
    assert ds["kv_blocks_peak"] >= dec.slots.blocks_for(len(PROMPT))
    assert ds["kv_blocks_used"] == 0      # fully released after drain


def test_decode_side_prefix_adoption(served):
    """A second handoff sharing a prompt prefix adopts the decode-side
    radix tree's blocks instead of re-importing them — fewer blocks
    scattered, same tokens."""
    cfg, params = served
    head = list(PROMPT)                    # 8 tokens = 2 full blocks
    p0, p1 = head + [31, 37, 41, 43], head + [47, 53, 59, 61]
    ref, _ = _run_unified(cfg, params, [p0, p1], gen=GEN)
    obs = Observability()
    pre = _engine(cfg, params, role="prefill")
    dec = _engine(cfg, params, role="decode", obs=obs)
    r0 = Request(prompt=list(p0), max_new_tokens=GEN)
    r1 = Request(prompt=list(p1), max_new_tokens=GEN)
    pre.submit(r0), pre.submit(r1)
    pre.run_until_idle()
    # sequential imports so r0's blocks are in the tree before r1 lands
    dec.submit_handoff(*pre.outbox.popleft())
    dec.run_until_idle()
    dec.submit_handoff(*pre.outbox.popleft())
    dec.run_until_idle()
    assert [r0.generated, r1.generated] == ref
    snap = obs.registry.snapshot()
    assert snap["repro_serving_handoff_adopted_blocks_total"] >= 2
    assert snap["repro_serving_handoff_imported_total"] == 2


# ---------------------------------------------------------------- gateway
def _gw_disagg(cfg, params, *, n_pre=1, n_dec=1, unified=0, clock=None,
               obs=None, pre_faults=(), dec_faults=(), **kw):
    mk = lambda role, name, faults: _engine(  # noqa: E731
        cfg, params, role=role, name=name,
        **({"clock": clock} if clock is not None else {}),
        **({"faults": faults} if faults is not None else {}))
    pres = [mk("prefill", f"p{i}",
               pre_faults[i] if i < len(pre_faults) else None)
            for i in range(n_pre)]
    decs = [mk("decode", f"d{i}",
               dec_faults[i] if i < len(dec_faults) else None)
            for i in range(n_dec)]
    gw = Gateway(**({} if clock is None else {"clock": clock,
                                              "sleep": clock.sleep}),
                 obs=obs, **kw)
    gw.vet_model(ModelEntry(cfg.name, cfg.name, 0.5, 1.5), cfg)
    gw.bind_disagg(cfg.name, pres, decs)
    unis = [_engine(cfg, params, name=f"u{i}") for i in range(unified)]
    if unis:
        gw.bind_endpoints(cfg.name, unis)
    return gw, gw.mint_key("proj"), pres, decs, unis


def test_gateway_disagg_completion(served):
    cfg, params = served
    ref, _ = _run_unified(cfg, params, [PROMPT])
    gw, key, pres, decs, _ = _gw_disagg(cfg, params)
    out = gw.completion(api_key=key.key, model=cfg.name,
                        prompt=list(PROMPT), max_tokens=GEN)
    assert out["tokens"] == ref[0]
    assert out["usage"]["engine"] == "d0"
    assert pres[0].metrics.summary()["handed_off"] == 1


def test_gateway_falls_back_to_unified_when_pool_down(served):
    cfg, params = served
    ref, _ = _run_unified(cfg, params, [PROMPT])
    gw, key, pres, decs, unis = _gw_disagg(cfg, params, unified=1)
    pres[0].crash()
    out = gw.completion(api_key=key.key, model=cfg.name,
                        prompt=list(PROMPT), max_tokens=GEN)
    assert out["tokens"] == ref[0]
    assert out["usage"]["engine"] == "u0"
    # without unified endpoints the same outage is a typed reject
    gw2, key2, pres2, _, _ = _gw_disagg(cfg, params)
    pres2[0].crash()
    with pytest.raises(NoHealthyEndpoint):
        gw2.completion(api_key=key2.key, model=cfg.name,
                       prompt=list(PROMPT), max_tokens=GEN)


def test_gateway_crash_mid_decode_reimports_same_handoff(served):
    """Decode replica dies mid-stream: the router retries the decode
    phase only, re-importing the cached handoff on the next replica —
    no re-prefill, token-exact resume."""
    cfg, params = served
    ref, _ = _run_unified(cfg, params, [PROMPT])
    vc = VirtualClock()
    obs = Observability(clock=vc.now)
    inj = FaultInjector(
        [FaultSpec(point="emission", kind="crash", at_call=4)],
        clock_advance=vc.advance)
    gw, key, pres, decs, _ = _gw_disagg(
        cfg, params, n_dec=2, clock=vc, obs=obs, dec_faults=(inj,),
        retry_budget=3, breaker_threshold=1, breaker_cooldown_s=5.0)
    out = gw.completion(api_key=key.key, model=cfg.name,
                        prompt=list(PROMPT), max_tokens=GEN)
    assert out["tokens"] == ref[0]
    assert out["usage"]["engine"] == "d1"
    assert gw._breakers[id(decs[0])].state == "open"
    # prefill ran once; the handoff crossed the wire twice (d0 then d1)
    assert pres[0].metrics.summary()["handed_off"] == 1
    snap = obs.registry.snapshot()
    assert snap["repro_serving_handoff_seconds"]["count"] == 2
    assert snap['repro_serving_retries_total'
                '{reason="UpstreamFailure"}'] >= 1


def test_gateway_crash_during_prefill_retries_prefill(served):
    """Prefill replica dies mid-chunked-prefill (prompt > chunk, so the
    crash lands inside a micro-step): no handoff exists yet, so the
    router re-runs the whole prefill phase on the next replica."""
    cfg, params = served
    prompt = _prompts(cfg.vocab_size, n=1, lo=20, hi=21, seed=29)[0]
    ref, _ = _run_unified(cfg, params, [prompt])
    vc = VirtualClock()
    inj = FaultInjector(
        [FaultSpec(point="micro_step", kind="crash", at_call=2)],
        clock_advance=vc.advance)
    gw, key, pres, decs, _ = _gw_disagg(
        cfg, params, n_pre=2, clock=vc, pre_faults=(inj,),
        retry_budget=3, breaker_threshold=1)
    out = gw.completion(api_key=key.key, model=cfg.name,
                        prompt=list(prompt), max_tokens=GEN)
    assert out["tokens"] == ref[0]
    assert gw._breakers[id(pres[0])].state == "open"
    assert pres[0].metrics.summary()["handed_off"] == 0
    assert pres[1].metrics.summary()["handed_off"] == 1


def test_gateway_run_pipelined_identity(served):
    cfg, params = served
    prompts = _prompts(cfg.vocab_size, n=5, seed=17)
    ref, _ = _run_unified(cfg, params, prompts)
    gw, key, pres, decs, _ = _gw_disagg(cfg, params)
    router = gw.routers[cfg.name]
    reqs = [Request(prompt=list(p), max_new_tokens=GEN) for p in prompts]
    assert router.run_pipelined(reqs) == ref


def test_evacuation_returns_queued_handoffs(served):
    """A decode-engine crash surfaces requests still waiting in the
    handoff queue — nothing is silently lost."""
    cfg, params = served
    pre = _engine(cfg, params, role="prefill")
    dec = _engine(cfg, params, role="decode")
    req = Request(prompt=list(PROMPT), max_new_tokens=GEN)
    pre.submit(req)
    pre.run_until_idle()
    dec.submit_handoff(*pre.outbox.popleft())
    assert dec.num_active == 1
    evac = dec.crash()
    assert req in evac and not dec.handoffs


# ---------------------------------------------------------------- obs
def test_handoff_metrics_and_spans_one_snapshot(served):
    """One shared registry carries the full handoff story: exported /
    imported / blocks / bytes counters, per-request handoff status, and
    scheduler-track export/import instants."""
    cfg, params = served
    obs = Observability()
    pre = _engine(cfg, params, role="prefill", obs=obs)
    dec = _engine(cfg, params, role="decode", obs=obs)
    prompts = _prompts(cfg.vocab_size, n=3, seed=23)
    reqs = [Request(prompt=list(p), max_new_tokens=4) for p in prompts]
    _drive(pre, dec, reqs)
    snap = obs.registry.snapshot()
    assert snap["repro_serving_handoff_exported_total"] == 3
    assert snap["repro_serving_handoff_imported_total"] == 3
    assert snap["repro_serving_handoff_requests_total"] == 3
    assert snap["repro_serving_handoff_bytes_total"] > 0
    assert snap["repro_serving_handoff_blocks_total"] > 0
    sched_events = [e["name"]
                    for e in obs.tracer.events_for("scheduler")]
    assert sched_events.count("handoff_export") == 3
    assert sched_events.count("handoff_import") == 3
    rid = reqs[0].request_id
    names = [e["name"] for e in obs.tracer.events_for(f"req {rid}")]
    assert "handoff" in names and "finish" in names


# ---------------------------------------------------------------- int8
import jax.numpy as jnp  # noqa: E402


def test_disagg_quantized_handoff(served):
    """Both pools on int8 KV: the handoff carries the quantized payload
    plus scales (~half the bf16 wire bytes) and the decode side resumes
    token-exactly against an int8 unified engine."""
    cfg, params = served
    prompts = _prompts(cfg.vocab_size, seed=41)
    ref, _ = _run_unified(cfg, params, prompts, kv_dtype="int8")
    pre = _engine(cfg, params, role="prefill", kv_dtype="int8")
    dec = _engine(cfg, params, role="decode", kv_dtype="int8")
    reqs = [Request(prompt=list(p), max_new_tokens=GEN) for p in prompts]
    for r in reqs:
        pre.submit(r)
    pre.run_until_idle()
    hand = [ho for _, ho in pre.outbox]
    # wire payload ~halves vs a bf16 prefill pool of the same requests
    pre16 = _engine(cfg, params, role="prefill")
    reqs16 = [Request(prompt=list(p), max_new_tokens=GEN)
              for p in prompts]
    for r in reqs16:
        pre16.submit(r)
    pre16.run_until_idle()
    for h8, (_, h16) in zip(hand, pre16.outbox):
        assert h8.length == h16.length and h8.n_blocks == h16.n_blocks
        ratio = h8.payload_bytes / h16.payload_bytes
        assert 0.45 < ratio < 0.6
        assert any(leaf.dtype == jnp.int8
                   for leaf in jax.tree.leaves(h8.blocks))
    while pre.outbox:
        dec.submit_handoff(*pre.outbox.popleft())
    dec.run_until_idle()
    assert [list(r.generated) for r in reqs] == ref


def test_disagg_mixed_dtype_handoff_rejected(served):
    """A quantized handoff cannot be imported into a bf16 decode pool
    (and vice versa): the leaf structures differ, so the import raises
    instead of silently corrupting the pool."""
    cfg, params = served
    pre = _engine(cfg, params, role="prefill", kv_dtype="int8")
    dec = _engine(cfg, params, role="decode")
    req = Request(prompt=list(PROMPT), max_new_tokens=GEN)
    pre.submit(req)
    pre.run_until_idle()
    with pytest.raises(Exception):
        dec.submit_handoff(*pre.outbox.popleft())
        dec.run_until_idle()
