"""Per-kernel validation: shape/dtype sweeps + hypothesis properties,
asserting allclose against the pure-jnp ref.py oracles (interpret=True
executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd.kernel import ssd_scan
from repro.kernels.ssd.ref import ssd_ref
from repro.kernels.ssd import ops as ssd_ops
from repro.models.ssm import ssd_chunked


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return (x * scale).astype(dtype)


# ------------------------------------------------------------ flash
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("B,KV,G,Sq,Skv,D,blk", [
    (2, 2, 2, 128, 128, 64, 64),
    (1, 1, 4, 96, 96, 32, 32),
    (1, 2, 1, 130, 130, 128, 64),   # ragged -> padding path
])
def test_flash_shapes_dtypes(B, KV, G, Sq, Skv, D, blk, dtype, tol):
    H = KV * G
    q = _rand(1, (B, H, Sq, D), dtype)
    k = _rand(2, (B, KV, Skv, D), dtype)
    v = _rand(3, (B, KV, Skv, D), dtype)
    got = flash_attention(q, k, v, causal=True, blk_q=blk, blk_k=blk,
                          interpret=True)
    want = attention_ref(q, k, v, causal=True)
    assert got.dtype == dtype
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - want.astype(jnp.float32)))) < tol


@settings(max_examples=8, deadline=None)
@given(B=st.integers(1, 2), KV=st.sampled_from([1, 2]),
       G=st.sampled_from([1, 3]), S=st.sampled_from([17, 64, 100]),
       D=st.sampled_from([8, 32]), causal=st.booleans())
def test_flash_property(B, KV, G, S, D, causal):
    H = KV * G
    q = _rand(11, (B, H, S, D))
    k = _rand(12, (B, KV, S, D))
    v = _rand(13, (B, KV, S, D))
    got = flash_attention(q, k, v, causal=causal, blk_q=32, blk_k=32,
                          interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    assert float(jnp.max(jnp.abs(got - want))) < 2e-5


# ------------------------------------------------------------ decode
@pytest.mark.parametrize("B,KV,G,S,D,blk", [
    (2, 2, 2, 512, 64, 128),
    (3, 1, 8, 300, 32, 64),
    (1, 8, 2, 1024, 128, 256),
])
def test_decode_shapes(B, KV, G, S, D, blk):
    H = KV * G
    q = _rand(1, (B, H, D))
    k = _rand(2, (B, S, KV, D))
    v = _rand(3, (B, S, KV, D))
    lengths = jax.random.randint(jax.random.PRNGKey(4), (B,), 1, S + 1)
    got = decode_attention(q, k, v, lengths, blk_k=blk, interpret=True)
    want = decode_ref(q, k, v, lengths)
    assert float(jnp.max(jnp.abs(got - want))) < 2e-5


@settings(max_examples=8, deadline=None)
@given(B=st.integers(1, 3), KV=st.sampled_from([1, 2]),
       G=st.sampled_from([1, 4]), S=st.sampled_from([40, 129]),
       length_frac=st.floats(0.05, 1.0))
def test_decode_property(B, KV, G, S, length_frac):
    H, D = KV * G, 16
    q = _rand(21, (B, H, D))
    k = _rand(22, (B, S, KV, D))
    v = _rand(23, (B, S, KV, D))
    lengths = jnp.full((B,), max(1, int(S * length_frac)), jnp.int32)
    got = decode_attention(q, k, v, lengths, blk_k=32, interpret=True)
    want = decode_ref(q, k, v, lengths)
    assert float(jnp.max(jnp.abs(got - want))) < 2e-5


# ------------------------------------------------------------ ssd
@pytest.mark.parametrize("BH,L,P,N,chunk", [
    (4, 256, 64, 16, 64),
    (2, 128, 32, 128, 32),
    (1, 64, 16, 8, 16),
])
def test_ssd_vs_sequential_ref(BH, L, P, N, chunk):
    xdt = _rand(1, (BH, L, P), scale=0.5)
    dA = -jnp.abs(_rand(2, (BH, L))) * 0.1
    Bm = _rand(3, (BH, L, N), scale=0.3)
    Cm = _rand(4, (BH, L, N), scale=0.3)
    y, h = ssd_scan(xdt, dA, Bm, Cm, chunk=chunk, interpret=True)
    yr, hr = ssd_ref(xdt, dA, Bm, Cm)
    assert float(jnp.max(jnp.abs(y - yr))) < 1e-4
    assert float(jnp.max(jnp.abs(h - hr))) < 1e-4


@settings(max_examples=6, deadline=None)
@given(BH=st.integers(1, 3), nc=st.integers(1, 4),
       chunk=st.sampled_from([8, 32]), P=st.sampled_from([8, 16]),
       N=st.sampled_from([4, 16]))
def test_ssd_property_chunk_invariance(BH, nc, chunk, P, N):
    """The chunked form must be invariant to the chunk size."""
    L = nc * chunk
    xdt = _rand(31, (BH, L, P), scale=0.5)
    dA = -jnp.abs(_rand(32, (BH, L))) * 0.2
    Bm = _rand(33, (BH, L, N), scale=0.3)
    Cm = _rand(34, (BH, L, N), scale=0.3)
    y1, h1 = ssd_scan(xdt, dA, Bm, Cm, chunk=chunk, interpret=True)
    y2, h2 = ssd_scan(xdt, dA, Bm, Cm, chunk=L, interpret=True)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
    assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-4


def test_ssd_ops_matches_model_path():
    B, L, H, P, N = 2, 96, 4, 16, 32
    x = _rand(41, (B, L, H, P), scale=0.5)
    dt = jnp.abs(_rand(42, (B, L, H))) * 0.2
    A = -jnp.abs(_rand(43, (H,)))
    Bm = _rand(44, (B, L, N), scale=0.3)
    Cm = _rand(45, (B, L, N), scale=0.3)
    y1, h1 = ssd_ops.ssd(x, dt, A, Bm, Cm, chunk=32)
    y2, h2 = ssd_chunked(x, dt, A, Bm, Cm, 32)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
    assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-4


# ------------------------------------------------------------ rmsnorm
@pytest.mark.parametrize("shape,dtype,tol", [
    ((4, 100, 64), jnp.float32, 1e-5),
    ((3, 33), jnp.float32, 1e-5),
    ((2, 7, 130), jnp.bfloat16, 2e-2),
])
def test_rmsnorm_shapes_dtypes(shape, dtype, tol):
    x = _rand(1, shape, dtype)
    w = _rand(2, (shape[-1],))
    got = rmsnorm(x, w, interpret=True)
    want = rmsnorm_ref(x, w)
    assert got.dtype == dtype
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - want.astype(jnp.float32)))) < tol


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 50), d=st.sampled_from([8, 64, 130]),
       blk=st.sampled_from([4, 16, 256]))
def test_rmsnorm_property(rows, d, blk):
    x = _rand(51, (rows, d))
    w = _rand(52, (d,))
    got = rmsnorm(x, w, blk_rows=blk, interpret=True)
    want = rmsnorm_ref(x, w)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-5
