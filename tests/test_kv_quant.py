"""Accuracy guard for int8 quantized KV-cache serving.

Three gates (ISSUE: quantized serving must not silently change what the
engine says):

* ``kv_dtype="bf16"`` is BIT-FOR-BIT identical to the default path —
  the golden-token fixtures are replayed with the explicit flag and
  must reproduce the committed tokens exactly.  The int8 machinery is
  keyed off scale leaves in the cache tree, so bf16 jaxprs are
  structurally untouched.
* int8 greedy tokens must match the fp path at >= ``MATCH_FLOOR`` on
  the golden fixtures (both attention families: GQA and MLA).
* int8 paged decode logits stay within ``LOGIT_TOL`` of the dense fp
  logits on the same state (model-level A/B through
  ``PagedCacheSlots`` + ``decode_step_paged``).

Also covers satellite wiring: the engine accepts ``quantize_tree``
output directly (dequantizing at param load) and rejects invalid
``kv_dtype`` combinations.
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, scaled_down
from repro.finetune.quantize import dequantize_tree, quantize_tree
from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request
from repro.serving.kvcache import PagedCacheSlots

GOLDEN = json.loads(
    (Path(__file__).parent / "golden" / "golden_tokens.json").read_text())
PAGED_FAMILIES = sorted(f for f in GOLDEN if GOLDEN[f]["paged"])

MATCH_FLOOR = 0.90     # min greedy-token agreement, int8 KV vs fp KV
LOGIT_TOL = 0.25       # max |logit diff|, int8 paged vs dense fp


def _served(g):
    cfg = scaled_down(get_config(g["arch"]))
    return cfg, M.init(cfg, jax.random.PRNGKey(0), jnp.float32)


def _run(cfg, params, prompts, lens, **kw):
    eng = InferenceEngine(cfg, params, max_batch=4, capacity=128, **kw)
    reqs = [Request(prompt=list(p), max_new_tokens=n)
            for p, n in zip(prompts, lens)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    return [r.generated for r in reqs], eng


def _match_rate(got, want):
    hit = tot = 0
    for g, w in zip(got, want):
        tot += len(w)
        hit += sum(1 for a, b in zip(g, w) if a == b)
    return hit / max(tot, 1)


@pytest.mark.parametrize("family", PAGED_FAMILIES)
def test_bf16_explicit_is_bit_for_bit(family):
    """kv_dtype="bf16" must be indistinguishable from the default —
    the golden tokens pin the pre-quantization numerics exactly."""
    g = GOLDEN[family]
    cfg, params = _served(g)
    got, eng = _run(cfg, params, g["prompts"],
                    [len(w) for w in g["generated"]], kv_dtype="bf16")
    assert eng.kv_dtype == "bf16"
    assert got == g["generated"]


@pytest.mark.parametrize("family", PAGED_FAMILIES)
def test_int8_match_rate_floor(family):
    g = GOLDEN[family]
    cfg, params = _served(g)
    got, eng = _run(cfg, params, g["prompts"],
                    [len(w) for w in g["generated"]], kv_dtype="int8")
    assert eng.kv_dtype == "int8"
    assert all(len(t) == len(w) for t, w in zip(got, g["generated"]))
    rate = _match_rate(got, g["generated"])
    assert rate >= MATCH_FLOOR, (
        f"{family}: int8 KV greedy match rate {rate:.2f} below floor "
        f"{MATCH_FLOOR}")


def test_int8_capacity_doubles_same_budget(tiny_cfg, tiny_params):
    """At the same pool_tokens budget int8 carries ~2x the blocks with
    ~half the per-block device bytes."""
    stats = {}
    for dt in ("bf16", "int8"):
        eng = InferenceEngine(tiny_cfg, tiny_params, max_batch=2,
                              capacity=64, pool_tokens=256, kv_dtype=dt)
        stats[dt] = eng.kv_stats()
    assert stats["int8"]["kv_blocks_total"] == \
        2 * stats["bf16"]["kv_blocks_total"]
    ratio = (stats["int8"]["kv_block_bytes_per_device"]
             / stats["bf16"]["kv_block_bytes_per_device"])
    assert 0.45 < ratio < 0.6   # int8 payload + small f32 scale overhead


def test_engine_int8_matches_bf16_gqa(tiny_cfg, tiny_params):
    prompts = [[3, 5, 7, 11, 13], [2, 4, 6], [9, 1, 8, 2, 7, 6, 5]]
    lens = [12, 12, 12]
    bf, _ = _run(tiny_cfg, tiny_params, prompts, lens, kv_dtype="bf16")
    q8, _ = _run(tiny_cfg, tiny_params, prompts, lens, kv_dtype="int8")
    assert _match_rate(q8, bf) >= MATCH_FLOOR


def test_engine_int8_matches_bf16_mla():
    cfg = scaled_down(get_config("deepseek-v2-lite-16b"), num_layers=2,
                      d_model=64, vocab_size=128, num_heads=4)
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompts = [[3, 5, 7, 11, 13], [2, 4, 6]]
    lens = [12, 12]
    bf, _ = _run(cfg, params, prompts, lens, kv_dtype="bf16")
    q8, eng = _run(cfg, params, prompts, lens, kv_dtype="int8")
    assert "ckv_scale" in str(jax.tree_util.tree_structure(eng.slots.pool))
    assert _match_rate(q8, bf) >= MATCH_FLOOR


def test_int8_logit_error_bound(tiny_cfg, tiny_params):
    """Model-level A/B: one decode step over an int8 paged pool vs the
    dense fp cache on identical state — logits bounded, argmax equal."""
    cfg = tiny_cfg
    params = jax.tree.map(lambda x: x.astype(jnp.float32), tiny_params)
    B, L, S = 2, 12, 16
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 1,
                              cfg.vocab_size).astype(jnp.int32)
    batch = {"tokens": toks,
             "prompt_lengths": jnp.full((B,), L, jnp.int32)}
    logits0, cache, _ = M.prefill(cfg, params, batch)
    lengths = batch["prompt_lengths"]
    nxt = jnp.argmax(logits0, -1).astype(jnp.int32)[:, None]

    # dense fp reference step
    ref, _ = M.decode_step(cfg, params, nxt, cache, lengths + 1)

    slots = PagedCacheSlots(cfg, max_batch=B, capacity=S, block_size=4,
                            pool_tokens=B * S, kv_dtype="int8")
    dense_ax = M.cache_axes(cfg)

    def cut(x, ax, i):
        idx = [slice(None)] * x.ndim
        idx[ax.index("act_batch")] = slice(i, i + 1)
        idx[ax.index("act_kvseq")] = slice(0, L)
        return x[tuple(idx)]

    from repro.serving.kvcache import tree_walk
    for b in range(B):
        slot = slots.allocate(f"r{b}")
        assert slots.ensure_capacity(slot, L + 1)
        one = tree_walk(lambda x, ax, i=b: cut(x, ax, i), cache, dense_ax)
        slots.insert_prefill(slot, one, L)
    got, _ = M.decode_step_paged(cfg, params, nxt, slots.pool,
                                 slots.tables_device(), lengths + 1)
    err = float(jnp.max(jnp.abs(got - ref)))
    assert err < LOGIT_TOL, f"int8 paged logit error {err:.3f}"
    assert jnp.array_equal(jnp.argmax(got, -1), jnp.argmax(ref, -1))


def test_engine_accepts_quantized_params(tiny_cfg, tiny_params):
    """Satellite: quantize_tree output plugs straight into the engine
    (lifecycle release -> deploy without a manual dequant step) and
    serves the exact tokens of an explicit f32 dequant."""
    q = quantize_tree(tiny_params)
    prompts = [[3, 5, 7, 11], [2, 4, 6, 8, 10]]
    lens = [8, 8]
    got, eng = _run(tiny_cfg, q, prompts, lens)
    want, _ = _run(tiny_cfg, dequantize_tree(q, jnp.float32),
                   prompts, lens)
    assert got == want
    assert all(len(t) == 8 for t in got)
    # the engine holds dense (dequantized) leaves, not wrapper dicts
    assert all(not isinstance(x, dict)
               for x in jax.tree.leaves(eng.params))


def test_kv_dtype_validation(tiny_cfg, tiny_params):
    with pytest.raises(ValueError, match="kv_dtype"):
        InferenceEngine(tiny_cfg, tiny_params, kv_dtype="fp8")
    with pytest.raises(ValueError, match="paged"):
        InferenceEngine(tiny_cfg, tiny_params, paged=False,
                        kv_dtype="int8")
