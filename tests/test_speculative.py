"""Speculative decoding: rejection-sampling properties (greedy equals
baseline exactly, acceptance preserves the target distribution, k=0
degenerates to the plain engine), drafter units, engine token-identity
across drafters/architectures/KV layouts, rollback block accounting,
and the multi-query paged verify kernel's parity with its oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, scaled_down
from repro.kernels.paged_attention.kernel import (paged_decode_attention,
                                                  paged_verify_attention)
from repro.kernels.paged_attention.ref import paged_verify_ref
from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request
from repro.serving.kvcache import PagedCacheSlots
from repro.serving.sampling import filter_logits, spec_accept_batched
from repro.serving.scheduler import SchedulerConfig
from repro.serving.speculative import NGramDrafter, make_drafter


@pytest.fixture(scope="module")
def served(tiny_cfg):
    return tiny_cfg, M.init(tiny_cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def served_mla():
    cfg = scaled_down(get_config("deepseek-v2-lite-16b"), num_layers=2,
                      d_model=64, d_ff=128, vocab_size=128, num_heads=4)
    return cfg, M.init(cfg, jax.random.PRNGKey(1))


def _spec_prompts(rng, vocab, n=4, reps=3, tail=2):
    """Repetitive prompts (pattern * reps + unique tail): the n-gram
    drafter finds suffix matches, so acceptance is exercised for real."""
    pat = list(map(int, rng.integers(1, vocab - 1, 6)))
    return [pat * reps + list(map(int, rng.integers(1, vocab - 1, tail)))
            for _ in range(n)]


def _run(cfg, params, prompts, gen=8, temperature=0.0, seed=0, **kw):
    eng = InferenceEngine(cfg, params, max_batch=3, capacity=128, seed=seed,
                          sched=SchedulerConfig(prefix_block=4,
                                                prefill_chunk=8), **kw)
    reqs = [Request(prompt=list(p), max_new_tokens=gen,
                    temperature=temperature) for p in prompts]
    for r in reqs:
        eng.submit(r)
    summary = eng.run_until_idle()
    return [r.generated for r in reqs], summary, eng


# ----------------------------------------------------- accept/reject unit
def test_spec_accept_greedy_cascade_exact():
    """Greedy rows accept drafts by exact argmax match and emit the
    correction (or bonus) token — deterministically."""
    V, k = 8, 3
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((1, k + 1, V)), jnp.float32)
    gm = np.asarray(jnp.argmax(logits[0], -1))
    # drafts: first two match argmax, third does not
    toks = jnp.asarray([[1, gm[0], gm[1], (gm[2] + 1) % V]], jnp.int32)
    out, ne = spec_accept_batched(
        logits, toks, jnp.zeros((1, k, V)), jnp.asarray([k]),
        jax.random.PRNGKey(0), jnp.zeros(1), jnp.zeros(1, jnp.int32),
        jnp.ones(1), True)
    assert int(ne[0]) == 3
    assert list(np.asarray(out[0, :3])) == [int(gm[0]), int(gm[1]),
                                            int(gm[2])]
    # all-accept: the bonus token from the last position rides along
    toks = jnp.asarray([[1, gm[0], gm[1], gm[2]]], jnp.int32)
    out, ne = spec_accept_batched(
        logits, toks, jnp.zeros((1, k, V)), jnp.asarray([k]),
        jax.random.PRNGKey(0), jnp.zeros(1), jnp.zeros(1, jnp.int32),
        jnp.ones(1), True)
    assert int(ne[0]) == 4 and int(out[0, 3]) == int(gm[3])
    # n_draft = 0 degenerates to one plain argmax sample
    out, ne = spec_accept_batched(
        logits, toks, jnp.zeros((1, k, V)), jnp.asarray([0]),
        jax.random.PRNGKey(0), jnp.zeros(1), jnp.zeros(1, jnp.int32),
        jnp.ones(1), True)
    assert int(ne[0]) == 1 and int(out[0, 0]) == int(gm[0])


def test_spec_accept_preserves_target_distribution():
    """Statistical property (the speculative-sampling theorem): whatever
    the draft distribution q, the emitted-token marginal equals the
    (temperature-filtered) target p — position 0 unconditionally, and
    position 1 on the rows that accepted draft 0."""
    V, k, B, temp = 6, 2, 120_000, 0.7
    T = k + 1
    rng = np.random.default_rng(0)
    logits1 = jnp.asarray(rng.standard_normal((T, V)) * 1.5, jnp.float32)
    q1 = jax.nn.softmax(
        logits1[:k] + jnp.asarray(rng.standard_normal((k, V)), jnp.float32),
        -1)
    kd, ka = jax.random.split(jax.random.PRNGKey(7))
    d = jnp.stack([jax.random.categorical(
        jax.random.fold_in(kd, t),
        jnp.broadcast_to(jnp.log(q1[t]), (B, V))) for t in range(k)], 1)
    toks = jnp.concatenate(
        [jnp.ones((B, 1), jnp.int32), d.astype(jnp.int32)], 1)
    out, ne = spec_accept_batched(
        jnp.broadcast_to(logits1, (B, T, V)), toks,
        jnp.broadcast_to(q1, (B, k, V)), jnp.full((B,), k, jnp.int32),
        ka, jnp.full((B,), temp), jnp.zeros((B,), jnp.int32),
        jnp.ones((B,)), False)
    out, ne = np.asarray(out), np.asarray(ne)
    p0 = np.asarray(jax.nn.softmax(logits1[0] / temp))
    emp0 = np.bincount(out[:, 0], minlength=V) / B
    assert np.abs(emp0 - p0).max() < 0.01, emp0
    mask = ne >= 2
    p1 = np.asarray(jax.nn.softmax(logits1[1] / temp))
    emp1 = np.bincount(out[mask, 1], minlength=V) / mask.sum()
    assert np.abs(emp1 - p1).max() < 0.015, emp1
    # sanity: both accept and reject paths were exercised
    assert 0.05 < float(mask.mean()) < 0.95


def test_spec_accept_filters_match_sample_batched():
    """The cascade scores drafts against the same filtered target
    distribution sample_batched draws from (top-k here): a draft outside
    the top-k set has p(d) = 0 and must always be rejected."""
    V, k = 8, 1
    logits = jnp.asarray([[[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]] * 2],
                         jnp.float32)
    lf = filter_logits(logits[0, :1], jnp.asarray([1.0]),
                       jnp.asarray([2], jnp.int32), jnp.asarray([1.0]))
    keep = np.asarray(lf[0]) > -1e29
    assert keep.sum() == 2 and keep[6] and keep[7]
    worst = jnp.asarray([[1, 0]], jnp.int32)      # draft far below top-2
    q = jnp.zeros((1, k, V)).at[0, 0, 0].set(1.0)
    for s in range(16):
        out, ne = spec_accept_batched(
            logits, worst, q, jnp.asarray([k]), jax.random.PRNGKey(s),
            jnp.asarray([1.0]), jnp.asarray([2], jnp.int32),
            jnp.ones(1), False)
        assert int(ne[0]) == 1          # always rejected...
        assert int(out[0, 0]) in (6, 7)  # ...and resampled inside top-k


# ----------------------------------------------------------- drafter units
def test_ngram_drafter_suffix_lookup():
    d = NGramDrafter(vocab_padded=64, max_n=3, min_n=1)
    assert d.deterministic   # q is one-hot, built inside the accept jit
    # ... 7 8 9 | 5 6 [7 8 9] -> continuation after the earlier [7 8 9]
    ctx = [1, 7, 8, 9, 5, 6, 7, 8, 9]
    drafts, probs = d.propose(0, ctx, k=3, temperature=0.0)
    assert drafts == [5, 6, 7]
    assert probs is None
    # no earlier occurrence of any suffix n-gram: nothing proposed
    drafts, probs = d.propose(0, [1, 2, 3, 4, 5], k=3, temperature=0.0)
    assert drafts == [] and probs is None
    # most recent earlier match wins
    ctx = [7, 1, 7, 2, 7]
    drafts, _ = d.propose(0, ctx, k=1, temperature=0.0)
    assert drafts == [2]


def test_spec_accept_onehot_q_built_in_jit():
    """draft_probs=None (deterministic drafter) must behave exactly like
    passing the explicit one-hot distributions."""
    V, k, B = 8, 2, 64
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.standard_normal((B, k + 1, V)), jnp.float32)
    toks = jnp.asarray(rng.integers(0, V, (B, k + 1)), jnp.int32)
    onehot = jax.nn.one_hot(toks[:, 1:], V, dtype=jnp.float32)
    args = (jnp.full((B,), k, jnp.int32), jax.random.PRNGKey(3),
            jnp.full((B,), 0.9), jnp.zeros((B,), jnp.int32),
            jnp.ones((B,)), False)
    out_a, ne_a = spec_accept_batched(logits, toks, None, *args)
    out_b, ne_b = spec_accept_batched(logits, toks, onehot, *args)
    assert np.array_equal(np.asarray(out_a), np.asarray(out_b))
    assert np.array_equal(np.asarray(ne_a), np.asarray(ne_b))


def test_draft_model_drafter_replays_target_context(served):
    """The draft-model drafter's proposals given a context equal running
    the draft model itself over that context (greedy): its per-slot KV
    catch-up (prefill, then multi-token verify deltas) is exact."""
    cfg, params = served
    dr = make_drafter("draft", cfg, spec_k=3, capacity=64,
                      draft_cfg=cfg, draft_params=params)
    ctx = [5, 9, 3, 7, 2, 11]
    drafts, probs = dr.propose(0, ctx, 3, 0.0)
    # reference: plain prefill + greedy decode of the same model
    b = {"tokens": jnp.asarray([ctx], jnp.int32),
         "prompt_lengths": jnp.asarray([len(ctx)], jnp.int32)}
    logits, cache, _ = M.prefill(cfg, params, b)
    cache = jax.tree.map(lambda a: a.astype(jnp.bfloat16),
                         M.pad_cache(cfg, cache, 64))
    want, L = [], len(ctx)
    for _ in range(3):
        t = int(jnp.argmax(logits[0]))
        want.append(t)
        L += 1
        logits, cache = M.decode_step(cfg, params,
                                      jnp.asarray([[t]], jnp.int32), cache,
                                      jnp.asarray([L], jnp.int32))
    assert drafts == want
    assert probs.shape[0] == 3 and np.all(probs.sum(-1) > 0.99)
    # second round: catch-up over the emitted delta, same property
    ctx2 = ctx + want + [4]
    drafts2, _ = dr.propose(0, ctx2, 2, 0.0)
    # rebuild reference from scratch for ctx2 (cheap, unambiguous)
    b = {"tokens": jnp.asarray([ctx2], jnp.int32),
         "prompt_lengths": jnp.asarray([len(ctx2)], jnp.int32)}
    logits, cache, _ = M.prefill(cfg, params, b)
    cache = jax.tree.map(lambda a: a.astype(jnp.bfloat16),
                         M.pad_cache(cfg, cache, 64))
    want2, L = [], len(ctx2)
    for _ in range(2):
        t = int(jnp.argmax(logits[0]))
        want2.append(t)
        L += 1
        logits, cache = M.decode_step(cfg, params,
                                      jnp.asarray([[t]], jnp.int32), cache,
                                      jnp.asarray([L], jnp.int32))
    assert drafts2 == want2
    dr.release(0)
    assert not dr._state


def test_drafter_factory_validates():
    cfg = scaled_down(get_config("qwen1.5-4b"), num_layers=2, d_model=64,
                      d_ff=128, vocab_size=128, num_heads=4,
                      num_kv_heads=2, head_dim=16)
    assert make_drafter(None, cfg, spec_k=4, capacity=64) is None
    with pytest.raises(ValueError):
        make_drafter("draft", cfg, spec_k=4, capacity=64)  # no draft model
    with pytest.raises(ValueError):
        bad = scaled_down(get_config("qwen1.5-4b"), num_layers=1,
                          d_model=32, d_ff=64, vocab_size=64, num_heads=2,
                          num_kv_heads=1, head_dim=16)
        make_drafter("draft", cfg, spec_k=4, capacity=64, draft_cfg=bad,
                     draft_params={})
    with pytest.raises(ValueError):
        make_drafter("huh", cfg, spec_k=4, capacity=64)


# --------------------------------------------------- engine token identity
def test_spec_ngram_paged_gqa_token_identical(served):
    cfg, params = served
    rng = np.random.default_rng(3)
    prompts = _spec_prompts(rng, cfg.vocab_size)
    base, _, _ = _run(cfg, params, prompts, gen=10)
    spec, s, eng = _run(cfg, params, prompts, gen=10,
                        speculative="ngram", spec_k=3)
    assert eng.paged
    assert spec == base
    assert s["spec_acceptance_rate"] > 0       # repetitive prompts hit
    assert s["spec_tokens_per_launch"] > 1.0
    # rollback accounting: no leaked pool blocks after drain (the only
    # remaining refs are the radix tree's stored prompt nodes)
    assert eng.slots.bp.num_used == eng.scheduler.prefix_cache.n_nodes
    assert not eng.slots.slot_owner


def test_spec_ngram_paged_mla_token_identical(served_mla):
    cfg, params = served_mla
    assert M.supports_speculative(cfg)
    rng = np.random.default_rng(5)
    prompts = _spec_prompts(rng, cfg.vocab_size, n=3)
    base, _, _ = _run(cfg, params, prompts, gen=8)
    spec, s, eng = _run(cfg, params, prompts, gen=8,
                        speculative="ngram", spec_k=3)
    assert eng.paged
    assert spec == base
    assert s["spec_acceptance_rate"] > 0


def test_spec_dense_layout_token_identical(served):
    """Speculation also runs on the dense per-slot KV layout (rollback is
    a pure length shrink there — no block accounting)."""
    cfg, params = served
    rng = np.random.default_rng(7)
    prompts = _spec_prompts(rng, cfg.vocab_size, n=3)
    base, _, _ = _run(cfg, params, prompts, gen=8, paged=False)
    spec, s, _ = _run(cfg, params, prompts, gen=8, paged=False,
                      speculative="ngram", spec_k=3)
    assert spec == base
    assert s["spec_acceptance_rate"] > 0


def test_spec_draft_model_token_identical(served):
    """Draft-model drafter end-to-end: a self-draft (target drafting for
    itself) must accept ~everything; a random-init draft accepts ~nothing
    — but both are token-identical to the baseline, because accept/
    reject guarantees correctness regardless of draft quality."""
    cfg, params = served
    rng = np.random.default_rng(11)
    prompts = [list(map(int, rng.integers(1, cfg.vocab_size - 1, 5)))
               for _ in range(3)]
    base, _, _ = _run(cfg, params, prompts, gen=8)
    good, sg, _ = _run(cfg, params, prompts, gen=8, speculative="draft",
                       spec_k=3, draft_cfg=cfg, draft_params=params)
    assert good == base
    assert sg["spec_acceptance_rate"] > 0.9
    bad_cfg = scaled_down(get_config("qwen1.5-4b"), num_layers=1,
                          d_model=32, d_ff=64, vocab_size=cfg.vocab_size,
                          num_heads=2, num_kv_heads=1, head_dim=16)
    bad_params = M.init(bad_cfg, jax.random.PRNGKey(99))
    bad, sb, _ = _run(cfg, params, prompts, gen=8, speculative="draft",
                      spec_k=3, draft_cfg=bad_cfg, draft_params=bad_params)
    assert bad == base
    assert sb["spec_acceptance_rate"] < sg["spec_acceptance_rate"]


def test_spec_k0_degenerates_to_plain_engine(served):
    """spec_k=0 is the plain engine: one token per launch, tokens
    identical, tokens-per-launch exactly 1."""
    cfg, params = served
    rng = np.random.default_rng(13)
    prompts = _spec_prompts(rng, cfg.vocab_size, n=3)
    base, _, _ = _run(cfg, params, prompts, gen=6)
    spec, s, _ = _run(cfg, params, prompts, gen=6,
                      speculative="ngram", spec_k=0)
    assert spec == base
    assert s["spec_tokens_per_launch"] == 1.0


def test_spec_sampled_mode_runs_and_respects_budget(served):
    """temperature > 0: no token-identity claim (RNG streams differ),
    but every request completes with exactly its budget, EOS semantics
    hold, and acceptance counters are sane."""
    cfg, params = served
    rng = np.random.default_rng(17)
    prompts = _spec_prompts(rng, cfg.vocab_size, n=4)
    outs, s, eng = _run(cfg, params, prompts, gen=9, temperature=0.8,
                        speculative="ngram", spec_k=3, seed=42)
    assert all(len(o) == 9 for o in outs)
    assert s["completed"] == 4
    assert 0.0 <= s["spec_acceptance_rate"] <= 1.0
    assert 1.0 <= s["spec_tokens_per_launch"] <= 4.0
    assert eng.slots.bp.num_used == eng.scheduler.prefix_cache.n_nodes


def test_spec_unsupported_arch_rejected():
    cfg = scaled_down(get_config("mamba2-1.3b"))
    assert not M.supports_speculative(cfg)
    params = M.init(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        InferenceEngine(cfg, params, speculative="ngram")


@pytest.mark.parametrize("arch,overrides", [
    ("qwen1.5-4b", dict(num_layers=2, d_model=64, d_ff=128, vocab_size=128,
                        num_heads=4, num_kv_heads=2, head_dim=16)),
    ("deepseek-v2-lite-16b", dict(num_layers=2, d_model=64, d_ff=128,
                                  vocab_size=128, num_heads=4)),
])
def test_spec_multi_lora_token_identical(arch, overrides):
    """Speculation composes with multi-LoRA: adapter'd rows thread their
    per-row shifts through the multi-token verify (GQA projections and
    MLA's absorbed-weight formulation alike), token-identically to the
    non-speculative multi-LoRA engine."""
    from repro.finetune.lora import LoraConfig, lora_init, lora_randomize
    cfg = scaled_down(get_config(arch), **overrides)
    params = M.init(cfg, jax.random.PRNGKey(0))
    lcfg = LoraConfig(rank=4)
    ad = lora_randomize(lora_init(params, lcfg, jax.random.PRNGKey(10)),
                        jax.random.PRNGKey(20))
    rng = np.random.default_rng(9)
    prompts = _spec_prompts(rng, cfg.vocab_size, n=3)

    def run(**kw):
        eng = InferenceEngine(cfg, params, max_batch=3, capacity=128,
                              adapter_slots=2,
                              sched=SchedulerConfig(prefix_block=4,
                                                    prefill_chunk=8), **kw)
        eng.register_adapter("t0", ad, lcfg)
        reqs = [Request(prompt=list(p), max_new_tokens=8,
                        adapter="t0" if i % 2 else "")
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        s = eng.run_until_idle()
        return [r.generated for r in reqs], s

    base, _ = run()
    spec, s = run(speculative="ngram", spec_k=3)
    assert spec == base
    assert s["spec_acceptance_rate"] > 0


# ----------------------------------------------------- rollback accounting
def test_paged_trim_frees_tail_blocks(tiny_cfg):
    slots = PagedCacheSlots(tiny_cfg, max_batch=2, capacity=64,
                            block_size=8)
    s = slots.allocate("r0")
    assert slots.ensure_capacity(s, 30)          # 4 blocks
    held = slots.block_ids(s)
    slots.trim(s, 17)                            # 3 blocks suffice
    assert slots.block_ids(s) == held[:3]
    assert held[3] not in slots.bp.refs
    assert slots.tables[s, 3] == 0
    slots.trim(s, 17)                            # idempotent
    assert slots.block_ids(s) == held[:3]
    # shared (adopted) blocks are never trimmed: length floor covers them
    s2 = slots.allocate("r1")
    slots.adopt_prefix(s2, held[:2], 16)
    slots.ensure_capacity(s2, 20)
    slots.trim(s2, 17)
    assert slots.bp.refs[held[0]] == 2 and slots.bp.refs[held[1]] == 2
    slots.release(s)
    slots.release(s2)
    assert slots.bp.num_used == 0


def test_spec_preemption_under_pool_pressure(served):
    """Speculative growth (+k+1 blocks per slot per step) under a small
    pool: preemption + requeue still resumes token-exactly."""
    cfg, params = served
    rng = np.random.default_rng(19)
    prompts = _spec_prompts(rng, cfg.vocab_size, n=4)
    base, _, _ = _run(cfg, params, prompts, gen=10)
    spec, s, eng = _run(cfg, params, prompts, gen=10, speculative="ngram",
                        spec_k=3, pool_tokens=160)
    assert spec == base
    assert not eng.slots.slot_owner


# ----------------------------------------------- multi-query verify kernel
def _paged_layout(k, v, bs, seed=0, extra_blocks=3):
    B, S, KV, D = k.shape
    W = S // bs
    nb = 1 + B * W + extra_blocks
    rng = np.random.default_rng(seed)
    ids = rng.permutation(np.arange(1, nb))[:B * W]
    kp = np.zeros((nb, bs, KV, D), np.float32)
    vp = np.zeros((nb, bs, KV, D), np.float32)
    bt = np.zeros((B, W), np.int32)
    it = iter(ids)
    for b in range(B):
        for j in range(W):
            pid = int(next(it))
            kp[pid] = np.asarray(k[b, j * bs:(j + 1) * bs])
            vp[pid] = np.asarray(v[b, j * bs:(j + 1) * bs])
            bt[b, j] = pid
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(bt)


@pytest.mark.parametrize("B,KV,G,W,bs,D,T", [
    (2, 2, 2, 4, 16, 64, 4),
    (3, 1, 8, 3, 32, 32, 3),      # MQA-style wide groups
    (1, 2, 2, 4, 8, 32, 5),       # tail spans a block boundary
    (2, 2, 1, 2, 64, 16, 1),      # T=1: single-query degenerate case
])
def test_paged_verify_kernel_matches_oracle(B, KV, G, W, bs, D, T):
    H = KV * G
    S = W * bs
    rng = np.random.default_rng(B * 100 + T)
    q = jnp.asarray(rng.standard_normal((B, T, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    lens = [S, max(T + 1, S - bs // 2 - 1), max(T, S // 2)][:B]
    lengths = jnp.asarray(lens + [S] * (B - len(lens)), jnp.int32)[:B]
    kp, vp, bt = _paged_layout(k, v, bs, seed=B)
    got = paged_verify_attention(q, kp, vp, bt, lengths, interpret=True)
    want = paged_verify_ref(q, kp, vp, bt, lengths)
    assert got.shape == (B, T, H, D)
    assert float(jnp.max(jnp.abs(got - want))) < 2e-5
    if T == 1:
        dec = paged_decode_attention(q[:, 0], kp, vp, bt, lengths,
                                     interpret=True)
        assert float(jnp.max(jnp.abs(got[:, 0] - dec))) < 1e-6


def test_verify_step_matches_sequential_decode(served):
    """Model-level contract: one verify_step launch over a T-token tail
    produces (bit-for-bit on GQA) the same logits as T sequential
    decode_steps — the exactness speculative acceptance relies on."""
    cfg, params = served
    prompt = [5, 9, 3, 7, 2]
    b = {"tokens": jnp.asarray([prompt], jnp.int32),
         "prompt_lengths": jnp.asarray([len(prompt)], jnp.int32)}
    logits, cache, _ = M.prefill(cfg, params, b)
    cache = jax.tree.map(lambda a: a.astype(jnp.bfloat16),
                         M.pad_cache(cfg, cache, 64))
    toks, L, seq = [int(jnp.argmax(logits[0]))], len(prompt), []
    c = cache
    for _ in range(4):
        L += 1
        lg, c = M.decode_step(cfg, params,
                              jnp.asarray([[toks[-1]]], jnp.int32), c,
                              jnp.asarray([L], jnp.int32))
        seq.append(np.asarray(lg[0]))
        toks.append(int(jnp.argmax(lg[0])))
    vlog, _ = M.verify_step(cfg, params, jnp.asarray([toks[:4]], jnp.int32),
                            cache, jnp.asarray([len(prompt) + 4], jnp.int32))
    v = np.asarray(vlog[0])
    assert max(float(np.max(np.abs(v[t] - seq[t]))) for t in range(4)) == 0.0
