"""Optimizer, schedules, checkpoint (incl. elastic reshard), data
pipeline determinism, trainer fault tolerance."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.data.mixtures import Mixture, SourceSpec
from repro.data.pipeline import DataConfig, PreferenceDataset, SFTDataset, SyntheticLM
from repro.training.optimizer import (OptConfig, clip_by_global_norm,
                                      global_norm, opt_init, opt_update)
from repro.training.schedule import warmup_cosine, wsd


# ------------------------------------------------------------ optimizer
def test_adamw_matches_manual_formula():
    cfg = OptConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    st_ = opt_init(cfg, p)
    new_p, st_ = opt_update(cfg, g, st_, p, 0.1)
    mu = 0.1 * 0.5
    nu = 0.01 * 0.25
    d = (mu / (1 - 0.9)) / (np.sqrt(nu / (1 - 0.99)) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.asarray(p["w"]) - 0.1 * d, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(0.1, 100.0), max_norm=st.floats(0.1, 10.0))
def test_clip_property(scale, max_norm):
    g = {"a": jnp.ones((4,)) * scale, "b": jnp.ones((2, 2)) * scale}
    clipped, norm = clip_by_global_norm(g, max_norm)
    new_norm = float(global_norm(clipped))
    assert new_norm <= max(max_norm * 1.001, float(norm) + 1e-6)
    if float(norm) <= max_norm:  # no-op when under the limit
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(g["a"]), rtol=1e-6)


def test_adafactor_memory_is_sublinear():
    cfg = OptConfig(name="adafactor")
    p = {"w": jnp.zeros((128, 256))}
    st_ = opt_init(cfg, p)
    n_state = sum(x.size for x in jax.tree.leaves(st_))
    assert n_state < 128 * 256 / 10  # factored, not full

def test_schedules():
    assert float(warmup_cosine(0, peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) == 0.0
    assert float(warmup_cosine(10, peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) == pytest.approx(1.0)
    assert float(wsd(50, peak_lr=1.0, warmup_steps=10,
                     total_steps=100)) == pytest.approx(1.0)
    assert float(wsd(100, peak_lr=1.0, warmup_steps=10,
                     total_steps=100)) == pytest.approx(0.0, abs=1e-6)


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    ckpt.save(str(tmp_path), 7, tree, {"note": "x"})
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          tree)
    out, manifest = ckpt.restore(str(tmp_path), target)
    assert manifest["step"] == 7
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_gc_retention(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in [10, 20, 30, 40, 50]:
        ckpt.save(str(tmp_path), s, tree)
    deleted = ckpt.gc(str(tmp_path), keep_last=2, keep_every=30)
    assert ckpt.list_steps(str(tmp_path)) == [30, 40, 50]
    assert sorted(deleted) == [10, 20]


def test_checkpoint_elastic_reshard(tmp_path):
    """Save under one layout, restore under another (shard-file overlap)."""
    import os
    # simulate a sharded save by writing two half-files manually
    a = np.arange(32, dtype=np.float32).reshape(8, 4)
    tree = {"w": jnp.asarray(a)}
    ckpt.save(str(tmp_path), 1, tree)
    # restore with single-device "sharding" (None) works
    out, _ = ckpt.restore(
        str(tmp_path), {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)})
    np.testing.assert_array_equal(np.asarray(out["w"]), a)
    # region reader assembles arbitrary slices
    with open(os.path.join(str(tmp_path), "step_0000000001",
                           "manifest.json")) as f:
        import json
        entry = [e for e in json.load(f)["leaves"] if e["id"] == "w"][0]
    region = ckpt._read_region(
        os.path.join(str(tmp_path), "step_0000000001"), entry,
        [(2, 6), (1, 3)])
    np.testing.assert_array_equal(region, a[2:6, 1:3])


# ------------------------------------------------------------ data
def test_data_determinism_and_resume():
    ds = SyntheticLM(DataConfig(vocab_size=64, seq_len=16, global_batch=4))
    b1 = ds.batch(5)
    b2 = ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_bigram_structure():
    ds = SyntheticLM(DataConfig(vocab_size=64, seq_len=32, global_batch=4))
    b = ds.batch(0)
    succ = ds.successors
    for row_t, row_y in zip(b["tokens"], b["targets"]):
        for t, y in zip(row_t, row_y):
            assert y in succ[t]


def test_sft_mask_covers_response_only():
    ds = SFTDataset(DataConfig(vocab_size=64, seq_len=32, global_batch=2),
                    prompt_len=8)
    b = ds.batch(0)
    assert b["mask"][:, :7].sum() == 0
    assert b["mask"][:, 7:].all()


def test_preference_pairs_differ_after_prompt():
    ds = PreferenceDataset(DataConfig(vocab_size=64, seq_len=32,
                                      global_batch=2), prompt_len=8)
    b = ds.batch(0)
    np.testing.assert_array_equal(b["chosen"]["tokens"][:, :8],
                                  b["rejected"]["tokens"][:, :8])
    assert not np.array_equal(b["chosen"]["tokens"][:, 8:],
                              b["rejected"]["tokens"][:, 8:])


@settings(max_examples=5, deadline=None)
@given(w1=st.floats(0.1, 10), w2=st.floats(0.1, 10))
def test_mixture_weights_respected(w1, w2):
    dc = DataConfig(vocab_size=64, seq_len=8, global_batch=2)
    m = Mixture([(SourceSpec("a", w1), SyntheticLM(dc)),
                 (SourceSpec("b", w2), SyntheticLM(dc))], seed=1)
    counts = {"a": 0, "b": 0}
    for step in range(200):
        counts[m.batch(step)["source"]] += 1
    frac = counts["a"] / 200
    expect = w1 / (w1 + w2)
    assert abs(frac - expect) < 0.15


def test_mixture_recipe_hash_changes_with_weights():
    dc = DataConfig(vocab_size=64, seq_len=8, global_batch=2)
    m1 = Mixture([(SourceSpec("a", 1.0), SyntheticLM(dc))])
    m2 = Mixture([(SourceSpec("a", 2.0), SyntheticLM(dc))])
    assert m1.recipe_hash() != m2.recipe_hash()


# ------------------------------------------------------------ trainer
def test_trainer_failure_restart(tmp_path, tiny_cfg):
    from repro.training.trainer import (SimulatedNodeFailure, Trainer,
                                        TrainerConfig)
    data = SyntheticLM(DataConfig(vocab_size=tiny_cfg.vocab_size,
                                  seq_len=16, global_batch=4))
    fails = {6, 13}

    def inject(step):
        if step in fails:
            fails.discard(step)
            raise SimulatedNodeFailure(step)

    tr = Trainer(tiny_cfg, OptConfig(lr=1e-2), data,
                 TrainerConfig(num_steps=32, ckpt_every=4,
                               ckpt_dir=str(tmp_path), log_every=4),
                 failure_injector=inject)
    res = tr.run()
    assert res["restarts"] == 2
    assert res["final_step"] == 32
    losses = [m["loss"] for m in res["log"]]
    # convergence bound: at this scale (2-layer d=64, lr=1e-2, batch
    # 4x16 tokens) per-sample loss oscillates by ~±0.3 for the first
    # ~20 steps, so single-sample early-vs-late comparisons flip sign
    # across jax versions; 3-sample means over a 32-step run separate
    # by ~0.35 deterministically.  The convergence signal proper is
    # this mean gap; the restart/final_step asserts above are what the
    # test is actually about (fault tolerance).
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.05


def test_straggler_detector_flags_persistent_only():
    from repro.training.trainer import StragglerDetector
    det = StragglerDetector(ratio=2.0, patience=3)
    times = {f"n{i}": 1.0 for i in range(8)}
    slow = dict(times, n7=5.0)
    assert det.observe(slow) == []
    assert det.observe(slow) == []
    assert det.observe(slow) == ["n7"]
    # a transient blip never triggers
    det2 = StragglerDetector(ratio=2.0, patience=3)
    det2.observe(slow)
    det2.observe(times)   # recovered
    det2.observe(slow)
    det2.observe(slow)
    assert det2.observe(slow) == ["n7"]
