"""Scheduler lifecycle property layer (shadow-model style, like
test_kvcache_props.py): random admit/step/preempt/crash/handoff
schedules drive real engines on a virtual clock while a host-side
shadow checks, after every operation, that slot/ledger/pool accounting
stays consistent, that no request's token stream ever loses or repeats
a token (generated is append-only, bounded by max_new_tokens), and that
the degradation ladder moves monotonically one rung at a time.  The
disaggregated simulator additionally checks request *conservation* —
every live request sits in exactly one place (queue / running / outbox
/ handoff queue) — and that arbitrary interleavings of prefill steps,
handoff moves, decode steps, preemptions, and crashes still end
token-identical to a unified engine."""
import numpy as np
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, scaled_down
from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request
from repro.serving.faults import VirtualClock
from repro.serving.scheduler import SchedulerConfig

CFG = scaled_down(get_config("qwen1.5-4b"), num_layers=2, d_model=32,
                  d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                  head_dim=8)


@pytest.fixture(scope="module")
def served():
    return CFG, M.init(CFG, jax.random.PRNGKey(0))


def _engine(cfg, params, role="unified", **kw):
    # deliberately tight pool: 3 slots x up to 5 blocks each against 12
    # allocatable blocks, so schedules really hit defers and preemptions
    kw.setdefault("max_batch", 3)
    kw.setdefault("capacity", 32)
    kw.setdefault("pool_tokens", 48)
    kw.setdefault("sched", SchedulerConfig(
        prefix_block=4, prefill_chunk=8, enable_prefix_cache=False,
        degrade_after=2, restore_after=2))
    return InferenceEngine(cfg, params, role=role, clock=VirtualClock(),
                           **kw)


def _mk_req(rng, vocab):
    n = int(rng.integers(4, 13))
    return Request(prompt=list(map(int, rng.integers(1, vocab - 1, n))),
                   max_new_tokens=int(rng.integers(3, 7)))


def _check_engine(eng, prev_level):
    """Per-operation structural invariants of one paged engine."""
    sch, bp = eng.scheduler, eng.slots.bp
    assert bp.num_free + bp.num_used == bp.num_blocks - 1   # null block
    assert bp.peak_used >= bp.num_used
    assert set(sch.pending) <= set(eng.running)
    assert set(sch._admit_order) == set(eng.running)
    for slot in eng.running:
        assert eng.slots.lengths[slot] <= eng.capacity
    lvl = sch.degrade_level
    assert 0 <= lvl <= 2
    assert abs(lvl - prev_level) <= 1        # one rung at a time
    return lvl


def _check_streams(shadow):
    """Shadow token-stream invariants: append-only (nothing lost, no
    position re-emitted) and bounded by the request's budget."""
    for ent in shadow:
        req, seen = ent
        g = list(req.generated)
        assert g[:len(seen)] == seen
        assert len(g) <= req.max_new_tokens
        ent[1] = g


# ----------------------------------------------------------- unified sim
UNI_OPS = ["submit", "step", "step", "step", "preempt", "crash"]


@settings(max_examples=5, deadline=None)
@given(ops=st.lists(st.sampled_from(UNI_OPS), min_size=8, max_size=22),
       seed=st.integers(min_value=0, max_value=2**16))
def test_unified_lifecycle_invariants(served, ops, seed):
    cfg, params = served
    rng = np.random.default_rng(seed)
    eng = _engine(cfg, params)
    shadow, lvl = [], 0
    for op in ops:
        if op == "submit":
            req = _mk_req(rng, cfg.vocab_size)
            eng.submit(req)
            shadow.append([req, []])
        elif op == "step" and eng.num_active:
            eng.step()
        elif op == "preempt" and eng.running:
            eng.scheduler._preempt_latest()
        elif op == "crash":
            evac = eng.crash()
            eng.recover()
            for r in evac:           # resubmit folded, token-exact
                eng.submit(r)
        lvl = _check_engine(eng, lvl)
        _check_streams(shadow)
    eng.run_until_idle()
    _check_streams(shadow)
    assert eng.scheduler.drained()
    assert not eng.scheduler.pending and not eng.scheduler._admit_order
    for req, _ in shadow:
        assert req.done
        assert len(req.generated) == req.max_new_tokens
    # prefix cache is off: a drained engine holds zero pool blocks
    assert eng.slots.bp.num_used == 0
    assert eng.metrics.summary()["rejected"] == 0


# ------------------------------------------------------------ disagg sim
def _locations(pre, dec):
    """id -> occurrence count across every place a request can live."""
    c = {}

    def add(r):
        c[id(r)] = c.get(id(r), 0) + 1
    for r in pre.queue:
        add(r)
    for r in pre.running.values():
        add(r)
    for r, _ in pre.outbox:
        add(r)
    for r in dec.queue:
        add(r)
    for r, _ in dec.handoffs:
        add(r)
    for r in dec.running.values():
        add(r)
    return c


DIS_OPS = ["submit", "pstep", "pstep", "move", "dstep", "dstep",
           "preempt", "dcrash"]


@settings(max_examples=4, deadline=None)
@given(ops=st.lists(st.sampled_from(DIS_OPS), min_size=10, max_size=24),
       seed=st.integers(min_value=0, max_value=2**16))
def test_disagg_lifecycle_conservation_and_identity(served, ops, seed):
    cfg, params = served
    rng = np.random.default_rng(seed)
    pre = _engine(cfg, params, role="prefill")
    dec = _engine(cfg, params, role="decode")
    shadow, plvl, dlvl = [], 0, 0
    for op in ops:
        if op == "submit" and len(shadow) < 4:
            req = _mk_req(rng, cfg.vocab_size)
            pre.submit(req)
            shadow.append([req, []])
        elif op == "pstep" and pre.num_active:
            pre.step()
        elif op == "move" and pre.outbox:
            dec.submit_handoff(*pre.outbox.popleft())
        elif op == "dstep" and dec.num_active:
            dec.step()
        elif op == "preempt" and dec.running:
            dec.scheduler._preempt_latest()
        elif op == "dcrash":
            evac = dec.crash()
            dec.recover()
            for r in evac:
                # an evacuated decode request lost its pool KV: it goes
                # back for a fresh prefill of the folded prompt
                pre.submit(r)
        plvl = _check_engine(pre, plvl)
        dlvl = _check_engine(dec, dlvl)
        _check_streams(shadow)
        locs = _locations(pre, dec)
        for req, _ in shadow:
            expect = 0 if req.done else 1
            assert locs.get(id(req), 0) == expect   # conservation
    # drain the pipeline: prefill -> move -> decode until everyone done
    for _ in range(500):
        if all(r.done for r, _ in shadow):
            break
        if pre.num_active:
            pre.step()
        while pre.outbox:
            dec.submit_handoff(*pre.outbox.popleft())
        if dec.num_active:
            dec.step()
        _check_streams(shadow)
    assert all(r.done for r, _ in shadow)
    for req, _ in shadow:
        assert len(req.generated) == req.max_new_tokens
    # and the whole scrambled lifecycle is token-identical to a fresh
    # unified engine running the original prompts
    uni = _engine(cfg, params)
    refs = [Request(prompt=list(r.prompt[:len(r.prompt) - r.n_folded]),
                    max_new_tokens=r.max_new_tokens) for r, _ in shadow]
    for r in refs:
        uni.submit(r)
    uni.run_until_idle()
    assert [list(r.generated) for r, _ in shadow] == \
        [list(r.generated) for r in refs]
    assert pre.slots.bp.num_used == 0 and dec.slots.bp.num_used == 0


# ------------------------------------------------- degradation ladder
def test_degrade_ladder_down_and_restore(served):
    """Sustained pressure walks the ladder down one rung at a time (1 =
    speculation off, 2 = admission paused); sustained calm walks it back
    up — never skipping a level in either direction."""
    cfg, params = served
    eng = _engine(cfg, params)
    sch = eng.scheduler
    seen = [0]
    # synthetic pressure: two events per tick with degrade_after=2
    for _ in range(4):
        sch._tick_pressure = 2
        sch._degrade_update()
        seen.append(sch.degrade_level)
    assert max(seen) == 2 and sch.degrade_level == 2
    for _ in range(6):
        sch._degrade_update()                # calm ticks
        seen.append(sch.degrade_level)
    assert sch.degrade_level == 0
    assert all(abs(b - a) <= 1 for a, b in zip(seen, seen[1:]))
    # one descent then one recovery: the level sequence is unimodal
    peak = seen.index(max(seen))
    assert seen[:peak + 1] == sorted(seen[:peak + 1])
    assert seen[peak:] == sorted(seen[peak:], reverse=True)
