"""Core lifecycle: cluster, planes, bridge, elastic, gateway, HA,
registry — the paper's §4/§6 behaviours."""
import itertools

import jax
import jax.numpy as jnp
import pytest

from repro.core.bridge import PlaneBridge
from repro.core.cluster import Cluster, NodeKind, NodeState
from repro.core.elastic import ElasticController, ElasticPolicy
from repro.core.gateway import (Gateway, ModelEntry, OverBudget, RateLimited,
                                Unauthorized)
from repro.core.ha import ClusterMesh, Site, SplitBrainError
from repro.core.planes import (BatchJob, BatchPlane, DeploymentSpec,
                               JobState, ServicePlane)
from repro.core.registry import ArtifactRegistry, RetentionPolicy
from repro.configs import get_config


def mk_cluster(hpc=6, vm=2):
    c = Cluster()
    c.add_nodes("nid", hpc, NodeKind.HPC)
    c.add_nodes("vm", vm, NodeKind.COMMODITY)
    return c


# ------------------------------------------------------------ cluster
def test_diskless_semantics():
    c = mk_cluster()
    n = c.attach("nid0000", NodeState.BATCH)
    n.ephemeral["scratch"] = "model-weights"
    c.detach("nid0000")
    assert n.ephemeral == {}                  # state gone on detach
    c.fail("nid0001")
    c.nodes["nid0001"].reboot()
    assert c.nodes["nid0001"].state == NodeState.FREE


# ------------------------------------------------------------ batch plane
def test_batch_gang_scheduling_and_requeue():
    c = mk_cluster(hpc=4)
    bp = BatchPlane(c)
    calls = []

    def flaky(job):
        calls.append(job.requeues)
        if job.requeues == 0:
            raise RuntimeError("node failure mid-step")
        return "done"

    jid = bp.submit(BatchJob("pretrain", nodes_needed=4, run_fn=flaky))
    bp.tick()      # fails, requeued
    assert bp.jobs[jid].state == JobState.PENDING
    bp.tick()      # restart succeeds (checkpoint/restart semantics)
    assert bp.jobs[jid].state == JobState.DONE
    assert calls == [0, 1]
    assert len(c.free_nodes(NodeKind.HPC)) == 4   # nodes released


def test_batch_priority_order():
    c = mk_cluster(hpc=2)
    bp = BatchPlane(c)
    order = []
    j1 = bp.submit(BatchJob("low", 2, lambda j: order.append("low"),
                            priority=0))
    j2 = bp.submit(BatchJob("high", 2, lambda j: order.append("high"),
                            priority=10))
    bp.tick()
    bp.tick()
    assert order == ["high", "low"]


# ------------------------------------------------------------ service plane
def test_service_reconcile_and_failover():
    c = mk_cluster(hpc=3, vm=2)
    sp = ServicePlane(c)
    made = []
    sp.apply(DeploymentSpec("llm", replicas=2, node_selector=NodeKind.HPC,
                            factory=lambda node: made.append(node) or node))
    sp.reconcile()
    assert len(sp.endpoints("llm")) == 2
    victim = sp.endpoints("llm")[0].node
    sp.handle_node_failure(victim)
    assert len(sp.endpoints("llm")) == 1
    sp.reconcile()                            # reschedules onto a free node
    assert len(sp.endpoints("llm")) == 2
    assert all(r.node != victim for r in sp.endpoints("llm"))


def test_commodity_services_survive_hpc_failure():
    """Paper §5.3.1: control plane on VMs is unaffected by HPC downtime."""
    c = mk_cluster(hpc=2, vm=2)
    sp = ServicePlane(c)
    sp.apply(DeploymentSpec("ui", 1, NodeKind.COMMODITY))
    sp.apply(DeploymentSpec("llm", 2, NodeKind.HPC))
    sp.reconcile()
    for n in list(c.nodes_in(NodeState.SERVICE, NodeKind.HPC)):
        sp.handle_node_failure(n.name)
    assert len(sp.endpoints("llm")) == 0
    assert len(sp.endpoints("ui")) == 1       # still up
    # HPC nodes return after maintenance; deployment recovers (pending->up)
    for name in ("nid0000", "nid0001"):
        c.nodes[name].reboot()
    sp.reconcile()
    assert len(sp.endpoints("llm")) == 2


def test_rolling_update_replaces_version():
    c = mk_cluster(hpc=3)
    sp = ServicePlane(c)
    sp.apply(DeploymentSpec("llm", 2, NodeKind.HPC, factory=lambda n: n))
    sp.reconcile()
    sp.rolling_update("llm")
    sp.reconcile()
    assert all(r.version == 2 for r in sp.endpoints("llm"))


# ------------------------------------------------------------ bridge
def test_bridge_catalog_enforcement():
    c = mk_cluster(hpc=2)
    bp = BatchPlane(c)
    br = PlaneBridge(bp, recipe_runner=lambda s, p, j: f"ran {s}",
                     allowed_scripts=["sft_lora_safe"])
    resp = br.submit(script="sft_lora_safe", params={"rank": 8}, nodes=1)
    bp.tick()
    assert br.status(resp.job_id)["state"] == "done"
    assert br.result(resp.job_id) == "ran sft_lora_safe"
    with pytest.raises(PermissionError):
        br.submit(script="rm_rf_slash", params={}, nodes=1)
    assert br.audit_log[-1]["action"] == "rejected"


# ------------------------------------------------------------ elastic
def test_elastic_scale_out_and_in():
    c = mk_cluster(hpc=5)
    sp = ServicePlane(c)
    sp.apply(DeploymentSpec("llm", 1, NodeKind.HPC, factory=lambda n: n))
    sp.reconcile()
    load = {"queue": 50.0, "active": 4.0, "capacity": 4.0}
    ec = ElasticController(c, sp, "llm",
                           ElasticPolicy(patience=2, max_replicas=4),
                           lambda: dict(load))
    for _ in range(4):
        ec.tick()
    assert len(sp.endpoints("llm")) >= 2      # scaled out under pressure
    load.update(queue=0.0, active=0.0)
    for _ in range(6):
        ec.tick()
    assert len(sp.endpoints("llm")) == 1      # returned to baseline


# ------------------------------------------------------------ gateway
def test_gateway_governance(tiny_cfg, tiny_params):
    from repro.serving.engine import InferenceEngine
    t = itertools.count()
    gw = Gateway(clock=lambda: float(next(t)) * 0.01)
    eng = InferenceEngine(tiny_cfg, tiny_params, max_batch=2, capacity=64)
    entry = gw.vet_model(ModelEntry("tiny", "qwen1.5-4b", 0.5, 1.5),
                         tiny_cfg)
    assert entry.vetted and entry.footprint_gb > 0
    gw.bind_endpoints("tiny", [eng])
    key = gw.mint_key("swiss-ai", budget_usd=0.05, rate_limit_per_min=5)

    out = gw.completion(api_key=key.key, model="tiny", prompt=[1, 2, 3],
                        max_tokens=4)
    assert len(out["tokens"]) == 4
    assert key.spent_usd > 0

    with pytest.raises(Unauthorized):
        gw.completion(api_key="sk-bogus", model="tiny", prompt=[1])
    with pytest.raises(Unauthorized):
        gw.completion(api_key=key.key, model="nope", prompt=[1])

    # budget exhaustion
    key.spent_usd = key.budget_usd
    with pytest.raises(OverBudget):
        gw.completion(api_key=key.key, model="tiny", prompt=[1])
    key.spent_usd = 0.0

    # rate limiting
    for _ in range(4):
        gw.completion(api_key=key.key, model="tiny", prompt=[1, 2],
                      max_tokens=1)
    with pytest.raises(RateLimited):
        gw.completion(api_key=key.key, model="tiny", prompt=[1, 2],
                      max_tokens=1)

    usage = gw.usage_by_project()["swiss-ai"]
    assert usage["requests"] == 5
    assert usage["completion_tokens"] == 8


def test_gateway_hot_model_needs_failover_capacity(tiny_cfg):
    gw = Gateway()
    from repro.core.gateway import GatewayError
    with pytest.raises(GatewayError):
        gw.vet_model(ModelEntry("hot", "x", 1, 1, hot=True), tiny_cfg,
                     reserved_failover_gb=0.0)


# ------------------------------------------------------------ HA
class _Ep:
    def __init__(self, name):
        self.name = name
        self.healthy = True
        self.num_active = 0


def test_ha_failover_and_split_brain():
    a = Site("lugano", [_Ep("a1"), _Ep("a2")])
    b = Site("geneva", [_Ep("b1")])
    mesh = ClusterMesh([a, b])
    site, _ = mesh.route(prefer="lugano")
    assert site.name == "lugano"
    mesh.partition("lugano")
    site, _ = mesh.route(prefer="lugano")     # near-real-time failover
    assert site.name == "geneva"
    with pytest.raises(SplitBrainError):      # partitioned writes fenced
        mesh.propose_config("lugano")
    mesh.propose_config("geneva")             # healthy site advances epoch
    # healing re-syncs the epoch; writes accepted again
    mesh.heal("lugano")
    mesh.propose_config("lugano")


def test_ha_stale_epoch_fenced():
    a = Site("s1", [_Ep("e")])
    b = Site("s2", [_Ep("e")])
    mesh = ClusterMesh([a, b])
    mesh.partition("s2")
    mesh.propose_config("s1")
    # s2 heals but pretend it skipped re-sync: emulate stale epoch
    mesh.sites["s2"].partitioned = False
    with pytest.raises(SplitBrainError):
        mesh.propose_config("s2")


# ------------------------------------------------------------ registry
def test_registry_lineage_and_gc():
    t = itertools.count()
    reg = ArtifactRegistry(clock=lambda: float(next(t)) * 86400.0)
    ds = reg.register("dataset", "s3://corpus-v1", size_bytes=100)
    ck1 = reg.register("checkpoint", "ckpt/step1", parents=[ds.artifact_id],
                       size_bytes=1000)
    ck2 = reg.register("checkpoint", "ckpt/step2", parents=[ck1.artifact_id],
                       size_bytes=1000)
    model = reg.register("model", "release/v1", parents=[ck2.artifact_id],
                         pinned=True, size_bytes=500)
    lin = [a.artifact_id for a in reg.lineage(model.artifact_id)]
    assert lin == [ds.artifact_id, ck1.artifact_id, ck2.artifact_id]

    # checkpoints age out, but pinned descendants & keep-last protect some
    for _ in range(20):
        next(t)
    pol = RetentionPolicy(max_age_s={"checkpoint": 5 * 86400.0},
                          keep_last_per_kind=1)
    collectible = {a.artifact_id for a in reg.collectible(pol)}
    assert ck1.artifact_id in collectible     # old, replaced, not pinned
    assert model.artifact_id not in collectible
    freed = reg.gc(pol)
    assert freed >= 1000
