"""Byte tokenizer roundtrip properties."""
from hypothesis import given, settings, strategies as st

from repro.data.tokenizer import ByteTokenizer


@settings(max_examples=25, deadline=None)
@given(text=st.text(max_size=200))
def test_roundtrip(text):
    tok = ByteTokenizer()
    ids = tok.encode(text, bos=True, eos=True)
    assert ids[0] == tok.BOS and ids[-1] == tok.EOS
    assert all(0 <= i < tok.vocab_size for i in ids)
    assert tok.decode(ids) == text
