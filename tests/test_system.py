"""End-to-end behaviour: the full lifecycle (pretrain -> SFT -> DPO ->
eval gates -> release -> deploy -> serve through gateway) on a tiny model,
plus a subprocess dry-run on a small fake-device mesh (the 512-device
production dry-run runs via ``repro.launch.dryrun``)."""
import itertools
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, scaled_down
from repro.core.cluster import Cluster, NodeKind, NodeState
from repro.core.gateway import Gateway, ModelEntry
from repro.core.lifecycle import LifecycleError, LifecyclePipeline, Stage, StageResult
from repro.core.planes import BatchJob, BatchPlane, DeploymentSpec, ServicePlane
from repro.core.registry import ArtifactRegistry
from repro.data.pipeline import DataConfig, PreferenceDataset, SFTDataset, SyntheticLM
from repro.finetune.evals import CapabilityGuard, evaluate
from repro.finetune.lora import lora_init, lora_merge
from repro.finetune.recipes import resolve
from repro.finetune.quantize import dequantize_tree, quantize_tree
from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request
from repro.training.optimizer import opt_init
from repro.training.trainer import Trainer, TrainerConfig
from repro.training.optimizer import OptConfig


def test_full_lifecycle(tmp_path, tiny_cfg):
    cfg = tiny_cfg
    registry = ArtifactRegistry()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    base_data = SyntheticLM(dc)
    # gate-tolerance bound: a 20-step lr=3e-3 LoRA SFT on this tiny
    # model legitimately shifts base-capability perplexity by up to
    # ~0.8 across jax versions (measured 0.60 on jax 0.4.37 vs ~0.4 on
    # CI's jax), so 0.5 flapped.  1.5 still fails hard breakage — the
    # deliberately-broken model in test_finetune regresses by >> 1.5 —
    # while letting a healthy SFT run through the gate deterministically.
    guard = CapabilityGuard(cfg, base_data, tolerance=1.5, steps=2)

    def stage_pretrain(ctx):
        ctx.register("data", "dataset", "synthetic-bigram-v1")
        tr = Trainer(cfg, OptConfig(lr=1e-2), base_data,
                     TrainerConfig(num_steps=30, ckpt_every=10,
                                   ckpt_dir=str(tmp_path / "pt"),
                                   log_every=10))
        res = tr.run()
        ctx.state["base_params"] = tr.params
        guard.snapshot(tr.params)
        aid = ctx.register("pretrain", "checkpoint", str(tmp_path / "pt"),
                           parent_stages=["data"])
        loss0, loss1 = res["log"][0]["loss"], res["log"][-1]["loss"]
        return StageResult("pretrain", aid,
                           {"loss0": loss0, "loss1": loss1},
                           passed=loss1 < loss0)

    def stage_sft(ctx):
        base = ctx.state["base_params"]
        _, lcfg, opt, extra = resolve("sft_lora_safe", cfg, {"lr": 3e-4})
        import dataclasses
        opt = dataclasses.replace(opt, lr=3e-3)  # tiny-model scale
        from repro.finetune.sft import make_lora_sft_step
        ad = lora_init(base, lcfg, jax.random.PRNGKey(1))
        step = jax.jit(make_lora_sft_step(cfg, opt, base, lcfg))
        st = opt_init(opt, ad)
        sft_data = SFTDataset(dc, prompt_len=8)
        first = last = None
        for i in range(20):
            b = {k: jnp.asarray(v) for k, v in sft_data.batch(i).items()}
            ad, st, m = step(ad, st, b)
            first = first if first is not None else float(m["loss"])
            last = float(m["loss"])
        ctx.state["lcfg"] = lcfg
        ctx.state["sft_params"] = lora_merge(base, ad, lcfg)
        aid = ctx.register("sft", "adapter", "adapters/sft",
                           parent_stages=["pretrain"])
        return StageResult("sft", aid, {"first": first, "last": last},
                           passed=last < first)

    def stage_eval(ctx):
        check = guard.check(ctx.state["sft_params"])
        aid = ctx.register("eval", "eval", "evals/guard",
                           parent_stages=["sft"])
        return StageResult("eval", aid, check, passed=check["passed"])

    def stage_release(ctx):
        q = quantize_tree(ctx.state["sft_params"])
        ctx.state["released"] = dequantize_tree(q, jnp.float32)
        aid = ctx.register("release", "model", "release/tiny-v1",
                           parent_stages=["sft", "eval"])
        ctx.registry.pin(aid)
        return StageResult("release", aid, {}, passed=True)

    def stage_deploy(ctx):
        cluster = Cluster()
        cluster.add_nodes("nid", 2, NodeKind.HPC)
        sp = ServicePlane(cluster)
        engines = []

        def factory(node):
            e = InferenceEngine(cfg, ctx.state["released"], max_batch=2,
                                capacity=64, name=f"eng-{node}")
            engines.append(e)
            return e

        sp.apply(DeploymentSpec("tiny", 1, NodeKind.HPC, factory=factory))
        sp.reconcile()
        gw = Gateway()
        gw.vet_model(ModelEntry("tiny", cfg.name, 0.1, 0.3), cfg)
        gw.bind_endpoints("tiny", engines)
        key = gw.mint_key("pilot", budget_usd=1.0)
        out = gw.completion(api_key=key.key, model="tiny",
                            prompt=[3, 5, 7], max_tokens=6)
        ctx.state["served_tokens"] = out["tokens"]
        aid = ctx.register("deploy", "model", "endpoints/tiny",
                           parent_stages=["release"])
        return StageResult("deploy", aid,
                           {"tokens": len(out["tokens"])},
                           passed=len(out["tokens"]) == 6)

    pipe = LifecyclePipeline(
        [Stage("pretrain", stage_pretrain), Stage("sft", stage_sft),
         Stage("eval", stage_eval), Stage("release", stage_release),
         Stage("deploy", stage_deploy)], registry)
    history = pipe.run()
    assert all(h.passed for h in history)
    # provenance: deployment traces back to the dataset
    deploy_id = pipe.ctx.artifacts["deploy"]
    lineage_kinds = [a.kind for a in registry.lineage(deploy_id)]
    assert "dataset" in lineage_kinds and "checkpoint" in lineage_kinds


@pytest.mark.xfail(
    tuple(int(x) for x in jax.__version__.split(".")[:2]) < (0, 5),
    reason="jax<0.5: no jax.shard_map / fake-device flag spelling "
           "differs (README: known version failures)", strict=False)
def test_small_mesh_dryrun_subprocess():
    """A reduced MoE config must lower+compile on a fake 2x2 mesh with the
    production sharding rules — validates the dry-run machinery itself
    (EP shard_map all-to-all included) without the 512-device cost."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, scaled_down, ShapeSpec
from repro.models import model as M
from repro.models.param import abstract_params, param_axes
from repro.parallel import sharding as sh
from repro.launch import hlo_analysis
from repro.training.optimizer import OptConfig, opt_init, opt_state_axes
from repro.training.train_step import make_train_step

cfg = scaled_down(get_config("granite-moe-3b-a800m"),
                  num_experts=8, moe_top_k=2, vocab_size=512)
mesh = jax.make_mesh((2, 2), ("data", "model"))
rules = sh.make_rules("train")
shape = ShapeSpec("tiny_train", 64, 8, "train")
axes = param_axes(M.model_specs(cfg))
p_sh = sh.tree_shardings(axes, mesh, rules)
p_abs = abstract_params(M.model_specs(cfg), jnp.float32)
opt_cfg = OptConfig()
opt_abs = jax.eval_shape(lambda p: opt_init(opt_cfg, p), p_abs)
o_sh = sh.tree_shardings(opt_state_axes(opt_cfg, axes), mesh, rules)
b_sh = sh.tree_shardings(M.input_axes(cfg, shape), mesh, rules)
step = make_train_step(cfg, opt_cfg)
def wrapped(p, o, b):
    with sh.use_rules(mesh, rules):
        return step(p, o, b)
jf = jax.jit(wrapped, in_shardings=(p_sh, o_sh, b_sh),
             out_shardings=(p_sh, o_sh, NamedSharding(mesh, P())))
compiled = jf.lower(p_abs, opt_abs, M.input_specs(cfg, shape)).compile()
res = hlo_analysis.analyze(compiled.as_text(), mesh.size)
assert res["flops"] > 0, "walker found no dots"
assert res["by_collective"]["all-to-all"] > 0, "EP a2a missing from HLO"
print("SMALL-MESH-DRYRUN-OK", int(res["flops"]),
      int(res["collective_wire_bytes"]))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "SMALL-MESH-DRYRUN-OK" in out.stdout, out.stderr[-3000:]
