"""Serving: engine exactness under continuous batching, admission
control, metrics; sampling properties."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request
from repro.serving.kvcache import BlockLedger
from repro.serving.sampling import sample


@pytest.fixture(scope="module")
def served(tiny_cfg):
    params = M.init(tiny_cfg, jax.random.PRNGKey(0))
    return tiny_cfg, params


def _ref_generate(cfg, params, prompt, n, cap=128):
    b = {"tokens": jnp.asarray([prompt], jnp.int32),
         "prompt_lengths": jnp.asarray([len(prompt)], jnp.int32)}
    logits, cache, _ = M.prefill(cfg, params, b)
    cache = M.pad_cache(cfg, cache, cap)
    out = [int(jnp.argmax(logits[0]))]
    lengths = jnp.asarray([len(prompt)], jnp.int32)
    for _ in range(n - 1):
        lengths = lengths + 1
        logits, cache = M.decode_step(
            cfg, params, jnp.asarray([[out[-1]]], jnp.int32), cache, lengths)
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_engine_matches_reference(served):
    cfg, params = served
    eng = InferenceEngine(cfg, params, max_batch=3, capacity=128)
    prompts = [[5, 6, 7, 8], [9, 10, 11], [3, 1, 4, 1, 5], [42, 17]]
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    for p, r in zip(prompts, reqs):
        assert r.generated == _ref_generate(cfg, params, p, 6), p


def test_engine_metrics(served):
    cfg, params = served
    t = itertools.count()
    eng = InferenceEngine(cfg, params, max_batch=2, capacity=64,
                          clock=lambda: float(next(t)))
    for p in ([1, 2, 3], [4, 5]):
        eng.submit(Request(prompt=p, max_new_tokens=4))
    s = eng.run_until_idle()
    assert s["completed"] == 2
    assert s["generated_tokens"] == 8
    assert s["ttft_p50_s"] > 0
    assert s["itl_mean_s"] > 0
    assert s["e2el_mean_s"] >= s["ttft_p50_s"]


def test_engine_eos_stops(served):
    cfg, params = served
    eng = InferenceEngine(cfg, params, max_batch=1, capacity=64)
    ref = _ref_generate(cfg, params, [5, 6, 7], 8)
    eos = ref[2]
    req = Request(prompt=[5, 6, 7], max_new_tokens=8, eos_id=eos)
    eng.submit(req)
    eng.run_until_idle()
    assert req.generated == ref[:3]          # stops at first eos


def test_engine_rejects_overlong(served):
    cfg, params = served
    eng = InferenceEngine(cfg, params, max_batch=1, capacity=32)
    req = Request(prompt=list(range(1, 30)), max_new_tokens=16)
    eng.submit(req)
    eng.run_until_idle()
    assert req.done and req.generated == []  # capacity-rejected


def test_block_ledger_admission():
    led = BlockLedger(capacity_tokens=256, block_size=64)  # 4 blocks
    assert led.can_admit("a", 100)           # 2 blocks
    led.admit("a", 100)
    led.admit("b", 128)                      # 2 blocks
    assert not led.can_admit("c", 10)        # full
    led.release("a")
    assert led.can_admit("c", 10)


@settings(max_examples=10, deadline=None)
@given(n_req=st.integers(1, 6), max_batch=st.integers(1, 3),
       n_new=st.integers(1, 4))
def test_engine_always_drains(served, n_req, max_batch, n_new):
    cfg, params = served
    eng = InferenceEngine(cfg, params, max_batch=max_batch, capacity=64)
    reqs = [Request(prompt=[i + 1, i + 2], max_new_tokens=n_new)
            for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    s = eng.run_until_idle()
    assert s["completed"] == n_req
    assert all(len(r.generated) == n_new for r in reqs)
    assert not eng.slots.slot_owner          # all slots returned
    assert eng.ledger.free_blocks == eng.ledger.total_blocks


# ------------------------------------------------------------ sampling
def test_sampling_greedy_and_topk():
    logits = jnp.asarray([[1.0, 3.0, 2.0, -1.0]])
    key = jax.random.PRNGKey(0)
    assert int(sample(logits, key)[0]) == 1
    for s in range(20):
        t = int(sample(logits, jax.random.PRNGKey(s), temperature=1.0,
                       top_k=2)[0])
        assert t in (1, 2)


@settings(max_examples=10, deadline=None)
@given(top_p=st.floats(0.05, 0.95))
def test_sampling_top_p_excludes_tail(top_p):
    # one dominant token: low top_p must always pick it
    logits = jnp.asarray([[10.0, 0.0, 0.0, 0.0]])
    t = int(sample(logits, jax.random.PRNGKey(1), temperature=1.0,
                   top_p=top_p)[0])
    assert t == 0
