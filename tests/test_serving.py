"""Serving: engine exactness under continuous batching, admission
control, metrics; sampling properties."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request
from repro.serving.kvcache import BlockLedger
from repro.serving.sampling import sample


@pytest.fixture(scope="module")
def served(tiny_cfg):
    params = M.init(tiny_cfg, jax.random.PRNGKey(0))
    return tiny_cfg, params


def _ref_generate(cfg, params, prompt, n, cap=128):
    b = {"tokens": jnp.asarray([prompt], jnp.int32),
         "prompt_lengths": jnp.asarray([len(prompt)], jnp.int32)}
    logits, cache, _ = M.prefill(cfg, params, b)
    cache = M.pad_cache(cfg, cache, cap)
    out = [int(jnp.argmax(logits[0]))]
    lengths = jnp.asarray([len(prompt)], jnp.int32)
    for _ in range(n - 1):
        lengths = lengths + 1
        logits, cache = M.decode_step(
            cfg, params, jnp.asarray([[out[-1]]], jnp.int32), cache, lengths)
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_engine_matches_reference(served):
    cfg, params = served
    eng = InferenceEngine(cfg, params, max_batch=3, capacity=128)
    prompts = [[5, 6, 7, 8], [9, 10, 11], [3, 1, 4, 1, 5], [42, 17]]
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    for p, r in zip(prompts, reqs):
        assert r.generated == _ref_generate(cfg, params, p, 6), p


def test_engine_metrics(served):
    cfg, params = served
    t = itertools.count()
    eng = InferenceEngine(cfg, params, max_batch=2, capacity=64,
                          clock=lambda: float(next(t)))
    for p in ([1, 2, 3], [4, 5]):
        eng.submit(Request(prompt=p, max_new_tokens=4))
    s = eng.run_until_idle()
    assert s["completed"] == 2
    assert s["generated_tokens"] == 8
    assert s["ttft_p50_s"] > 0
    assert s["itl_mean_s"] > 0
    assert s["e2el_mean_s"] >= s["ttft_p50_s"]


def test_engine_eos_stops(served):
    cfg, params = served
    eng = InferenceEngine(cfg, params, max_batch=1, capacity=64)
    ref = _ref_generate(cfg, params, [5, 6, 7], 8)
    eos = ref[2]
    req = Request(prompt=[5, 6, 7], max_new_tokens=8, eos_id=eos)
    eng.submit(req)
    eng.run_until_idle()
    assert req.generated == ref[:3]          # stops at first eos


def test_engine_rejects_overlong(served):
    cfg, params = served
    eng = InferenceEngine(cfg, params, max_batch=1, capacity=32)
    req = Request(prompt=list(range(1, 30)), max_new_tokens=16)
    eng.submit(req)
    s = eng.run_until_idle()
    assert req.done and req.generated == []  # capacity-rejected
    # an impossible request is an explicit rejection, not a silent finish
    assert s["rejected"] == 1 and s["completed"] == 0
    assert eng.metrics.requests[req.request_id].status == "rejected"


def test_rejection_does_not_pollute_latency_metrics(served):
    """summary() stays robust with a mix of rejected and served requests:
    rejects never enter TTFT/ITL/E2EL quantiles."""
    cfg, params = served
    t = itertools.count()
    eng = InferenceEngine(cfg, params, max_batch=1, capacity=64,
                          clock=lambda: float(next(t)))
    good = Request(prompt=[1, 2, 3], max_new_tokens=4)
    bad = Request(prompt=list(range(1, 80)), max_new_tokens=16)
    eng.submit(bad)
    eng.submit(good)
    s = eng.run_until_idle()
    assert s["rejected"] == 1 and s["completed"] == 1
    assert s["generated_tokens"] == 4
    assert s["ttft_p50_s"] > 0 and s["e2el_mean_s"] >= s["ttft_p50_s"]


def test_admit_tick_still_decodes(served):
    """Regression for the old admit/decode coupling: a tick that admits a
    queued request must still decode the running batch (a deep queue used
    to stall every running request)."""
    cfg, params = served
    eng = InferenceEngine(cfg, params, max_batch=1, capacity=64)
    r1 = Request(prompt=[5, 6, 7], max_new_tokens=8)
    r2 = Request(prompt=[9, 10], max_new_tokens=2)
    eng.submit(r1)
    eng.step()                  # admits r1 (prefill token) + decodes
    assert len(r1.generated) == 2
    eng.submit(r2)              # r2 queues behind r1 (single slot)
    n = len(r1.generated)
    eng.step()                  # r2 cannot be admitted; r1 still decodes
    assert len(r1.generated) == n + 1


def test_block_ledger_admission():
    led = BlockLedger(capacity_tokens=256, block_size=64)  # 4 blocks
    assert led.can_admit("a", 100)           # 2 blocks
    led.admit("a", 100)
    led.admit("b", 128)                      # 2 blocks
    assert not led.can_admit("c", 10)        # full
    led.release("a")
    assert led.can_admit("c", 10)


def test_block_ledger_readmission_idempotent():
    """can_admit/admit are rid-aware: blocks a request already holds count
    toward its own allowance, so re-admitting the same rid never
    double-charges the pool."""
    led = BlockLedger(capacity_tokens=256, block_size=64)  # 4 blocks
    led.admit("a", 128)                      # 2 blocks
    led.admit("b", 128)                      # 2 blocks -> pool full
    assert not led.can_admit("c", 10)
    assert led.can_admit("a", 128)           # same footprint: idempotent
    assert led.can_admit("a", 100)           # shrink: fine
    led.admit("a", 100)
    assert led.free_blocks == 0              # still 2+2 blocks held
    assert not led.can_admit("a", 200)       # growth beyond pool refused


@settings(max_examples=10, deadline=None)
@given(n_req=st.integers(1, 6), max_batch=st.integers(1, 3),
       n_new=st.integers(1, 4))
def test_engine_always_drains(served, n_req, max_batch, n_new):
    cfg, params = served
    eng = InferenceEngine(cfg, params, max_batch=max_batch, capacity=64)
    reqs = [Request(prompt=[i + 1, i + 2], max_new_tokens=n_new)
            for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    s = eng.run_until_idle()
    assert s["completed"] == n_req
    assert all(len(r.generated) == n_new for r in reqs)
    assert not eng.slots.slot_owner          # all slots returned
    assert eng.ledger.free_blocks == eng.ledger.total_blocks


# ------------------------------------------------------------ sampling
def test_sampling_greedy_and_topk():
    logits = jnp.asarray([[1.0, 3.0, 2.0, -1.0]])
    key = jax.random.PRNGKey(0)
    assert int(sample(logits, key)[0]) == 1
    for s in range(20):
        t = int(sample(logits, jax.random.PRNGKey(s), temperature=1.0,
                       top_k=2)[0])
        assert t in (1, 2)


@settings(max_examples=10, deadline=None)
@given(top_p=st.floats(0.05, 0.95))
def test_sampling_top_p_excludes_tail(top_p):
    # one dominant token: low top_p must always pick it
    logits = jnp.asarray([[10.0, 0.0, 0.0, 0.0]])
    t = int(sample(logits, jax.random.PRNGKey(1), temperature=1.0,
                   top_p=top_p)[0])
    assert t == 0
