"""Gradient compression + error feedback properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.parallel.compression import (compress_with_feedback, ef_init,
                                        wire_bytes)

# jax < 0.5 (e.g. the 0.4.37 container pin) emits different HLO text /
# lacks the new shard_map spelling; see README "Known
# jax-version-dependent failures".  strict=False: current-jax CI still
# runs (and must pass) these.
OLD_JAX = tuple(int(x) for x in jax.__version__.split(".")[:2]) < (0, 5)


def test_int8_roundtrip_bounded_error():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (256, 64))}
    ef = ef_init(g)
    r, ef = compress_with_feedback(g, ef, bits=8)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    err = float(jnp.max(jnp.abs(r["w"] - g["w"])))
    assert err <= scale * 0.5 + 1e-6


def test_error_feedback_unbiased_over_steps():
    """With a CONSTANT gradient, error feedback makes the mean of the
    reconstructed gradients converge to the true gradient."""
    g = {"w": jnp.asarray([0.004, -0.3, 1.7, 0.011])}
    ef = ef_init(g)
    acc = jnp.zeros_like(g["w"])
    steps = 64
    for _ in range(steps):
        r, ef = compress_with_feedback(g, ef, bits=8)
        acc = acc + r["w"]
    mean = acc / steps
    np.testing.assert_allclose(np.asarray(mean), np.asarray(g["w"]),
                               rtol=0.02, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(bits=st.sampled_from([8, 16]), n=st.integers(8, 300))
def test_wire_bytes_shrink(bits, n):
    g = {"w": jnp.ones((n,), jnp.float32)}
    assert wire_bytes(g, bits) < n * 4 + 8


@pytest.mark.xfail(OLD_JAX, reason="jax<0.5: reduction-schedule HLO "
                   "text differs (README: known version failures)",
                   strict=False)
def test_reduction_schedules_agree():
    """All three schedules produce the same reduced gradients."""
    import os, subprocess, sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel import collectives as C

mesh = jax.make_mesh((8,), ("dp",))
gs = [jax.random.normal(jax.random.PRNGKey(i), (64 * 8,)) for i in range(3)]
outs = []
for fn in (lambda g: C.per_tensor_psum(g, "dp"),
           lambda g: C.bucketed_psum(g, "dp"),
           lambda g: C.rs_ag(g, "dp", pad_to=64)):
    f = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=(P("dp"),),
                              out_specs=P("dp")))
    outs.append(f(gs))
for o in outs[1:]:
    for a, b in zip(outs[0], o):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
print("SCHEDULES-AGREE")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SCHEDULES-AGREE" in out.stdout, out.stderr[-2000:]
