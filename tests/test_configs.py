"""Config registry: assigned geometries, param counts, applicability."""
import pytest

from repro.configs import SHAPES, applicable, get_config, list_archs
from repro.configs.all_archs import ASSIGNED, PAPER_OWN


def test_all_assigned_registered():
    archs = list_archs()
    for a in ASSIGNED + PAPER_OWN:
        assert a in archs


@pytest.mark.parametrize("arch,lo,hi", [
    ("yi-34b", 33e9, 36e9),
    ("qwen2.5-32b", 31e9, 34e9),
    ("qwen1.5-4b", 3.5e9, 4.5e9),
    ("glm4-9b", 8.5e9, 10.5e9),
    ("mamba2-1.3b", 1.1e9, 1.5e9),
    ("apertus-8b", 7.5e9, 8.6e9),
    ("apertus-70b", 68e9, 72e9),
    ("jamba-v0.1-52b", 49e9, 55e9),
    ("deepseek-v2-lite-16b", 14e9, 17e9),
    ("granite-moe-3b-a800m", 2.8e9, 3.8e9),
])
def test_param_counts_match_names(arch, lo, hi):
    n = get_config(arch).param_count()
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


@pytest.mark.parametrize("arch,lo,hi", [
    ("granite-moe-3b-a800m", 0.6e9, 1.0e9),     # ~800M active
    ("deepseek-v2-lite-16b", 2.0e9, 3.2e9),     # ~2.4B active
])
def test_moe_active_params(arch, lo, hi):
    n = get_config(arch).param_count(active_only=True)
    assert lo <= n <= hi, f"{arch} active: {n/1e9:.2f}B"


def test_vocab_padding_divides_mesh():
    for a in ASSIGNED:
        cfg = get_config(a)
        assert cfg.vocab_padded % 256 == 0
        assert cfg.vocab_padded >= cfg.vocab_size
        assert cfg.vocab_padded - cfg.vocab_size < 256


def test_long_500k_applicability():
    long = SHAPES["long_500k"]
    runs = [a for a in ASSIGNED if applicable(get_config(a), long)[0]]
    assert sorted(runs) == ["jamba-v0.1-52b", "mamba2-1.3b"]


def test_hybrid_layout():
    cfg = get_config("jamba-v0.1-52b")
    attn = cfg.attn_layer_ids()
    assert len(attn) == 4                      # 1:7 over 32 layers
    assert all(i % 8 == 4 for i in attn)
    moe = cfg.moe_layer_ids()
    assert len(moe) == 16                      # every 2nd layer
    assert all(i % 2 == 1 for i in moe)


def test_mla_cache_is_compressed():
    ds = get_config("deepseek-v2-lite-16b")
    gqa_equiv = 2 * 2 * 16 * 128               # if it were MHA-cached
    assert ds.kv_cache_bytes_per_token_per_layer == 2 * (512 + 64)
    assert ds.kv_cache_bytes_per_token_per_layer < gqa_equiv / 5
