"""Paged KV path of the serving engine: block pool accounting, token
exactness vs. the sequential reference (GQA and MLA), dense-fallback
gating, zero-copy prefix hits, preemption under pool pressure, the
BlockLedger.grow over-commit regression, and fused batched sampling."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, scaled_down
from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request
from repro.serving.kvcache import BlockLedger, BlockPool, PagedCacheSlots
from repro.serving.sampling import sample, sample_batched
from repro.serving.scheduler import SchedulerConfig


@pytest.fixture(scope="module")
def served(tiny_cfg):
    params = M.init(tiny_cfg, jax.random.PRNGKey(0))
    return tiny_cfg, params


def _ref_generate(cfg, params, prompt, n, cap=128):
    """Sequential reference with a bf16 KV cache — the engine's exact
    storage dtype, so comparisons are token-identical, not tolerance."""
    b = {"tokens": jnp.asarray([prompt], jnp.int32),
         "prompt_lengths": jnp.asarray([len(prompt)], jnp.int32)}
    logits, cache, _ = M.prefill(cfg, params, b)
    cache = jax.tree.map(lambda a: a.astype(jnp.bfloat16),
                         M.pad_cache(cfg, cache, cap))
    out = [int(jnp.argmax(logits[0]))]
    lengths = jnp.asarray([len(prompt)], jnp.int32)
    for _ in range(n - 1):
        lengths = lengths + 1
        logits, cache = M.decode_step(
            cfg, params, jnp.asarray([[out[-1]]], jnp.int32), cache, lengths)
        out.append(int(jnp.argmax(logits[0])))
    return out


def _engine(cfg, params, **kw):
    sched = kw.pop("sched", SchedulerConfig(prefix_block=4, prefill_chunk=8))
    kw.setdefault("max_batch", 3)
    kw.setdefault("capacity", 128)
    return InferenceEngine(cfg, params, sched=sched, **kw)


# ------------------------------------------------------------ block pool
def test_block_pool_alloc_refcount():
    bp = BlockPool(6)                       # ids 1..5 allocatable
    a = bp.alloc(3)
    assert sorted(a) == [1, 2, 3] and bp.num_free == 2
    assert bp.alloc(3) is None              # all-or-nothing
    assert bp.num_free == 2
    bp.incref([a[0]])
    assert bp.decref([a[0]]) == 0           # still shared
    assert bp.decref(a) == 3                # now all free
    assert bp.num_free == 5 and bp.peak_used == 3
    with pytest.raises(ValueError):
        bp.decref([1])                      # double free
    with pytest.raises(ValueError):
        bp.incref([4])                      # never allocated


def test_block_ledger_grow_never_overcommits():
    """Regression: grow() past the pool must raise, not silently hand out
    blocks that do not exist (the caller preempts or rejects instead)."""
    led = BlockLedger(capacity_tokens=256, block_size=64)   # 4 blocks
    led.admit("a", 128)                     # 2 blocks
    led.grow("a", 200)                      # 4 blocks: exactly fits
    assert led.free_blocks == 0
    with pytest.raises(RuntimeError):
        led.grow("a", 300)                  # 5 blocks > pool
    assert led.used["a"] == 4               # reservation unchanged
    led.admit("b", 1) if led.free_blocks else None
    with pytest.raises(RuntimeError):
        led.grow("missing-rid", 320)        # growth from zero, too big
    assert led.free_blocks == 0
    assert led.peak_blocks == 4
    led.release("a")
    led.grow("c", 64)                       # growth from zero that fits
    assert led.used["c"] == 1


def test_paged_slots_adopt_and_release(tiny_cfg):
    slots = PagedCacheSlots(tiny_cfg, max_batch=2, capacity=64,
                            block_size=16)
    s = slots.allocate("r0")
    assert slots.ensure_capacity(s, 20)     # 2 blocks
    ids = slots.block_ids(s)
    assert len(ids) == 2 and slots.tables[s, 0] == ids[0]
    # a second slot adopts the first block: refcount, not copy
    s2 = slots.allocate("r1")
    slots.adopt_prefix(s2, ids[:1], 16)
    assert slots.bp.refs[ids[0]] == 2
    slots.release(s)
    assert ids[0] in slots.bp.refs          # survives: s2 still holds it
    assert ids[1] not in slots.bp.refs      # private block freed
    slots.release(s2)
    assert slots.bp.num_used == 0
    assert not slots.slot_owner


# ------------------------------------------------------------ exactness
def test_paged_engine_matches_reference(served):
    cfg, params = served
    eng = _engine(cfg, params)
    assert eng.paged
    prompts = [[5, 6, 7, 8], [9, 10, 11], [3, 1, 4, 1, 5, 9, 2, 6],
               [42, 17]]
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    for p, r in zip(prompts, reqs):
        assert r.generated == _ref_generate(cfg, params, p, 6), p
    assert not eng.slots.slot_owner
    assert eng.slots.bp.num_used == eng.scheduler.prefix_cache.n_nodes


def test_paged_equals_dense_outputs(served):
    """The same request mix through paged and dense engines is
    token-identical (shared system prompt + disjoint tails)."""
    cfg, params = served
    rng = np.random.default_rng(5)
    system = list(map(int, rng.integers(1, 120, 12)))
    prompts = [system + list(map(int, rng.integers(1, 120, 4)))
               for _ in range(5)] + [[99, 98, 97]]
    outs = {}
    for paged in (True, False):
        eng = _engine(cfg, params, paged=paged)
        reqs = [Request(prompt=list(p), max_new_tokens=5, namespace="t")
                for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.run_until_idle()
        outs[paged] = [r.generated for r in reqs]
    assert outs[True] == outs[False]


def test_paged_mla_engine_matches_reference():
    """MLA caches (latent + rope leaves) page the same way."""
    cfg = scaled_down(get_config("deepseek-v2-lite-16b"), num_layers=2,
                      d_model=64, d_ff=128, vocab_size=128, num_heads=4)
    assert M.supports_paged_cache(cfg)
    params = M.init(cfg, jax.random.PRNGKey(1))
    eng = _engine(cfg, params, max_batch=2)
    assert eng.paged
    prompts = [[7, 3, 9, 1, 4], [2, 8, 6]]
    reqs = [Request(prompt=p, max_new_tokens=4) for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    for p, r in zip(prompts, reqs):
        assert r.generated == _ref_generate(cfg, params, p, 4), p


def test_dense_fallback_gating():
    """SSM has no position-sliceable KV: the engine silently falls back
    to dense slots and still serves."""
    cfg = get_config("mamba2-1.3b")
    assert not M.supports_paged_cache(cfg)
    cfg = scaled_down(cfg, num_layers=2, d_model=64, d_ff=128,
                      vocab_size=128)
    params = M.init(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, max_batch=2, capacity=64)
    assert not eng.paged
    req = Request(prompt=[5, 6, 7], max_new_tokens=3)
    eng.submit(req)
    s = eng.run_until_idle()
    assert s["completed"] == 1 and len(req.generated) == 3
    with pytest.raises(ValueError):
        InferenceEngine(cfg, params, max_batch=2, capacity=64, paged=True)


# ------------------------------------------------------------ zero copy
def test_prefix_hit_is_copy_free(served, monkeypatch):
    """A paged prefix hit must move zero KV bytes: no prefill scatter, no
    segment gather — just a refcount bump + block-table splice."""
    cfg, params = served
    eng = _engine(cfg, params)
    sys_p = [7, 3, 9, 1, 4, 4, 2, 8]                  # 2 whole blocks of 4
    r1 = Request(prompt=sys_p + [20, 21], max_new_tokens=3, namespace="z")
    eng.submit(r1)
    eng.run_until_idle()
    stored = [n.seg for n in
              eng.prefix_cache.match("z", sys_p, peek=True).nodes]
    assert len(stored) == 2

    calls = {"scatter": 0}
    real = type(eng.slots).insert_prefill

    def spy(self, *a, **k):
        calls["scatter"] += 1
        return real(self, *a, **k)
    monkeypatch.setattr(type(eng.slots), "insert_prefill", spy)
    # gather() on the paged cache raises by construction — any KV-segment
    # extraction on the hit path would blow up the run
    r2 = Request(prompt=sys_p + [30, 31], max_new_tokens=3, namespace="z")
    eng.submit(r2)
    eng.run_until_idle()
    assert calls["scatter"] == 0                      # no prefill copy-in
    assert eng.metrics.requests[r2.request_id].n_cached == 8
    assert r2.generated == _ref_generate(cfg, params, r2.prompt, 3)


def test_paged_dense_no_extract_on_hit(served):
    """The dense slots' extract/_insert never exist on the paged path."""
    cfg, params = served
    eng = _engine(cfg, params)
    assert not hasattr(eng.slots, "extract")
    assert not hasattr(eng.slots, "_insert_impl")


# ------------------------------------------------------------ preemption
def test_preemption_under_pool_pressure(served):
    """A pool too small for both requests' full lengths forces the
    latest-admitted request back to the queue; both still finish with
    reference-exact outputs."""
    cfg, params = served
    eng = _engine(cfg, params, max_batch=2, capacity=48,
                  pool_tokens=48,        # 12 blocks of 4: tight — two
                  # 32-token sequences need 16
                  sched=SchedulerConfig(prefix_block=4, prefill_chunk=8,
                                        enable_prefix_cache=False))
    assert eng.slots.bp.num_blocks - 1 == 12
    p1 = [(i * 7) % 120 + 1 for i in range(16)]
    p2 = [(i * 5) % 110 + 1 for i in range(16)]
    r1 = Request(prompt=list(p1), max_new_tokens=16)
    r2 = Request(prompt=list(p2), max_new_tokens=16)
    eng.submit(r1)
    eng.submit(r2)
    s = eng.run_until_idle()
    assert s["completed"] == 2
    assert s["preempted"] >= 1
    assert r1.generated == _ref_generate(cfg, params, p1, 16)
    assert r2.generated == _ref_generate(cfg, params, p2, 16)
    assert eng.slots.bp.num_used == 0


def test_repeated_preemption_folds_each_token_once(served):
    """Regression: a request preempted more than once must fold only the
    tokens generated since the previous fold — re-folding the whole
    generated list duplicated context and could push the request past
    capacity mid-generation."""
    cfg, params = served
    eng = _engine(cfg, params, max_batch=3, capacity=40, pool_tokens=40,
                  sched=SchedulerConfig(prefix_block=4, prefill_chunk=8,
                                        enable_prefix_cache=False))
    prompts = [[(i * k) % 110 + 1 for i in range(8)] for k in (3, 5, 7)]
    reqs = [Request(prompt=list(p), max_new_tokens=12) for p in prompts]
    for r in reqs:
        eng.submit(r)
    s = eng.run_until_idle()
    assert s["completed"] == 3 and s["rejected"] == 0
    assert s["preempted"] >= 2           # churn actually happened
    for p, r in zip(prompts, reqs):
        # the folded prompt is exactly original + first n_folded tokens
        assert r.prompt == p + r.generated[:r.n_folded]
        assert len(r.generated) == 12
        assert r.generated == _ref_generate(cfg, params, p, 12)


def test_paged_oversubscribed_slots(served):
    """More slots than the pool could serve at worst case: short requests
    run concurrently anyway (the dense layout cannot oversubscribe)."""
    cfg, params = served
    eng = _engine(cfg, params, max_batch=6, capacity=64,
                  pool_tokens=128,       # worst case would need 384
                  sched=SchedulerConfig(prefix_block=4, prefill_chunk=8,
                                        admit_per_tick=6))
    reqs = [Request(prompt=[i + 1, i + 2, i + 3], max_new_tokens=8)
            for i in range(6)]
    for r in reqs:
        eng.submit(r)
    peak = 0
    while eng.num_active:
        eng.step()
        peak = max(peak, len(eng.running))
    assert peak == 6                     # all concurrent despite the pool
    for r in reqs:
        assert r.generated == _ref_generate(cfg, params, r.prompt, 8)


# ------------------------------------------------------------ sampling
def test_sample_batched_greedy_matches_argmax():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(5, 33)),
                         jnp.float32)
    got = sample_batched(logits, jax.random.PRNGKey(0),
                         jnp.zeros((5,)), jnp.zeros((5,), jnp.int32),
                         jnp.ones((5,)))
    assert (np.asarray(got) == np.asarray(jnp.argmax(logits, -1))).all()


def test_sample_batched_matches_single_row():
    """One-row batched sampling with the same key reproduces sample()
    for every filter combination."""
    logits = jnp.asarray([[2.0, 1.0, 0.5, -1.0, 3.0]])
    for seed in range(5):
        for t, k, p in ((1.0, 0, 1.0), (0.7, 2, 1.0), (1.0, 0, 0.6),
                        (1.3, 3, 0.8), (0.0, 0, 1.0)):
            key = jax.random.PRNGKey(seed)
            a = int(sample(logits, key, temperature=t, top_k=k, top_p=p)[0])
            b = int(sample_batched(
                logits, key, jnp.asarray([t]), jnp.asarray([k], jnp.int32),
                jnp.asarray([p]))[0])
            assert a == b, (t, k, p, seed)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_sample_batched_mixed_rows(seed):
    """Per-row settings apply row-wise: greedy rows are exact argmax,
    top-k rows stay inside their top-k set."""
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(3, 16)) * 3, jnp.float32)
    got = np.asarray(sample_batched(
        logits, jax.random.PRNGKey(seed),
        jnp.asarray([0.0, 1.0, 2.0]),
        jnp.asarray([0, 2, 4], jnp.int32),
        jnp.asarray([1.0, 1.0, 0.9])))
    assert got[0] == int(jnp.argmax(logits[0]))
    top2 = np.argsort(np.asarray(logits[1]))[-2:]
    assert got[1] in top2
    top4 = np.argsort(np.asarray(logits[2]))[-4:]
    assert got[2] in top4
