"""Serving-plane fault tolerance (ISSUE 7): deterministic fault
injection, token-exact crash recovery, gateway retry/failover with
breakers and deadlines, graceful degradation, HA quorum edges, and the
trainer's bounded restart loop.  Everything timed runs on an injected
virtual clock — ``time.sleep`` is patched to *raise* in the retry
tests."""
import time

import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.gateway import (CircuitBreaker, DeadlineExceeded, Gateway,
                                ModelEntry, NoHealthyEndpoint, Overloaded,
                                UpstreamFailure)
from repro.core.ha import ClusterMesh, Site, SplitBrainError
from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request
from repro.serving.faults import (Backoff, ChaosEngine, EngineFailure,
                                  EngineTimeout, FaultInjector, FaultSpec,
                                  VirtualClock, parse_fault_spec)
from repro.serving.scheduler import SchedulerConfig


@pytest.fixture(scope="module")
def served(tiny_cfg):
    params = M.init(tiny_cfg, jax.random.PRNGKey(0))
    return tiny_cfg, params


def _reference(cfg, params, prompt, n):
    eng = InferenceEngine(cfg, params, max_batch=2, capacity=96)
    r = Request(prompt=list(prompt), max_new_tokens=n)
    eng.submit(r)
    eng.run_until_idle()
    return list(r.generated)


def _gw(engines, cfg, clock=None, obs=None, **kw):
    gw = Gateway(**({} if clock is None else {"clock": clock}),
                 obs=obs, **kw)
    gw.vet_model(ModelEntry(cfg.name, cfg.name, 0.5, 1.5), cfg)
    gw.bind_endpoints(cfg.name, engines)
    return gw, gw.mint_key("proj")


PROMPT = [5, 7, 11, 13, 17]
GEN = 8


# ------------------------------------------------------------ injector
def test_fault_injector_deterministic():
    spec = FaultSpec(point="micro_step", kind="crash", at_call=3)
    inj = FaultInjector([spec])
    hits = [inj.check("micro_step") for _ in range(5)]
    assert hits == [None, None, spec, None, None]   # times=1 exhausted
    # probabilistic schedules replay exactly under the same seed
    mk = lambda: FaultInjector(  # noqa: E731
        [FaultSpec(point="emission", kind="reject", prob=0.3, times=-1)],
        seed=7)
    a, b = mk(), mk()
    seq = [a.check("emission") is not None for _ in range(50)]
    assert seq == [b.check("emission") is not None for _ in range(50)]
    assert any(seq) and not all(seq)
    # unrelated points never trip a spec
    assert all(a.check("micro_step") is None for _ in range(20))


def test_parse_fault_spec():
    s = parse_fault_spec("hang@micro_step:5:0.25")
    assert (s.kind, s.point, s.at_call, s.hang_s) == (
        "hang", "micro_step", 5, 0.25)
    assert parse_fault_spec("crash@admission").at_call == 1
    with pytest.raises(ValueError):
        parse_fault_spec("crash@nowhere")
    with pytest.raises(ValueError):
        FaultSpec(point="emission", kind="reject")   # no trigger


# ------------------------------------------------------------ backoff
@settings(max_examples=30, deadline=None)
@given(base=st.floats(0.001, 0.5), cap=st.floats(0.01, 5.0),
       attempt=st.integers(0, 40), seed=st.integers(0, 2**16))
def test_backoff_full_jitter_bounds(base, cap, attempt, seed):
    d = Backoff(base, cap, seed=seed).delay(attempt)
    assert 0.0 <= d <= cap
    assert d <= base * (2.0 ** attempt)
    # same seed -> same schedule; the jitter is reproducible
    s1 = [Backoff(base, cap, seed=seed).delay(a) for a in range(8)]
    s2 = [Backoff(base, cap, seed=seed).delay(a) for a in range(8)]
    assert s1 == s2


# ------------------------------------------------------------ engine
def test_health_drain_submit_gate(served):
    cfg, params = served
    eng = InferenceEngine(cfg, params, max_batch=2, capacity=96)
    assert eng.health() == "ok"
    r = Request(prompt=list(PROMPT), max_new_tokens=4)
    eng.submit(r)
    eng.drain()
    assert eng.health() == "draining" and r.done
    with pytest.raises(EngineFailure) as ei:
        eng.submit(Request(prompt=[1, 2, 3]))
    assert ei.value.kind == "draining"
    eng.recover()
    assert eng.health() == "ok"
    eng.healthy = False                  # legacy flag stays writable
    assert eng.health() == "down"
    with pytest.raises(EngineFailure):
        eng.submit(Request(prompt=[1, 2, 3]))


def test_crash_recover_token_exact_same_engine(served):
    cfg, params = served
    ref = _reference(cfg, params, PROMPT, GEN)
    inj = FaultInjector(
        [FaultSpec(point="emission", kind="crash", at_call=4)])
    eng = InferenceEngine(cfg, params, max_batch=2, capacity=96,
                          faults=inj)
    r = Request(prompt=list(PROMPT), max_new_tokens=GEN)
    eng.submit(r)
    with pytest.raises(EngineFailure) as ei:
        eng.run_until_idle()
    assert ei.value.kind == "crash" and ei.value.point == "emission"
    assert eng.health() == "down"
    # clean teardown: nothing in flight, every pool block returned
    assert not eng.running and not eng.queue
    assert eng.kv_stats()["kv_blocks_used"] == 0
    # committed tokens were folded so resumption is exact
    assert 0 < r.n_folded == len(r.generated) < GEN
    eng.recover()
    eng.submit(r)
    eng.run_until_idle()
    assert list(r.generated) == ref


def test_crash_failover_token_exact_other_engine(served):
    cfg, params = served
    ref = _reference(cfg, params, PROMPT, GEN)
    inj = FaultInjector(
        [FaultSpec(point="micro_step", kind="crash", at_call=3)])
    e0 = InferenceEngine(cfg, params, max_batch=2, capacity=96,
                         name="ft-e0", faults=inj)
    e1 = InferenceEngine(cfg, params, max_batch=2, capacity=96,
                         name="ft-e1")
    r = Request(prompt=list(PROMPT), max_new_tokens=GEN)
    e0.submit(r)
    with pytest.raises(EngineFailure):
        e0.run_until_idle()
    e1.submit(r)
    e1.run_until_idle()
    assert list(r.generated) == ref


def test_deadline_evacuates_token_exact(served):
    cfg, params = served
    ref = _reference(cfg, params, PROMPT, GEN)
    vc = VirtualClock()
    inj = FaultInjector(
        [FaultSpec(point="micro_step", kind="hang", at_call=3,
                   hang_s=9.0)],
        clock_advance=vc.advance)
    eng = InferenceEngine(cfg, params, max_batch=2, capacity=96,
                          clock=vc, faults=inj)
    r = Request(prompt=list(PROMPT), max_new_tokens=GEN)
    eng.submit(r)
    with pytest.raises(EngineTimeout) as ei:
        eng.run_until_idle(deadline=vc.now() + 5.0)
    assert ei.value.requests == [r]
    assert eng.health() == "ok"          # client deadline, engine fine
    eng.submit(r)
    eng.run_until_idle()
    assert list(r.generated) == ref


def test_chaos_engine_auto_recovers(served):
    cfg, params = served
    inj = FaultInjector(
        [FaultSpec(point="admission", kind="crash", at_call=1)])
    ce = ChaosEngine(
        InferenceEngine(cfg, params, max_batch=2, capacity=96),
        inj, auto_recover_probes=2)
    with pytest.raises(EngineFailure):
        ce.submit(Request(prompt=list(PROMPT)))
    assert ce.health() == "down"         # probe 1
    assert ce.health() == "ok"           # probe 2 triggers recover()
    r = Request(prompt=list(PROMPT), max_new_tokens=4)
    ce.submit(r)
    ce.run_until_idle()
    assert len(r.generated) == 4


# ------------------------------------------------------------ gateway
def test_pick_skips_unhealthy_typed_error(served):
    cfg, params = served
    e0 = InferenceEngine(cfg, params, max_batch=2, capacity=96,
                         name="gw-e0")
    e1 = InferenceEngine(cfg, params, max_batch=2, capacity=96,
                         name="gw-e1")
    gw, key = _gw([e0, e1], cfg)
    e0.crash()
    out = gw.completion(api_key=key.key, model=cfg.name,
                        prompt=list(PROMPT), max_tokens=4)
    assert out["usage"]["engine"] == "gw-e1"
    e1.draining = True
    with pytest.raises(NoHealthyEndpoint):
        gw.completion(api_key=key.key, model=cfg.name,
                      prompt=list(PROMPT), max_tokens=4)


def test_gateway_retry_failover_no_real_sleep(served, monkeypatch):
    cfg, params = served
    ref = _reference(cfg, params, PROMPT, GEN)
    vc = VirtualClock()
    from repro.obs import Observability
    obs = Observability(clock=vc.now)
    inj = FaultInjector(
        [FaultSpec(point="emission", kind="crash", at_call=4)],
        clock_advance=vc.advance)
    e0 = InferenceEngine(cfg, params, max_batch=2, capacity=96,
                         name="rt-e0", clock=vc, faults=inj)
    e1 = InferenceEngine(cfg, params, max_batch=2, capacity=96,
                         name="rt-e1", clock=vc)
    gw, key = _gw([e0, e1], cfg, clock=vc, obs=obs, retry_budget=3,
                  breaker_threshold=1, breaker_cooldown_s=5.0,
                  sleep=vc.sleep)

    def no_sleep(_dt):
        raise AssertionError("real time.sleep in retry path")
    monkeypatch.setattr(time, "sleep", no_sleep)

    t0 = vc.now()
    out = gw.completion(api_key=key.key, model=cfg.name,
                        prompt=list(PROMPT), max_tokens=GEN)
    assert out["tokens"] == ref          # resumed mid-stream, exact
    assert out["usage"]["engine"] == "rt-e1"
    assert vc.now() > t0                 # backoff burned virtual time
    assert gw._breakers[id(e0)].state == "open"
    snap = obs.registry.snapshot()
    assert snap[
        'repro_serving_retries_total{reason="UpstreamFailure"}'] >= 1
    # recovery: cooldown elapses -> half-open probe -> breaker closes
    e0.recover()
    vc.advance(6.0)
    out2 = gw.completion(api_key=key.key, model=cfg.name,
                         prompt=[9, 9, 9], max_tokens=4)
    assert out2["usage"]["engine"] == "rt-e0"
    assert gw._breakers[id(e0)].state == "closed"
    snap = obs.registry.snapshot()
    assert snap['repro_gateway_breaker_state{engine="rt-e0"}'] == 0
    for state in ("open", "half_open", "closed"):
        k = ('repro_gateway_breaker_transitions_total'
             f'{{engine="rt-e0",state="{state}"}}')
        assert snap[k] >= 1, k


def test_gateway_sheds_when_all_breakers_open(served, monkeypatch):
    cfg, params = served
    vc = VirtualClock()
    engines = []
    for i in range(2):
        inj = FaultInjector(
            [FaultSpec(point="admission", kind="reject", times=-1,
                       at_call=None, prob=1.0)])
        engines.append(InferenceEngine(
            cfg, params, max_batch=2, capacity=96, name=f"shed-e{i}",
            clock=vc, faults=inj))
    gw, key = _gw(engines, cfg, clock=vc, retry_budget=0,
                  breaker_threshold=1, breaker_cooldown_s=30.0,
                  sleep=vc.sleep)
    monkeypatch.setattr(time, "sleep", lambda _dt: (_ for _ in ()).throw(
        AssertionError("real sleep")))
    # first call trips one breaker (reject), budget 0 -> typed failure
    with pytest.raises(UpstreamFailure):
        gw.completion(api_key=key.key, model=cfg.name,
                      prompt=list(PROMPT), max_tokens=4)
    with pytest.raises(UpstreamFailure):
        gw.completion(api_key=key.key, model=cfg.name,
                      prompt=list(PROMPT), max_tokens=4)
    # both circuits open and cooling: the gateway sheds, never hangs
    assert all(gw._breakers[id(e)].state == "open" for e in engines)
    with pytest.raises(Overloaded):
        gw.completion(api_key=key.key, model=cfg.name,
                      prompt=list(PROMPT), max_tokens=4)


def test_gateway_queue_depth_shedding(served):
    cfg, params = served
    eng = InferenceEngine(cfg, params, max_batch=2, capacity=96)
    gw, key = _gw([eng], cfg, max_queue_depth=2)
    for _ in range(2):
        gw.completion(api_key=key.key, model=cfg.name,
                      prompt=list(PROMPT), max_tokens=2, run=False)
    assert eng.num_active == 2
    with pytest.raises(Overloaded):
        gw.completion(api_key=key.key, model=cfg.name,
                      prompt=list(PROMPT), max_tokens=2, run=False)
    eng.run_until_idle()
    gw.completion(api_key=key.key, model=cfg.name,
                  prompt=list(PROMPT), max_tokens=2)


def test_gateway_deadline_exceeded(served, monkeypatch):
    cfg, params = served
    vc = VirtualClock()
    inj = FaultInjector(
        [FaultSpec(point="micro_step", kind="hang", at_call=2,
                   hang_s=50.0)],
        clock_advance=vc.advance)
    eng = InferenceEngine(cfg, params, max_batch=2, capacity=96,
                          name="dl-e0", clock=vc, faults=inj)
    gw, key = _gw([eng], cfg, clock=vc, retry_budget=3,
                  deadline_s=10.0, sleep=vc.sleep)
    monkeypatch.setattr(time, "sleep", lambda _dt: (_ for _ in ()).throw(
        AssertionError("real sleep")))
    with pytest.raises(DeadlineExceeded):
        gw.completion(api_key=key.key, model=cfg.name,
                      prompt=list(PROMPT), max_tokens=GEN)
    # a slow engine is not a broken engine: no breaker failure recorded
    assert gw._breakers[id(eng)].state == "closed"
    assert eng.health() == "ok"


# ------------------------------------------------- graceful degradation
def test_degrade_ladder_down_and_up(served):
    cfg, params = served
    from repro.obs import Observability
    obs = Observability()
    eng = InferenceEngine(
        cfg, params, max_batch=2, capacity=48, pool_tokens=48, obs=obs,
        sched=SchedulerConfig(prefix_block=4, prefill_chunk=8,
                              enable_prefix_cache=False,
                              degrade_after=1, restore_after=3))
    p1 = [(i * 7) % 120 + 1 for i in range(16)]
    p2 = [(i * 5) % 110 + 1 for i in range(16)]
    for p in (p1, p2):
        eng.submit(Request(prompt=list(p), max_new_tokens=16))
    peak = 0
    while eng.num_active:
        eng.step()
        peak = max(peak, eng.scheduler.degrade_level)
    assert eng.metrics.summary()["preempted"] >= 1
    assert peak >= 1                      # pressure stepped the ladder
    # pressure is gone: calm ticks walk it back to 0
    for _ in range(3 * (peak + 1)):
        eng.scheduler.tick()
    assert eng.scheduler.degrade_level == 0
    snap = obs.registry.snapshot()
    assert snap[
        'repro_sched_degrade_transitions_total{direction="down"}'] >= 1
    assert snap[
        'repro_sched_degrade_transitions_total{direction="up"}'] >= 1
    assert snap["repro_sched_degrade_level_count"] == 0


def test_degrade_level1_suspends_speculation(served):
    cfg, params = served
    pat = [3, 1, 4, 1, 5, 9, 2, 6]
    prompt = pat * 3 + [7, 7]            # repetitive: ngram would hit
    base = _reference(cfg, params, prompt, GEN)
    eng = InferenceEngine(
        cfg, params, max_batch=2, capacity=128,
        speculative="ngram", spec_k=3,
        sched=SchedulerConfig(restore_after=10_000))  # pin the level
    eng.scheduler.degrade_level = 1
    r = Request(prompt=list(prompt), max_new_tokens=GEN)
    eng.submit(r)
    eng.run_until_idle()
    assert list(r.generated) == base     # plain decode, still exact
    assert eng.metrics.spec_rows == 0    # drafter never consulted


def test_degrade_level2_pauses_admission(served):
    cfg, params = served
    eng = InferenceEngine(
        cfg, params, max_batch=2, capacity=96,
        sched=SchedulerConfig(restore_after=10_000))
    eng.scheduler.degrade_level = 2
    r = Request(prompt=list(PROMPT), max_new_tokens=4)
    eng.submit(r)
    for _ in range(5):
        eng.step()
    assert not r.generated               # queued, never admitted
    eng.scheduler.degrade_level = 0
    eng.run_until_idle()
    assert len(r.generated) == 4


# ------------------------------------------------------------ HA edges
class _Ep:
    def __init__(self, healthy=True, num_active=0):
        self.healthy = healthy
        self.num_active = num_active


def test_ha_partition_heal_route_and_quorum():
    a = Site("alps", endpoints=[_Ep(), _Ep(num_active=3)])
    b = Site("lugano", endpoints=[_Ep()])
    mesh = ClusterMesh([a, b])
    assert mesh.propose_config("alps") == 1
    mesh.partition("alps")
    # partitioned site: writes fenced, traffic fails over
    with pytest.raises(SplitBrainError):
        mesh.propose_config("alps")
    site, _ = mesh.route(prefer="alps")
    assert site.name == "lugano"
    # epochs advanced while alps was dark
    assert mesh.propose_config("lugano") == 2
    # un-partitioning without heal() leaves a stale epoch: still fenced
    a.partitioned = False
    mesh.probe()
    with pytest.raises(SplitBrainError):
        mesh.propose_config("alps")
    # heal re-syncs the epoch; writes and routing both resume
    mesh.heal("alps")
    assert mesh.propose_config("alps") == 3
    site, ep = mesh.route(prefer="alps")
    assert site.name == "alps" and ep.num_active == 0  # least loaded
    # total blackout is a typed failure, not a hang
    mesh.partition("alps")
    mesh.partition("lugano")
    with pytest.raises(RuntimeError):
        mesh.route()


def test_ha_all_endpoints_dead_marks_site_unhealthy():
    s = Site("solo", endpoints=[_Ep(healthy=False)])
    mesh = ClusterMesh([s])
    mesh.probe()
    assert not s.healthy
    with pytest.raises(RuntimeError):
        mesh.route(prompt=[1, 2, 3])


# ------------------------------------------------------------ trainer
def test_trainer_gives_up_after_max_restarts(tiny_cfg, tmp_path):
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.obs import Observability
    from repro.training.optimizer import OptConfig
    from repro.training.trainer import (SimulatedNodeFailure, Trainer,
                                        TrainerConfig)
    data = SyntheticLM(DataConfig(vocab_size=tiny_cfg.vocab_size,
                                  seq_len=16, global_batch=4))

    def injector(step):
        if step >= 4:
            raise SimulatedNodeFailure(f"node died at {step}")

    obs = Observability()
    tc = TrainerConfig(num_steps=12, ckpt_every=2, log_every=4,
                       ckpt_dir=str(tmp_path), max_restarts=3)
    tr = Trainer(tiny_cfg, OptConfig(lr=1e-2), data, tc,
                 failure_injector=injector, obs=obs)
    with pytest.raises(SimulatedNodeFailure):
        tr.run()
    # 3 restore-and-retry cycles were allowed, the 4th failure raised
    assert tr.restarts == 4
    snap = obs.registry.snapshot()
    assert snap["repro_train_restarts_abandoned_total"] == 1
    assert snap["repro_train_failures_total"] == 4


def test_trainer_nonconsecutive_failures_still_tolerated(tiny_cfg,
                                                         tmp_path):
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.training.optimizer import OptConfig
    from repro.training.trainer import (SimulatedNodeFailure, Trainer,
                                        TrainerConfig)
    data = SyntheticLM(DataConfig(vocab_size=tiny_cfg.vocab_size,
                                  seq_len=16, global_batch=4))
    fails = {3, 7}

    def injector(step):
        if step in fails:
            fails.discard(step)
            raise SimulatedNodeFailure(f"flaky at {step}")

    tc = TrainerConfig(num_steps=10, ckpt_every=2, log_every=5,
                       ckpt_dir=str(tmp_path), max_restarts=1)
    tr = Trainer(tiny_cfg, OptConfig(lr=1e-2), data, tc,
                 failure_injector=injector)
    out = tr.run()
    # the consecutive counter resets on every completed step, so two
    # isolated failures pass under max_restarts=1
    assert out["final_step"] == 10 and out["restarts"] == 2


# ------------------------------------------------------------ breaker
def test_circuit_breaker_state_machine():
    vc = VirtualClock()
    seen = []
    br = CircuitBreaker(vc, threshold=2, cooldown_s=5.0,
                        on_transition=seen.append)
    assert br.allow() and br.state == "closed"
    br.record_failure()
    assert br.state == "closed"          # below threshold
    br.record_failure()
    assert br.state == "open" and not br.allow()
    vc.advance(4.0)
    assert not br.allow()                # still cooling
    vc.advance(1.0)
    assert br.allow() and br.state == "half_open"
    br.record_failure()                  # probe failed: snap back open
    assert br.state == "open" and not br.allow()
    vc.advance(5.0)
    assert br.allow()
    br.record_success()
    assert br.state == "closed" and br.failures == 0
    assert seen == ["open", "half_open", "open", "half_open", "closed"]
