import os
import sys

# Tests must see the single real CPU device (the dry-run sets its own
# device-count flag in its subprocess) — so no XLA_FLAGS here, but cap
# compilation parallelism for the 1-core container.  Exception: the
# sharded-serving suite opts in to N forced host devices by exporting
# REPRO_FORCE_DEVICES before launching pytest (its in-suite subprocess
# wrapper and the CI multi-device job both do); it must be appended
# before the first jax import.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_n_dev = os.environ.get("REPRO_FORCE_DEVICES")
if _n_dev and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={int(_n_dev)}").strip()

# Property tests use hypothesis; fall back to the deterministic shim in
# containers that don't ship it (CI installs the real package).
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_shim
    sys.modules["hypothesis"] = _hypothesis_shim
    sys.modules["hypothesis.strategies"] = _hypothesis_shim.strategies

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, scaled_down


TINY_OVERRIDES = dict(num_layers=2, d_model=64, d_ff=128, vocab_size=128,
                      num_heads=4, num_kv_heads=2, head_dim=16)


@pytest.fixture(scope="session")
def tiny_cfg():
    return scaled_down(get_config("qwen1.5-4b"), **TINY_OVERRIDES)


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    from repro.models import model as M
    return M.init(tiny_cfg, jax.random.PRNGKey(0))


def tiny_batch(cfg, B=2, S=16, key=0):
    k = jax.random.PRNGKey(key)
    toks = jax.random.randint(k, (B, S + 1), 1, cfg.vocab_size)
    return {
        "tokens": toks[:, :-1].astype(jnp.int32),
        "targets": toks[:, 1:].astype(jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
