"""Differential tests: a tensor-parallel (mesh-aware) engine must be
token-identical to the single-device engine at temperature 0.

The suite needs several host devices, which XLA only provides when
``--xla_force_host_platform_device_count`` is set *before jax imports*.
conftest.py appends that flag when ``REPRO_FORCE_DEVICES`` is exported,
so there are two ways in:

- the CI multi-device job (and any dev run) launches
  ``REPRO_FORCE_DEVICES=4 pytest tests/test_sharded_serving.py``;
- inside a plain single-device tier-1 run, the differential tests skip
  and :func:`test_sharded_suite_in_subprocess` re-runs this file in a
  subprocess with the flag set — so the tier-1 gate still proves TP
  token-identity without perturbing every other test's device world.

Token-identity caveat pinned here on purpose: TP shards contracting
dimensions (wo, mlp down), so partial sums reduce in a different order
than the single-device matmul.  On the tiny fp32 test models the logit
gaps are orders of magnitude above that reassociation noise, so greedy
argmax — and therefore every emitted token — is exactly identical; these
tests are the regression net that keeps it that way.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.models import model as M
from repro.parallel import sharding
from repro.serving.engine import InferenceEngine, Request

REPO = Path(__file__).resolve().parent.parent
MULTI = jax.device_count() >= 2
needs_multi = pytest.mark.skipif(
    not MULTI, reason="needs forced host devices (REPRO_FORCE_DEVICES)")


def _gqa_cfg(**over):
    kw = dict(num_layers=2, d_model=64, d_ff=128, vocab_size=128,
              num_heads=4, num_kv_heads=2, head_dim=16)
    kw.update(over)
    return scaled_down(get_config("qwen1.5-4b"), **kw)


def _mla_cfg():
    return scaled_down(get_config("deepseek-v2-lite-16b"), num_layers=2,
                       d_model=64, d_ff=128, vocab_size=128, num_heads=2)


def _tp_mesh(n: int):
    return jax.make_mesh((n,), ("model",))


def _prompts(vocab: int, n: int = 5, seed: int = 0):
    """Mixed-length prompts; the last two share a 20-token prefix so the
    sharded radix/prefix-cache adoption path is exercised too."""
    rng = np.random.default_rng(seed)
    ps = [[int(x) for x in rng.integers(1, vocab - 1, 5 + 3 * i)]
          for i in range(n - 2)]
    shared = [int(x) for x in rng.integers(1, vocab - 1, 20)]
    ps.append(shared + [3, 5])
    ps.append(shared + [7, 9])
    return ps


def _run(cfg, params, mesh, prompts, max_new=8, **eng_kw):
    eng = InferenceEngine(cfg, params, max_batch=4, capacity=128,
                          mesh=mesh, **eng_kw)
    reqs = [Request(prompt=list(p), max_new_tokens=max_new)
            for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    assert all(r.done for r in reqs)
    return [r.generated for r in reqs], eng


# ------------------------------------------------------------- differential
@needs_multi
def test_tp2_paged_gqa_token_identity():
    cfg = _gqa_cfg()
    params = M.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    prompts = _prompts(cfg.vocab_size)
    base, b_eng = _run(cfg, params, None, prompts)
    tp, t_eng = _run(cfg, params, _tp_mesh(2), prompts)
    assert b_eng.paged and t_eng.paged
    assert base == tp
    # the pool actually sharded: one device holds half the head axis
    leaf = jax.tree.leaves(t_eng.slots.pool)[0]
    assert leaf.addressable_shards[0].data.nbytes * 2 == leaf.nbytes


@needs_multi
def test_tp2_paged_mla_token_identity():
    cfg = _mla_cfg()
    params = M.init(cfg, jax.random.PRNGKey(1), jnp.float32)
    prompts = _prompts(cfg.vocab_size, seed=1)
    base, _ = _run(cfg, params, None, prompts)
    tp, t_eng = _run(cfg, params, _tp_mesh(2), prompts)
    assert t_eng.paged
    assert base == tp
    # MLA's latent pool has no head axis -> replicated on every device
    leaf = jax.tree.leaves(t_eng.slots.pool)[0]
    assert leaf.addressable_shards[0].data.nbytes == leaf.nbytes


@needs_multi
def test_tp2_dense_fallback_token_identity():
    cfg = _gqa_cfg()
    params = M.init(cfg, jax.random.PRNGKey(2), jnp.float32)
    prompts = _prompts(cfg.vocab_size, n=3, seed=2)
    base, _ = _run(cfg, params, None, prompts, paged=False)
    tp, t_eng = _run(cfg, params, _tp_mesh(2), prompts, paged=False)
    assert not t_eng.paged
    assert base == tp


@needs_multi
@pytest.mark.skipif(jax.device_count() < 4, reason="needs 4 devices")
def test_tp4_paged_gqa_token_identity():
    # TP=4 needs num_kv_heads % 4 == 0 (a sharded dim must divide the
    # mesh axis — the engine surfaces jax's divisibility error otherwise)
    cfg = _gqa_cfg(num_kv_heads=4)
    params = M.init(cfg, jax.random.PRNGKey(3), jnp.float32)
    prompts = _prompts(cfg.vocab_size, n=3, seed=3)
    base, _ = _run(cfg, params, None, prompts)
    tp, _ = _run(cfg, params, _tp_mesh(4), prompts)
    assert base == tp


@needs_multi
def test_tp2_multi_lora_token_identity():
    from repro.finetune.lora import LoraConfig, lora_init, lora_randomize

    cfg = _gqa_cfg()
    params = M.init(cfg, jax.random.PRNGKey(4), jnp.float32)
    lcfg = LoraConfig(rank=4)
    ads = [lora_randomize(lora_init(params, lcfg, jax.random.PRNGKey(10 + i)),
                          jax.random.PRNGKey(20 + i)) for i in range(2)]

    def go(mesh):
        eng = InferenceEngine(cfg, params, max_batch=4, capacity=128,
                              mesh=mesh, adapter_slots=2)
        for i, ad in enumerate(ads):
            eng.register_adapter(f"t{i}", ad, lcfg)
        prompts = _prompts(cfg.vocab_size, n=4, seed=4)
        names = ["", "t0", "t1", "t0"]
        reqs = [Request(prompt=list(p), max_new_tokens=8, adapter=a)
                for p, a in zip(prompts, names)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_idle()
        return [r.generated for r in reqs]

    assert go(None) == go(_tp_mesh(2))


@needs_multi
def test_tp2_speculative_ngram_token_identity():
    cfg = _gqa_cfg()
    params = M.init(cfg, jax.random.PRNGKey(5), jnp.float32)
    # repetitive prompts give the n-gram drafter real matches
    prompts = [[7, 8, 9, 7, 8, 9, 7, 8] for _ in range(3)]
    base, _ = _run(cfg, params, None, prompts, max_new=12)
    spec, s_eng = _run(cfg, params, _tp_mesh(2), prompts, max_new=12,
                       speculative="ngram", spec_k=3)
    assert base == spec
    assert s_eng.metrics.spec_rows > 0   # the drafter actually drafted


@needs_multi
def test_tp2_crash_recover_evacuation_token_identity():
    cfg = _gqa_cfg()
    params = M.init(cfg, jax.random.PRNGKey(6), jnp.float32)
    prompts = _prompts(cfg.vocab_size, n=3, seed=6)
    base, _ = _run(cfg, params, None, prompts, max_new=10)

    mesh = _tp_mesh(2)
    a = InferenceEngine(cfg, params, max_batch=4, capacity=128, mesh=mesh,
                        name="tpA")
    b = InferenceEngine(cfg, params, max_batch=4, capacity=128, mesh=mesh,
                        name="tpB")
    reqs = [Request(prompt=list(p), max_new_tokens=10) for p in prompts]
    for r in reqs:
        a.submit(r)
    for _ in range(4):           # a few committed tokens, then the crash
        a.step()
    evacuated = a.crash()
    assert a.health() == "down"
    for r in evacuated:          # preemption fold keeps them token-exact
        b.submit(r)
    b.run_until_idle()
    assert [r.generated for r in reqs] == base
    a.recover()
    assert a.health() == "ok"


@needs_multi
def test_tp2_gateway_sharded_replica_is_one_endpoint():
    from repro.core.gateway import Gateway, ModelEntry

    cfg = _gqa_cfg()
    params = M.init(cfg, jax.random.PRNGKey(7), jnp.float32)
    prompts = _prompts(cfg.vocab_size, n=3, seed=7)
    base, _ = _run(cfg, params, None, prompts, max_new=8)

    eng = InferenceEngine(cfg, params, max_batch=4, capacity=128,
                          mesh=_tp_mesh(2), name="tp2")
    gw = Gateway()
    gw.vet_model(ModelEntry(cfg.name, cfg.name, 0.5, 1.5), cfg)
    gw.bind_endpoints(cfg.name, [eng])     # sharded replica == 1 endpoint
    key = gw.mint_key("test", budget_usd=10.0)
    outs = [gw.completion(api_key=key.key, model=cfg.name, prompt=list(p),
                          max_tokens=8, temperature=0.0)["tokens"]
            for p in prompts]
    assert outs == base


# ------------------------------------------------------------------- HLO
@needs_multi
def test_tp2_decode_hlo_collectives():
    """The per-token collective budget (serving/README.md): the fused
    paged decode step lowers to all-reduce/all-gather only — the two
    partial-sum reductions per layer plus the logits gather — and never
    an all-to-all or a host transfer."""
    from repro.launch import hlo_analysis as H

    cfg = _gqa_cfg()
    params = M.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = InferenceEngine(cfg, params, max_batch=4, capacity=128,
                          mesh=_tp_mesh(2))
    B = eng.slots.B
    toks = jnp.zeros((B, 1), jnp.int32)
    lengths = jnp.ones((B,), jnp.int32)
    key = jax.random.PRNGKey(0)
    temps = jnp.zeros((B,), jnp.float32)
    tks = jnp.zeros((B,), jnp.int32)
    tps = jnp.ones((B,), jnp.float32)
    lowered = eng._decode_sample_paged.lower(
        eng.params, toks, eng.slots.pool, eng.slots.tables_device(),
        lengths, key, temps, tks, tps, None, None, True)
    txt = lowered.compile().as_text()
    n_ar = txt.count("all-reduce(") + txt.count("all-reduce-start(")
    n_ag = txt.count("all-gather(") + txt.count("all-gather-start(")
    assert n_ar >= 1, "TP decode must reduce partial sums"
    # static instruction budget: 2 reductions per layer (attn wo + mlp
    # down) plus a small constant for logits/embed — the scan body
    # appears once in the module text
    assert n_ar + n_ag <= 2 * cfg.num_layers + 6, txt[:2000]
    assert "all-to-all" not in txt
    res = H.analyze(txt, 2)
    active = {k for k, v in res["by_collective"].items() if v > 0}
    assert active <= {"all-reduce", "all-gather", "reduce-scatter",
                      "collective-permute"}, active


@needs_multi
def test_tp1_decode_hlo_has_no_collectives():
    """mesh=None engines compile collective-free single-device modules —
    the 'bit-for-bit untouched' acceptance criterion at the HLO level."""
    cfg = _gqa_cfg()
    params = M.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = InferenceEngine(cfg, params, max_batch=4, capacity=128)
    assert eng.mesh is None and eng.rules is None and eng.tp == 1
    B = eng.slots.B
    lowered = eng._decode_sample_paged.lower(
        eng.params, jnp.zeros((B, 1), jnp.int32), eng.slots.pool,
        eng.slots.tables_device(), jnp.ones((B,), jnp.int32),
        jax.random.PRNGKey(0), jnp.zeros((B,), jnp.float32),
        jnp.zeros((B,), jnp.int32), jnp.ones((B,), jnp.float32),
        None, None, True)
    txt = lowered.compile().as_text()
    for coll in ("all-reduce(", "all-gather(", "all-to-all",
                 "collective-permute("):
        assert coll not in txt


def test_serving_mesh_requires_model_axis():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    cfg = _gqa_cfg()
    params = M.init(cfg, jax.random.PRNGKey(0), jnp.float32)
    with pytest.raises(ValueError, match="model"):
        InferenceEngine(cfg, params, mesh=mesh)


def test_serving_tp_rules_resolve():
    """Pure rule-table checks (no devices): the serving_tp layout."""
    r = sharding.make_rules("serving_tp")
    # params: pure TP, no fsdp
    assert tuple(r.spec(("fsdp", "tensor"))) == (None, "model")
    assert tuple(r.spec(("tensor", "fsdp"))) == ("model", None)
    # GQA pool leaf (num_blocks, block_size, KV, hd): head-sharded only
    assert tuple(r.spec(("act_batch", "act_kvseq", "act_heads", None))) \
        == (None, None, "model", None)
    # MLA latent pool leaf: fully replicated
    assert tuple(r.spec(("act_batch", "act_kvseq", None))) \
        == (None, None, None)
    # embeddings + logits replicated
    assert tuple(r.spec((None, "fsdp"))) == (None, None)
    assert tuple(r.spec(("act_batch", None, "act_vocab"))) \
        == (None, None, None)
    # MoE: dense-impl (no expert axis) with TP-sharded shared experts
    assert r.resolve("expert") is None
    assert r.resolve("act_ff") == "model"


# ------------------------------------------------------- tier-1 entrypoint
def test_sharded_suite_in_subprocess():
    """Single-device tier-1 runs still gate on the sharded suite: re-run
    this file with 4 forced host devices in a fresh interpreter (the
    flag must precede jax's import, so it cannot be set in-process)."""
    if MULTI:
        pytest.skip("already multi-device: the suite ran natively")
    env = dict(os.environ)
    env["REPRO_FORCE_DEVICES"] = "4"
    env["PYTHONPATH"] = (str(REPO / "src") + os.pathsep
                         + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    r = subprocess.run(
        [sys.executable, "-m", "pytest", str(Path(__file__).resolve()),
         "-q", "-p", "no:randomly", "-x"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=3000)
    assert r.returncode == 0, (r.stdout[-5000:] + "\n" + r.stderr[-2000:])
    assert "passed" in r.stdout
