"""Radix-tree prefix cache + chunked-prefill scheduler.

Exactness (engine output with prefix reuse matches the sequential
reference token-for-token), eviction under ledger pressure, per-tenant
namespace isolation, ref-count pinning, longest-prefix-match properties,
and prefix-affinity routing through the gateway and the HA mesh.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request
from repro.serving.prefix_cache import PrefixCache, supports_prefix_cache
from repro.serving.scheduler import SchedulerConfig


@pytest.fixture(scope="module")
def served(tiny_cfg):
    params = M.init(tiny_cfg, jax.random.PRNGKey(0))
    return tiny_cfg, params


def _ref_generate(cfg, params, prompt, n, cap=128):
    b = {"tokens": jnp.asarray([prompt], jnp.int32),
         "prompt_lengths": jnp.asarray([len(prompt)], jnp.int32)}
    logits, cache, _ = M.prefill(cfg, params, b)
    cache = M.pad_cache(cfg, cache, cap)
    out = [int(jnp.argmax(logits[0]))]
    lengths = jnp.asarray([len(prompt)], jnp.int32)
    for _ in range(n - 1):
        lengths = lengths + 1
        logits, cache = M.decode_step(
            cfg, params, jnp.asarray([[out[-1]]], jnp.int32), cache, lengths)
        out.append(int(jnp.argmax(logits[0])))
    return out


def _engine(cfg, params, **kw):
    sched = kw.pop("sched", SchedulerConfig(prefix_block=4, prefill_chunk=8))
    kw.setdefault("max_batch", 3)
    kw.setdefault("capacity", 128)
    return InferenceEngine(cfg, params, sched=sched, **kw)


# ------------------------------------------------------------ exactness
def test_shared_and_disjoint_prefix_exactness(served):
    """Cache hits and misses both reproduce the reference exactly."""
    cfg, params = served
    sys_p = [7, 3, 9, 1, 4, 4, 2, 8, 6, 5, 1, 2]       # 3 whole blocks
    prompts = ([sys_p + [20 + i, 30 + i] for i in range(4)]
               + [[90, 91, 92, 93, 94], [60, 61]])     # disjoint tails
    eng = _engine(cfg, params)
    reqs = [Request(prompt=p, max_new_tokens=5) for p in prompts]
    for r in reqs:
        eng.submit(r)
    s = eng.run_until_idle()
    for p, r in zip(prompts, reqs):
        assert r.generated == _ref_generate(cfg, params, p, 5), p
    # later shared-prefix requests reused the stored system prompt
    assert s["prefill_tokens_saved"] >= 3 * 12
    assert s["prefix_hit_rate"] > 0.3
    assert eng.prefix_cache.hit_queries >= 3
    # everything drained cleanly
    assert not eng.slots.slot_owner
    assert eng.ledger.free_blocks == eng.ledger.total_blocks


def test_chunked_prefill_long_prompt_exact(served):
    """A cache-miss prompt longer than prefill_chunk streams its tail
    through decode micro-steps and still matches the reference."""
    cfg, params = served
    prompt = [(i * 7) % 120 + 1 for i in range(37)]    # 37 > chunk of 8
    eng = _engine(cfg, params, sched=SchedulerConfig(
        prefix_block=4, prefill_chunk=8, enable_prefix_cache=False))
    req = Request(prompt=prompt, max_new_tokens=6)
    eng.submit(req)
    eng.run_until_idle()
    assert req.generated == _ref_generate(cfg, params, prompt, 6)


def test_interleaved_decode_not_starved(served):
    """Chunked prefill of a long prompt must not stall a running decode:
    the running request keeps emitting one token per tick."""
    cfg, params = served
    eng = _engine(cfg, params, sched=SchedulerConfig(
        prefix_block=4, prefill_chunk=4))
    r1 = Request(prompt=[5, 6, 7], max_new_tokens=12)
    eng.submit(r1)
    eng.step()                       # r1 admitted + first decode
    tokens_before = len(r1.generated)
    r2 = Request(prompt=[(i * 5) % 110 + 1 for i in range(30)],
                 max_new_tokens=4)
    eng.submit(r2)
    eng.step()                       # r2 admitted; r1 must still progress
    assert len(r1.generated) > tokens_before
    eng.run_until_idle()
    assert r1.generated == _ref_generate(cfg, params, [5, 6, 7], 12)
    assert r2.generated == _ref_generate(cfg, params, r2.prompt, 4)


# ------------------------------------------------------------ eviction
def test_eviction_under_ledger_pressure(served):
    """A tiny cache budget forces LRU eviction; outputs stay exact and
    the cache ledger never overflows."""
    cfg, params = served
    eng = _engine(cfg, params, sched=SchedulerConfig(
        prefix_block=4, prefill_chunk=8, cache_capacity_tokens=16))
    rng = np.random.default_rng(3)
    prompts = [list(map(int, rng.integers(1, 120, 12))) for _ in range(6)]
    reqs = [Request(prompt=p, max_new_tokens=3) for p in prompts]
    for r in reqs:
        eng.submit(r)
        eng.run_until_idle()
    pc = eng.prefix_cache
    assert pc.evicted_nodes > 0
    assert pc.cached_tokens <= 16
    assert pc.ledger.free_blocks >= 0
    for p, r in zip(prompts, reqs):
        assert r.generated == _ref_generate(cfg, params, p, 3)


def test_refcount_blocks_eviction():
    """Pinned paths survive eviction pressure; unpinned LRU leaves go."""
    axes = {"k": ("act_batch", "act_kvseq")}
    pc = PrefixCache(axes, block_size=2, capacity_tokens=8)  # 4 nodes max

    def seg_fn(tag):
        return lambda s, e: {"k": np.full((1, e - s), tag, np.float32)}

    a = pc.insert("t", [1, 2, 3, 4], seg_fn(1.0))    # 2 nodes
    b = pc.insert("t", [9, 8, 7, 6], seg_fn(2.0))    # 2 nodes -> full
    assert pc.n_nodes == 4 and pc.ledger.free_blocks == 0
    pc.unlock(b)                                     # b evictable, a pinned
    c = pc.insert("t", [5, 5, 5, 5], seg_fn(3.0))    # needs 2 evictions
    assert pc.n_nodes == 4
    assert pc.match("t", [1, 2, 3, 4]).length == 4   # pinned path intact
    assert pc.match("t", [9, 8, 7, 6]).length == 0   # LRU path evicted
    pc.unlock(a), pc.unlock(c)
    # fully pinned tree refuses eviction entirely
    pc2 = PrefixCache(axes, block_size=2, capacity_tokens=4)
    locked = pc2.insert("t", [1, 2, 3, 4], seg_fn(1.0))
    assert len(locked) == 2
    assert pc2.evict(5) == 0
    pc2.unlock(locked)
    assert pc2.evict(5) == 2


def test_insert_never_evicts_its_own_path():
    """Eviction during insert must exclude the path being extended —
    evicting the leaf we are about to hang a child off would orphan the
    child while it still holds a ledger block (permanent capacity leak)."""
    axes = {"k": ("act_batch", "act_kvseq")}
    seg = lambda s, e: {"k": np.zeros((1, e - s))}
    # full ledger, only evictable node IS the insertion path: stop early
    pc = PrefixCache(axes, block_size=2, capacity_tokens=2)
    a = pc.insert("t", [1, 2], seg)
    pc.unlock(a)
    b = pc.insert("t", [1, 2, 3, 4], seg)
    assert b == []                                   # refused, not orphaned
    assert pc.match("t", [1, 2]).length == 2         # path intact
    assert pc.evict(10) == 1
    assert pc.ledger.free_blocks == pc.ledger.total_blocks  # no leak
    # with an unrelated evictable sibling, the extension succeeds
    pc2 = PrefixCache(axes, block_size=2, capacity_tokens=4)
    pc2.unlock(pc2.insert("t", [1, 2], seg))
    pc2.unlock(pc2.insert("t", [9, 9], seg))
    pc2.unlock(pc2.insert("t", [1, 2, 3, 4], seg))   # evicts [9,9], not [1,2]
    assert pc2.match("t", [1, 2, 3, 4]).length == 4
    assert pc2.match("t", [9, 9]).length == 0
    assert pc2.evict(10) == 2
    assert pc2.ledger.free_blocks == pc2.ledger.total_blocks


# ------------------------------------------------------------ isolation
def test_namespace_isolation(served):
    """The same prompt under another tenant's namespace gets no reuse."""
    cfg, params = served
    eng = _engine(cfg, params)
    prompt = [11, 12, 13, 14, 15, 16, 17, 18]
    r1 = Request(prompt=list(prompt), max_new_tokens=4, namespace="proj-a")
    eng.submit(r1)
    eng.run_until_idle()
    # proj-a's prefill is indexed under proj-a only
    assert eng.prefix_match_len("proj-a", prompt) > 0
    assert eng.prefix_match_len("proj-b", prompt) == 0
    r2 = Request(prompt=list(prompt), max_new_tokens=4, namespace="proj-b")
    r3 = Request(prompt=list(prompt), max_new_tokens=4, namespace="proj-a")
    eng.submit(r2), eng.submit(r3)
    eng.run_until_idle()
    ref = _ref_generate(cfg, params, prompt, 4)
    assert r1.generated == ref and r2.generated == ref and r3.generated == ref
    ms = eng.metrics.requests
    assert ms[r2.request_id].n_cached == 0        # cross-tenant: no reuse
    assert ms[r3.request_id].n_cached > 0         # same tenant: reuse


# ------------------------------------------------------------ properties
@settings(max_examples=30, deadline=None)
@given(data=st.lists(st.lists(st.integers(0, 3), min_size=0, max_size=12),
                     min_size=1, max_size=6),
       query=st.lists(st.integers(0, 3), min_size=0, max_size=12),
       bs=st.integers(1, 4))
def test_match_never_exceeds_stored_prefix(data, query, bs):
    """Longest-prefix match equals the brute-force longest whole-block
    common prefix over everything inserted — never more."""
    axes = {"k": ("act_batch", "act_kvseq")}
    pc = PrefixCache(axes, block_size=bs, capacity_tokens=10_000)
    for seq in data:
        pc.insert("ns", seq, lambda s, e: {"k": np.zeros((1, e - s))})
    got = pc.match("ns", query).length
    brute = 0
    for seq in data:
        stored = (len(seq) // bs) * bs            # whole blocks only
        common = 0
        while (common < min(stored, len(query))
               and seq[common] == query[common]):
            common += 1
        brute = max(brute, (common // bs) * bs)
    assert got == brute
    assert got <= len(query) and got % bs == 0
    if got:
        seg = pc.gather(pc.match("ns", query), got)
        assert seg["k"].shape == (1, got)


def test_supports_prefix_cache_gating(tiny_cfg):
    from repro.configs import get_config
    assert supports_prefix_cache(tiny_cfg)                      # GQA
    assert not supports_prefix_cache(get_config("mamba2-1.3b"))  # SSM state
    assert not supports_prefix_cache(get_config("whisper-small"))  # enc-dec
    assert not supports_prefix_cache(get_config("internvl2-1b"))   # vision


# ------------------------------------------------------------ routing
def test_gateway_prefix_affinity_and_namespace(served):
    from repro.core.gateway import Gateway, ModelEntry
    cfg, params = served
    t = itertools.count()
    gw = Gateway(clock=lambda: float(next(t)) * 0.01)
    gw.vet_model(ModelEntry("tiny", "qwen", 0.1, 0.2), cfg)
    engines = [_engine(cfg, params, name=f"e{i}", max_batch=2) for i in (0, 1)]
    gw.bind_endpoints("tiny", engines)
    key = gw.mint_key("proj-a", budget_usd=100.0)
    prompt = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3]
    out1 = gw.completion(api_key=key.key, model="tiny", prompt=prompt,
                         max_tokens=4)
    # same project + same prefix -> affinity routes to the warm replica
    out2 = gw.completion(api_key=key.key, model="tiny",
                         prompt=prompt + [7, 7], max_tokens=4)
    assert out2["usage"]["engine"] == out1["usage"]["engine"]
    assert out1["tokens"] == _ref_generate(cfg, params, prompt, 4)
    # another project is namespace-isolated: no cached tokens for it,
    # even for the byte-identical prompt
    key_b = gw.mint_key("proj-b", budget_usd=100.0)
    out_b = gw.completion(api_key=key_b.key, model="tiny", prompt=prompt,
                          max_tokens=4)
    eng_b = {e.name: e for e in engines}[out_b["usage"]["engine"]]
    assert eng_b.metrics.requests[out_b["id"]].n_cached == 0
    assert out_b["tokens"] == out1["tokens"]      # same math, no reuse


def test_ha_route_prefix_affinity(served):
    from repro.core.ha import ClusterMesh, Site
    cfg, params = served
    e_cold = _engine(cfg, params, name="cold", max_batch=2)
    e_warm = _engine(cfg, params, name="warm", max_batch=2)
    prompt = [9, 9, 8, 8, 7, 7, 6, 6]
    r = Request(prompt=list(prompt), max_new_tokens=3, namespace="p")
    e_warm.submit(r)
    e_warm.run_until_idle()
    mesh = ClusterMesh([Site("a", [e_cold]), Site("b", [e_warm])])
    site, eng = mesh.route(prompt=prompt + [5, 4], namespace="p")
    assert eng is e_warm and site.name == "b"
    # no prompt -> legacy least-loaded routing still works
    site, eng = mesh.route(prefer="a")
    assert site.name == "a"
    # warm replica down -> affinity falls back to the healthy one
    e_warm.healthy = False
    site, eng = mesh.route(prompt=prompt, namespace="p")
    assert eng is e_cold
