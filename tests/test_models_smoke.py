"""Per-arch smoke tests (assignment requirement): a REDUCED config of the
same family runs one forward/train step on CPU; output shapes + no NaNs.
Plus decode-vs-prefill consistency for every family."""
import jax
import jax.numpy as jnp
import pytest

import repro.models.model as MM
from repro.configs import get_config, scaled_down
from repro.configs.all_archs import ASSIGNED, PAPER_OWN
from repro.models import model as M

SMOKE_FRAMES = 24


def _batch_for(cfg, B=2, S=16, key=0):
    k = jax.random.PRNGKey(key)
    S_txt = S - cfg.frontend_tokens if cfg.frontend == "vision" else S
    b = {"tokens": jax.random.randint(k, (B, S_txt), 1,
                                      cfg.vocab_size).astype(jnp.int32)}
    if cfg.frontend == "vision":
        b["vision_embeds"] = 0.1 * jax.random.normal(
            k, (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        b["frames"] = 0.1 * jax.random.normal(
            k, (B, SMOKE_FRAMES, cfg.frontend_dim), jnp.bfloat16)
    b["targets"] = jax.random.randint(jax.random.PRNGKey(key + 1),
                                      (B, S), 1, cfg.vocab_size
                                      ).astype(jnp.int32)
    b["mask"] = jnp.ones((B, S), jnp.float32)
    return b


@pytest.fixture(autouse=True)
def _small_whisper_window(monkeypatch):
    monkeypatch.setattr(MM, "WHISPER_ENCODER_FRAMES", SMOKE_FRAMES)


@pytest.mark.parametrize("arch", ASSIGNED + PAPER_OWN)
def test_smoke_train_step(arch):
    cfg = scaled_down(get_config(arch))
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)

    def lossfn(p):
        return M.train_loss(cfg, p, batch)

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lossfn, has_aux=True))(params)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    gn = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gn)), f"{arch}: grads not finite"
    assert float(gn) > 0.0, f"{arch}: zero gradients"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode_consistency(arch):
    """Greedy decode logits == prefix-prefill logits (cache correctness)."""
    cfg = scaled_down(get_config(arch))
    params = M.init(cfg, jax.random.PRNGKey(0))
    B, T0, T = 2, 8, 11
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, T), 1, cfg.vocab_size
                              ).astype(jnp.int32)
    extra = {}
    n_front = 0
    if cfg.frontend == "vision":
        extra["vision_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
        n_front = cfg.frontend_tokens
    if cfg.is_encoder_decoder:
        extra["frames"] = 0.1 * jax.random.normal(
            key, (B, SMOKE_FRAMES, cfg.frontend_dim), jnp.bfloat16)

    def pre(n):
        b = dict(tokens=toks[:, :n],
                 prompt_lengths=jnp.full((B,), n + n_front, jnp.int32),
                 **extra)
        return M.prefill(cfg, params, b)

    _, cache, _ = jax.jit(pre, static_argnums=0)(T0)
    cache = M.pad_cache(cfg, cache, T + n_front + 4)
    dec = jax.jit(lambda p, t, c, l: M.decode_step(cfg, p, t, c, l))
    lengths = jnp.full((B,), T0 + n_front, jnp.int32)
    for t in range(T0, T):
        ref, _, _ = jax.jit(pre, static_argnums=0)(t + 1)
        lengths = lengths + 1
        got, cache = dec(params, toks[:, t:t + 1], cache, lengths)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 3e-2, f"{arch} step {t}: decode/prefill err {err}"


def test_vlm_prefill_shapes():
    cfg = scaled_down(get_config("internvl2-1b"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    b = _batch_for(cfg, B=2, S=16)
    b["prompt_lengths"] = jnp.full((2,), 16, jnp.int32)
    logits, cache, _ = M.prefill(cfg, params, b)
    assert logits.shape == (2, cfg.vocab_padded)
