"""Paper §5.3: system throughput (token generation under sustained load)
and training-step throughput, on the CPU-tiny stand-in."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, scaled_down
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request
from repro.training.optimizer import OptConfig, opt_init
from repro.training.train_step import make_train_step


def serving_throughput(window_s: float = 6.0) -> List[str]:
    cfg = scaled_down(get_config("apertus-8b"), num_layers=2, d_model=64,
                      d_ff=128, vocab_size=256, num_heads=2,
                      num_kv_heads=2, head_dim=32)
    params = M.init(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, max_batch=4, capacity=96)
    rng = np.random.default_rng(1)
    t_end = time.monotonic() + window_s
    submitted = 0
    while time.monotonic() < t_end:
        if eng.num_active < 8:
            eng.submit(Request(
                prompt=list(rng.integers(1, 255, 8)), max_new_tokens=24))
            submitted += 1
        eng.step()
    s = eng.metrics.summary()
    tps = s["tokens_per_s"]
    per48h = tps * 48 * 3600
    return [
        f"throughput_tokens_per_s,{1e6 / max(tps, 1e-9):.0f},"
        f"tokens_per_s={tps:.1f}",
        f"throughput_48h_projection,{per48h:.0f},"
        f"paper=2.5M(8B)+1M(70B) on GH200",
    ]


def training_throughput(steps: int = 10) -> List[str]:
    cfg = scaled_down(get_config("apertus-8b"), num_layers=2, d_model=128,
                      d_ff=256, vocab_size=512, num_heads=4,
                      num_kv_heads=2, head_dim=32)
    data = SyntheticLM(DataConfig(vocab_size=512, seq_len=128,
                                  global_batch=8))
    params = M.init(cfg, jax.random.PRNGKey(0))
    opt_cfg = OptConfig()
    state = opt_init(opt_cfg, params)
    step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    params, state, _ = step(params, state, b)  # compile
    t0 = time.perf_counter()
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i + 1).items()}
        params, state, m = step(params, state, b)
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / steps
    toks = 8 * 128 / dt
    return [f"train_step_tiny,{dt * 1e6:.0f},tokens_per_s={toks:.0f}"]


def run() -> List[str]:
    return serving_throughput() + training_throughput()


if __name__ == "__main__":
    print("\n".join(run()))
