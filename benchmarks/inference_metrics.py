"""Paper §5.2: serving metrics (QPS, TTFT, ITL, E2EL).

Two reproductions:
1. measured: the continuous-batching engine on a tiny model on CPU, with
   the paper's two workload mixes (70B-style: medium prompts / moderate
   responses; 8B-style: short prompts / long-form generation) scaled down.
   Reproduces the paper's qualitative finding: the long-generation mix has
   far higher E2EL despite lower per-token latency pressure.
2. analytic: ITL for Apertus-8B/70B-class configs on the v5e target from
   the decode roofline (paper reference points: ~11 ms and ~42 ms).
3. shared-system-prompt mix: the multi-tenant gateway pattern (every
   request of a project carries the same long system prefix) with the
   radix prefix cache on vs. off — reports TTFT, prefill tokens saved,
   and hit rate, and checks decoded outputs are identical
   token-for-token (see src/repro/serving/README.md).
"""
from __future__ import annotations

import itertools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, scaled_down
from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request
from repro.serving.scheduler import SchedulerConfig

# v5e-per-chip constants (same as launch.dryrun)
HBM_BW = 819e9
PEAK = 197e12


def _mk_engine(max_batch=4, capacity=160, sched=None):
    cfg = scaled_down(get_config("apertus-8b"), num_layers=2, d_model=64,
                      d_ff=128, vocab_size=256, num_heads=2,
                      num_kv_heads=2, head_dim=32)
    params = M.init(cfg, jax.random.PRNGKey(0))
    return InferenceEngine(cfg, params, max_batch=max_batch,
                           capacity=capacity, sched=sched)


def _mix(engine, rng, n_req, prompt_rng, gen_rng):
    reqs = []
    for _ in range(n_req):
        p = int(rng.integers(*prompt_rng))
        g = int(rng.integers(*gen_rng))
        r = Request(prompt=list(rng.integers(1, 255, p)), max_new_tokens=g)
        reqs.append(r)
        engine.submit(r)
    return engine.run_until_idle()


def measured_rows() -> List[str]:
    rng = np.random.default_rng(0)
    # 70B-style mix: prompts 100-800 -> 10-80; responses 200-500 -> 20-50
    e1 = _mk_engine()
    s1 = _mix(e1, rng, 12, (10, 80), (20, 50))
    # 8B-style mix: prompts <200 -> <20; long-form 3000+ -> 100+
    e2 = _mk_engine(capacity=192)
    s2 = _mix(e2, rng, 12, (4, 20), (100, 128))
    rows = []
    for tag, s in (("mix70b", s1), ("mix8b_longform", s2)):
        rows.append(f"serve_{tag}_ttft_p50,{s['ttft_p50_s'] * 1e6:.0f},"
                    f"p99_s={s['ttft_p99_s']:.3f}")
        rows.append(f"serve_{tag}_itl_mean,{s['itl_mean_s'] * 1e6:.0f},"
                    f"tokens={s['generated_tokens']}")
        rows.append(f"serve_{tag}_e2el_mean,{s['e2el_mean_s'] * 1e6:.0f},"
                    f"qps={s['qps']:.3f}")
    # paper's qualitative claim: long-form mix E2EL >> medium mix E2EL
    ratio = s2["e2el_mean_s"] / s1["e2el_mean_s"]
    rows.append(f"serve_longform_e2el_ratio,{ratio * 1e6:.0f},"
                f"paper=31.4s_vs_5.84s (5.4x)")
    return rows


def shared_prefix_rows() -> List[str]:
    """Multi-tenant shared-system-prompt mix, prefix cache on vs. off.

    Every request of the project carries the same 48-token system prompt
    plus a short unique user turn — the dominant pattern behind the
    paper's shared gateway.  The acceptance bar is >= 30% of prefill
    tokens served from cache with token-identical outputs."""
    rng = np.random.default_rng(7)
    system = list(map(int, rng.integers(1, 255, 48)))
    prompts = [system + list(map(int, rng.integers(1, 255,
                                                   int(rng.integers(8, 24)))))
               for _ in range(12)]
    outs, sums = {}, {}
    for on in (True, False):
        eng = _mk_engine(capacity=192, sched=SchedulerConfig(
            enable_prefix_cache=on, prefix_block=8, prefill_chunk=32))
        reqs = [Request(prompt=list(p), max_new_tokens=24,
                        namespace="proj") for p in prompts]
        for r in reqs:
            eng.submit(r)
        sums[on] = eng.run_until_idle()
        outs[on] = [r.generated for r in reqs]
    identical = int(outs[True] == outs[False])
    s_on, s_off = sums[True], sums[False]
    rows = [
        f"serve_sharedprefix_cache_on_ttft_p50,{s_on['ttft_p50_s'] * 1e6:.0f},"
        f"cached_p50_s={s_on['ttft_cached_p50_s']:.4f}"
        f" uncached_p50_s={s_on['ttft_uncached_p50_s']:.4f}",
        f"serve_sharedprefix_cache_off_ttft_p50,"
        f"{s_off['ttft_p50_s'] * 1e6:.0f},baseline",
        f"serve_sharedprefix_prefill_tokens_saved,"
        f"{s_on['prefill_tokens_saved']},"
        f"of_total={s_on['prompt_tokens']}",
        f"serve_sharedprefix_hit_rate_pct,"
        f"{s_on['prefix_hit_rate'] * 100:.1f},target>=30",
        f"serve_sharedprefix_outputs_identical,{identical},"
        f"token-for-token vs cache-off",
    ]
    assert identical, "prefix cache changed decoded tokens"
    assert s_on["prefix_hit_rate"] >= 0.30, s_on["prefix_hit_rate"]
    return rows


def analytic_itl(arch: str, tp: int, batch: int, ctx: int) -> float:
    """Decode step latency (s) on v5e: max(weights+KV reads / HBM, flops)."""
    cfg = get_config(arch)
    w_bytes = cfg.param_count() * 2 / tp
    kv_per_tok = (cfg.kv_cache_bytes_per_token_per_layer
                  * len(cfg.attn_layer_ids()))
    kv_bytes = kv_per_tok * ctx * batch / tp
    t_mem = (w_bytes + kv_bytes) / HBM_BW
    t_flops = 2 * cfg.param_count(active_only=True) * batch / (tp * PEAK)
    return max(t_mem, t_flops)


def analytic_rows() -> List[str]:
    rows = []
    for arch, tp, paper_ms in (("apertus-8b", 4, 11.0),
                               ("apertus-70b", 8, 42.0)):
        itl = analytic_itl(arch, tp, batch=8, ctx=1024)
        rows.append(f"serve_analytic_itl_{arch},{itl * 1e6:.0f},"
                    f"paper_ms={paper_ms} (GH200; v5e-chips={tp})")
    return rows


def run() -> List[str]:
    return measured_rows() + shared_prefix_rows() + analytic_rows()


if __name__ == "__main__":
    print("\n".join(run()))
