"""Paper §5.2: serving metrics (QPS, TTFT, ITL, E2EL) + paged-KV A/B.

Reproductions:
1. measured: the continuous-batching engine on a tiny model on CPU, with
   the paper's two workload mixes (70B-style: medium prompts / moderate
   responses; 8B-style: short prompts / long-form generation) scaled down.
   Reproduces the paper's qualitative finding: the long-generation mix has
   far higher E2EL despite lower per-token latency pressure.  Rows include
   decode tokens/sec and peak KV blocks in use.
2. analytic: ITL for Apertus-8B/70B-class configs on the v5e target from
   the decode roofline (paper reference points: ~11 ms and ~42 ms).
3. shared-system-prompt mix: the multi-tenant gateway pattern (every
   request of a project carries the same long system prefix) with the
   radix prefix cache on vs. off — reports TTFT, prefill tokens saved,
   and hit rate, and checks decoded outputs are identical
   token-for-token across cache on/off AND across the paged/dense KV
   layouts (see src/repro/serving/README.md).
4. paged-vs-dense: same total KV budget, same per-request capacity — the
   paged engine allocates blocks on demand, so it sustains a larger
   concurrent decode batch than the dense engine (which pins
   max_batch x capacity up front) and reports decode tokens/sec for both.
5. multi-adapter mix: 4 tenants' LoRA adapters + base-model requests in
   ONE continuous decode batch (the S-LoRA pattern behind the paper's
   shared fine-tune/serve platform).  Acceptance: every request's output
   is token-identical to a single-tenant run on that adapter's
   ``lora_merge``d weights; also reports A/B decode tokens/sec vs the
   merge-and-redeploy alternative and the pool's load/evict counters
   under slot pressure.
6. speculative decoding A/B: the same repetitive-prompt mix (the
   code/RAG shape prompt-lookup thrives on) through the baseline
   engine, the n-gram drafter, and a draft-model drafter, on paged GQA
   *and* paged MLA.  Acceptance: temperature-0 outputs token-identical
   to the baseline for every drafter/architecture pair; rows report
   acceptance rate, tokens-per-launch, and decode tokens/sec vs
   baseline.

7. chaos mix: two replicas behind the resilient gateway on a virtual
   clock; a deterministic fault injector kills one mid-decode.  The
   gateway's breaker opens, the evacuated request retries onto the
   survivor token-exactly, and after recovery a half-open probe
   re-closes the circuit.  Acceptance: 100% completion, temp-0 token
   identity to a fault-free run, breaker open AND re-close observed in
   the metrics snapshot, zero real sleeps (docs/robustness.md).
8. sharded serving (tensor parallelism): the same greedy mix through a
   TP=2 mesh-aware engine and the single-device engine.  Acceptance: a
   HARD token-identity assert (serving/README.md "Sharded serving"),
   plus decode tokens/s and per-device KV bytes rows (the head-sharded
   paged pool halves per-device KV at TP=2).  Needs two devices; run as
   a CLI the module forces two XLA host devices before jax loads, so
   the rows are live even on a one-CPU CI runner.

9. kv-quant A/B: the same greedy mix through a bf16-KV and an int8-KV
   paged engine at a fixed pool_tokens budget.  The int8 pool carries
   2x the blocks in the same device bytes, so it sustains >= 1.8x the
   concurrent decode batch; greedy tokens must agree with the bf16 run
   at >= 90% (the accuracy-guard floor; see serving/README.md
   "Quantized serving").

CLI: ``--paged`` (default) / ``--dense`` select the KV layout for the
measured mixes; ``--smoke`` runs the fast subset (3 + 4 + 5 + 6 + 7 +
8 + 9) for CI; ``--chaos-smoke`` runs only mix 7 (the CI chaos job);
``--kv-quant-smoke`` runs only mix 9 (the CI kv-quant job);
``--json PATH`` additionally writes the rows as a machine-readable
artifact (uploaded by the CI workflow).
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import sys
from typing import List, Optional, Tuple

# mix 8 needs >= 2 devices; on the usual 1-CPU runner force two XLA host
# devices — must happen before jax's first import (harmless for every
# other mix: their engines are mesh-free and compile single-device
# modules on device 0).  When another module imported jax first (e.g. a
# test importing this file) the flag is too late; sharded_rows then
# degrades to an explicit skip row instead of asserting.
if "jax" not in sys.modules and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=2").strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, scaled_down
from repro.models import model as M
from repro.serving.engine import InferenceEngine, Request
from repro.serving.scheduler import SchedulerConfig

# v5e-per-chip constants (same as launch.dryrun)
HBM_BW = 819e9
PEAK = 197e12

_STATE = {}


def _tiny():
    if "cfg" not in _STATE:
        cfg = scaled_down(get_config("apertus-8b"), num_layers=2, d_model=64,
                          d_ff=128, vocab_size=256, num_heads=2,
                          num_kv_heads=2, head_dim=32)
        _STATE["cfg"] = cfg
        _STATE["params"] = M.init(cfg, jax.random.PRNGKey(0))
    return _STATE["cfg"], _STATE["params"]


def _mk_engine(max_batch=4, capacity=160, sched=None, paged=None,
               pool_tokens=None):
    cfg, params = _tiny()
    return InferenceEngine(cfg, params, max_batch=max_batch,
                           capacity=capacity, sched=sched, paged=paged,
                           pool_tokens=pool_tokens)


def _mix(engine, rng, n_req, prompt_rng, gen_rng):
    reqs = []
    for _ in range(n_req):
        p = int(rng.integers(*prompt_rng))
        g = int(rng.integers(*gen_rng))
        r = Request(prompt=list(rng.integers(1, 255, p)), max_new_tokens=g)
        reqs.append(r)
        engine.submit(r)
    return engine.run_until_idle()


def measured_rows(paged: Optional[bool] = None) -> List[str]:
    rng = np.random.default_rng(0)
    tag_kv = "paged" if (paged or paged is None) else "dense"
    # 70B-style mix: prompts 100-800 -> 10-80; responses 200-500 -> 20-50
    e1 = _mk_engine(paged=paged)
    s1 = _mix(e1, rng, 12, (10, 80), (20, 50))
    # 8B-style mix: prompts <200 -> <20; long-form 3000+ -> 100+
    e2 = _mk_engine(capacity=192, paged=paged)
    s2 = _mix(e2, rng, 12, (4, 20), (100, 128))
    rows = []
    for tag, s, e in (("mix70b", s1, e1), ("mix8b_longform", s2, e2)):
        kv = e.kv_stats()
        rows.append(f"serve_{tag}_ttft_p50,{s['ttft_p50_s'] * 1e6:.0f},"
                    f"p99_s={s['ttft_p99_s']:.3f}")
        rows.append(f"serve_{tag}_itl_mean,{s['itl_mean_s'] * 1e6:.0f},"
                    f"tokens={s['generated_tokens']}")
        rows.append(f"serve_{tag}_e2el_mean,{s['e2el_mean_s'] * 1e6:.0f},"
                    f"qps={s['qps']:.3f}")
        rows.append(f"serve_{tag}_decode_tokens_per_s,"
                    f"{s['tokens_per_s']:.1f},kv={tag_kv}")
        rows.append(f"serve_{tag}_kv_blocks_peak,{kv['kv_blocks_peak']},"
                    f"of_total={kv['kv_blocks_total']}"
                    f" block_tokens={kv['kv_block_size']}")
    # paper's qualitative claim: long-form mix E2EL >> medium mix E2EL
    ratio = s2["e2el_mean_s"] / s1["e2el_mean_s"]
    rows.append(f"serve_longform_e2el_ratio,{ratio * 1e6:.0f},"
                f"paper=31.4s_vs_5.84s (5.4x)")
    return rows


def shared_prefix_rows() -> List[str]:
    """Multi-tenant shared-system-prompt mix: prefix cache on vs. off and
    paged vs. dense KV.

    Every request of the project carries the same 48-token system prompt
    plus a short unique user turn — the dominant pattern behind the
    paper's shared gateway.  Acceptance: >= 30% of prefill tokens served
    from cache, outputs token-identical across cache on/off AND across
    the paged/dense layouts (the paged hit is copy-free: physical blocks
    are refcount-spliced into the request's block table)."""
    rng = np.random.default_rng(7)
    system = list(map(int, rng.integers(1, 255, 48)))
    prompts = [system + list(map(int, rng.integers(1, 255,
                                                   int(rng.integers(8, 24)))))
               for _ in range(12)]
    outs, sums, engines = {}, {}, {}
    cases = [("paged_on", True, True), ("paged_off", True, False),
             ("dense_on", False, True)]
    for name, paged, cache_on in cases:
        eng = _mk_engine(capacity=192, paged=paged, sched=SchedulerConfig(
            enable_prefix_cache=cache_on, prefix_block=8, prefill_chunk=32))
        reqs = [Request(prompt=list(p), max_new_tokens=24,
                        namespace="proj") for p in prompts]
        for r in reqs:
            eng.submit(r)
        sums[name] = eng.run_until_idle()
        outs[name] = [r.generated for r in reqs]
        engines[name] = eng
    identical = int(outs["paged_on"] == outs["paged_off"])
    paged_eq_dense = int(outs["paged_on"] == outs["dense_on"])
    s_on, s_off = sums["paged_on"], sums["paged_off"]
    kv_on = engines["paged_on"].kv_stats()
    rows = [
        f"serve_sharedprefix_cache_on_ttft_p50,{s_on['ttft_p50_s'] * 1e6:.0f},"
        f"cached_p50_s={s_on['ttft_cached_p50_s']:.4f}"
        f" uncached_p50_s={s_on['ttft_uncached_p50_s']:.4f}",
        f"serve_sharedprefix_cache_off_ttft_p50,"
        f"{s_off['ttft_p50_s'] * 1e6:.0f},baseline",
        f"serve_sharedprefix_prefill_tokens_saved,"
        f"{s_on['prefill_tokens_saved']},"
        f"of_total={s_on['prompt_tokens']}",
        f"serve_sharedprefix_hit_rate_pct,"
        f"{s_on['prefix_hit_rate'] * 100:.1f},target>=30",
        f"serve_sharedprefix_decode_tokens_per_s,"
        f"{s_on['tokens_per_s']:.1f},kv=paged",
        f"serve_sharedprefix_kv_blocks_peak,{kv_on['kv_blocks_peak']},"
        f"shared_blocks_counted_once block_tokens={kv_on['kv_block_size']}",
        f"serve_sharedprefix_outputs_identical,{identical},"
        f"token-for-token vs cache-off",
        f"serve_sharedprefix_paged_equals_dense,{paged_eq_dense},"
        f"token-for-token vs dense KV",
    ]
    assert identical, "prefix cache changed decoded tokens"
    assert paged_eq_dense, "paged KV changed decoded tokens"
    assert s_on["prefix_hit_rate"] >= 0.30, s_on["prefix_hit_rate"]
    return rows


def paged_vs_dense_rows(smoke: bool = False) -> List[str]:
    """Same KV budget (1024 cache tokens), same per-request capacity
    (256): the dense layout can only preallocate 4 slots; the paged
    layout runs 8 slots over an on-demand pool and serves short requests
    at twice the concurrency."""
    budget, capacity = 1024, 256
    gen = 12 if smoke else 24
    n_req = 8
    sched = SchedulerConfig(enable_prefix_cache=False, admit_per_tick=8,
                            prefill_chunk=32)
    rng = np.random.default_rng(11)
    prompts = [list(map(int, rng.integers(1, 255, 12))) for _ in range(n_req)]
    res = {}
    for mode, paged, mb in (("dense", False, budget // capacity),
                            ("paged", True, 8)):
        eng = _mk_engine(max_batch=mb, capacity=capacity, sched=sched,
                         paged=paged,
                         pool_tokens=budget if paged else None)
        reqs = [Request(prompt=list(p), max_new_tokens=gen) for p in prompts]
        for r in reqs:
            eng.submit(r)
        peak = 0
        while eng.num_active:
            eng.step()
            peak = max(peak, len(eng.running))
        s = eng.metrics.summary()
        kv = eng.kv_stats()
        res[mode] = (peak, s, kv, [r.generated for r in reqs])
    rows = []
    for mode in ("dense", "paged"):
        peak, s, kv, _ = res[mode]
        rows.append(
            f"serve_{mode}_concurrent_batch_peak,{peak},"
            f"budget_tokens={budget} capacity={capacity}")
        rows.append(
            f"serve_{mode}_decode_tokens_per_s,{s['tokens_per_s']:.1f},"
            f"generated={s['generated_tokens']}")
        rows.append(
            f"serve_{mode}_kv_blocks_peak,{kv['kv_blocks_peak']},"
            f"block_tokens={kv['kv_block_size']}"
            f" peak_kv_tokens={kv['kv_blocks_peak'] * kv['kv_block_size']}")
    assert res["paged"][3] == res["dense"][3], \
        "paged KV changed decoded tokens"
    assert res["paged"][0] > res["dense"][0], (
        f"paged sustained {res['paged'][0]} concurrent <= "
        f"dense {res['dense'][0]} under the same budget")
    rows.append(f"serve_paged_batch_gain,"
                f"{res['paged'][0] / res['dense'][0]:.2f},"
                f"paged_peak/dense_peak under equal KV budget")
    return rows


def multi_adapter_rows(smoke: bool = False) -> List[str]:
    """Multi-tenant LoRA mix: 4 distinct adapters + base requests in one
    decode batch, validated token-for-token against per-adapter
    ``lora_merge``d single-tenant runs (same engine machinery, merged
    weights — the A/B the merge-and-redeploy alternative would serve)."""
    from repro.finetune.lora import (LoraConfig, lora_init, lora_merge,
                                     lora_randomize)
    cfg, params = _tiny()
    lcfg = LoraConfig(rank=4)
    n_adapters, gen = 4, (10 if smoke else 20)
    ads = {f"tenant{i}": lora_randomize(
        lora_init(params, lcfg, jax.random.PRNGKey(50 + i)),
        jax.random.PRNGKey(150 + i)) for i in range(n_adapters)}
    # slot pressure: fewer device slots than adapters, so the mix also
    # exercises load + LRU eviction mid-run
    eng_ml = InferenceEngine(cfg, params, max_batch=4, capacity=160,
                             adapter_slots=3)
    for name, ad in ads.items():
        eng_ml.register_adapter(name, ad, lcfg)
    rng = np.random.default_rng(23)
    names = (list(ads) + [""]) * 2          # 8 adapter'd + 2 base
    prompts = [list(map(int, rng.integers(1, 255,
                                          int(rng.integers(8, 20)))))
               for _ in names]
    reqs = [Request(prompt=list(p), max_new_tokens=gen, adapter=nm)
            for p, nm in zip(prompts, names)]
    for r in reqs:
        eng_ml.submit(r)
    s = eng_ml.run_until_idle()
    merged = {nm: lora_merge(params, ad, lcfg) for nm, ad in ads.items()}
    merged[""] = params
    # A/B: the merge-and-redeploy alternative serves each variant's
    # requests on its own merged-weights engine (same total work, no
    # sharing) — and is the token-identity baseline for the mixed batch
    identical, t_nonshared = True, 0.0
    for nm in [""] + list(ads):
        e = InferenceEngine(cfg, merged[nm], max_batch=4, capacity=160)
        pairs = [(p, r) for p, n2, r in zip(prompts, names, reqs)
                 if n2 == nm]
        sub = [Request(prompt=list(p), max_new_tokens=gen)
               for p, _ in pairs]
        for r in sub:
            e.submit(r)
        t_nonshared += e.run_until_idle()["e2el_mean_s"] * len(sub)
        identical &= all(r.generated == mixed.generated
                         for r, (_, mixed) in zip(sub, pairs))
    st = eng_ml.adapter_stats()
    rows = [
        f"serve_multilora_outputs_identical,{int(identical)},"
        f"token-for-token vs per-adapter lora_merge",
        f"serve_multilora_decode_tokens_per_s,{s['tokens_per_s']:.1f},"
        f"adapters={n_adapters}+base in one batch",
        f"serve_multilora_e2el_mean,{s['e2el_mean_s'] * 1e6:.0f},"
        f"merged_per_tenant_sum={t_nonshared * 1e6:.0f}",
        f"serve_multilora_pool,{st['loads']},loads "
        f"evictions={st['evictions']} slots={st['slots']}"
        f" registered={st['registered']}",
    ]
    assert identical, "multi-LoRA decode diverged from merged baselines"
    assert st["evictions"] > 0, "slot pressure never exercised eviction"
    return rows


def _tiny_mla():
    if "mla_cfg" not in _STATE:
        cfg = scaled_down(get_config("deepseek-v2-lite-16b"), num_layers=2,
                          d_model=64, d_ff=128, vocab_size=256, num_heads=2)
        _STATE["mla_cfg"] = cfg
        _STATE["mla_params"] = M.init(cfg, jax.random.PRNGKey(2))
    return _STATE["mla_cfg"], _STATE["mla_params"]


def speculative_rows(smoke: bool = False) -> List[str]:
    """Speculative decoding A/B (ISSUE 4 acceptance bar).

    A repetitive-prompt workload (a shared boilerplate block + short
    unique tail — the shape of code-edit/RAG/summarisation traffic)
    decoded greedily through (a) the baseline engine, (b) the n-gram /
    prompt-lookup drafter, (c) a draft-model drafter — on paged GQA, and
    (a)+(b) again on paged MLA.  Every speculative run must be
    token-identical to its baseline (temperature 0 makes accept/reject
    an exact argmax match, so this is a hard assert, not a tolerance).
    The draft model here is the target itself ("self-draft"): it bounds
    the machinery's best case (acceptance ~1, tokens/launch -> k+1) with
    zero training dependencies; realistic draft pairs plug in via
    ``launch/serve.py --speculative draft --draft-config ...``.
    """
    gen = 16 if smoke else 32
    spec_k = 4
    rng = np.random.default_rng(17)

    def mk_prompts(vocab):
        pat = list(map(int, rng.integers(1, vocab - 1, 8)))
        return [pat * 4 + list(map(int, rng.integers(1, vocab - 1, 3)))
                for _ in range(6)]

    def run(cfg, params, prompts, **kw):
        eng = InferenceEngine(cfg, params, max_batch=4, capacity=192,
                              sched=SchedulerConfig(prefill_chunk=32,
                                                    prefix_block=8), **kw)
        reqs = [Request(prompt=list(p), max_new_tokens=gen)
                for p in prompts]
        for r in reqs:
            eng.submit(r)
        s = eng.run_until_idle()
        return [r.generated for r in reqs], s

    rows = []
    for tag, (cfg, params) in (("gqa", _tiny()), ("mla", _tiny_mla())):
        prompts = mk_prompts(cfg.vocab_size)
        base, sb = run(cfg, params, prompts)
        cases = [("ngram", dict(speculative="ngram", spec_k=spec_k))]
        if tag == "gqa":
            cases.append(("draft", dict(speculative="draft", spec_k=spec_k,
                                        draft_cfg=cfg,
                                        draft_params=params)))
        for name, kw in cases:
            out, s = run(cfg, params, prompts, **kw)
            ident = int(out == base)
            rows.append(
                f"serve_spec_{tag}_{name}_acceptance_rate,"
                f"{s['spec_acceptance_rate'] * 100:.1f},"
                f"pct k={spec_k}")
            rows.append(
                f"serve_spec_{tag}_{name}_tokens_per_launch,"
                f"{s['spec_tokens_per_launch']:.2f},baseline=1.0")
            rows.append(
                f"serve_spec_{tag}_{name}_decode_tokens_per_s,"
                f"{s['tokens_per_s']:.1f},"
                f"baseline={sb['tokens_per_s']:.1f}")
            rows.append(
                f"serve_spec_{tag}_{name}_outputs_identical,{ident},"
                f"token-for-token vs non-speculative at temperature 0")
            assert ident, (
                f"speculative ({tag}/{name}) changed greedy tokens")
            assert s["spec_tokens_per_launch"] >= 1.0
    return rows


def observability_rows(smoke: bool = False) -> List[str]:
    """ISSUE 6 acceptance: lifecycle observability through the full
    gateway -> engine -> scheduler stack, plus an instrumentation
    overhead A/B.

    (a) a multi-tenant mix runs through a ``Gateway(obs=...)``; one
        ``collect_metrics`` snapshot must carry scheduler, KV-pool,
        prefix-cache, serving-latency, and per-tenant gateway series in
        Prometheus text form;
    (b) the Perfetto trace must round-trip ``json.loads`` and
        reconstruct at least one request's full lifecycle
        (queued -> prefill -> decode -> finish, in order, on one track);
    (c) instrumentation must cost < 2% of the uninstrumented decode
        tokens/s — measured by attribution (exact instrument-op counts
        from an obs-on run x tight-loop per-op costs, over the obs-off
        run time), because direct run-vs-run wall-clock deltas have a
        +-5% null spread on a contended CI core.
    The snapshot + trace are kept in ``_STATE`` so ``--json`` can write
    them as sibling CI artifacts."""
    import time

    from repro.core.gateway import Gateway, ModelEntry
    from repro.obs import Observability

    cfg, params = _tiny()
    sched = SchedulerConfig(prefill_chunk=32, prefix_block=8)
    gen = 12 if smoke else 24
    rng = np.random.default_rng(41)
    system = list(map(int, rng.integers(1, 255, 24)))
    prompts = [system + list(map(int, rng.integers(1, 255, 6)))
               for _ in range(8)]

    # (a)+(b): governed mix with obs attached
    obs = Observability()
    eng = InferenceEngine(cfg, params, max_batch=4, capacity=192,
                          sched=sched, obs=obs)
    gw = Gateway(obs=obs)
    gw.vet_model(ModelEntry(cfg.name, cfg.name, 0.5, 1.5), cfg)
    gw.bind_endpoints(cfg.name, [eng])
    keys = {p: gw.mint_key(p) for p in ("tenant-a", "tenant-b")}
    rids = []
    for i, p in enumerate(prompts):
        proj = "tenant-a" if i % 2 == 0 else "tenant-b"
        out = gw.completion(api_key=keys[proj].key, model=cfg.name,
                            prompt=list(p), max_tokens=gen)
        rids.append(out["id"])
    gw.collect_metrics()
    prom = obs.registry.to_prometheus()
    lines = prom.splitlines()
    subsystems = ("repro_sched_", "repro_kv_", "repro_prefix_",
                  "repro_serving_", "repro_gateway_")
    n_series = {}
    for pre in subsystems:
        # sample lines only (HELP/TYPE lines start with '#')
        n_series[pre] = sum(1 for ln in lines if ln.startswith(pre))
        assert n_series[pre] > 0, f"snapshot missing {pre}* series"
    assert 'project="tenant-a"' in prom and 'project="tenant-b"' in prom, \
        "per-tenant gateway accounting missing from snapshot"

    trace_text = obs.tracer.to_json()
    trace = json.loads(trace_text)            # must round-trip
    ev = trace["traceEvents"]
    tid_name = {e["tid"]: e["args"]["name"] for e in ev
                if e.get("ph") == "M" and e.get("name") == "thread_name"}
    lifecycle_ok = 0
    for rid in rids:
        tids = [t for t, nm in tid_name.items() if nm == f"req {rid}"]
        if not tids:
            continue
        spans = sorted((e["ts"], e["name"]) for e in ev
                       if e.get("ph") == "X" and e["tid"] == tids[0])
        names = [n for _, n in spans]
        insts = [e["name"] for e in ev
                 if e.get("ph") == "i" and e["tid"] == tids[0]]
        if (names and names[0] == "queued" and "prefill" in names
                and "decode" in names
                and names.index("prefill") < names.index("decode")
                and "finish" in insts):
            lifecycle_ok += 1
    assert lifecycle_ok == len(rids), (
        f"only {lifecycle_ok}/{len(rids)} request lifecycles "
        f"reconstructed from the trace")
    _STATE["obs_artifacts"] = (prom, trace_text)

    # (c): instrumentation overhead, obs on vs off.  Direct wall-clock
    # A/B between two separate engine runs cannot resolve 2% on a
    # contended CI core: a null experiment (off vs off, alternating
    # order, median/min of 12 process_time runs each) still shows a
    # +-5% spread, so any direct-delta assert at 2% is a coin flip.
    # The overhead is therefore measured by ATTRIBUTION, which is exact
    # and noise-robust:
    #   1. run the instrumented engine once and count the instrument
    #      ops it actually performed (span X-events + instants from the
    #      trace; histogram observes, gauge sets, counter incs from
    #      registry snapshot diffs — every push op is one of these);
    #   2. microbenchmark each op in a tight loop (min of several
    #      passes of process_time: contention noise is one-sided);
    #   3. overhead = sum(count * cost) / uninstrumented run time.
    # Noise enters only multiplicatively on an already-small ratio
    # (+-10% on ~0.6% stays ~0.6%), instead of additively on a delta of
    # two large numbers.  Both arms' measured tokens/s are reported
    # alongside for reference, with a loose 25% sanity band.
    import gc

    from repro.obs.registry import MetricsRegistry
    from repro.obs.tracer import Tracer
    gen_ab = 48

    def mk(obs_on: bool):
        return InferenceEngine(cfg, params, max_batch=4, capacity=192,
                               sched=SchedulerConfig(prefill_chunk=32,
                                                     prefix_block=8),
                               obs=Observability() if obs_on else None)

    def run_once(e) -> Tuple[int, float]:
        reqs = [Request(prompt=list(p), max_new_tokens=gen_ab)
                for p in prompts]
        for r in reqs:
            e.submit(r)
        gc.collect()
        t0 = time.process_time()
        e.run_until_idle()
        dt = time.process_time() - t0
        return sum(len(r.generated) for r in reqs), dt

    e_off, e_on = mk(False), mk(True)
    run_once(e_off), run_once(e_on)           # compile + cache warmup

    # 1. op counts from one instrumented run (trace + snapshot diffs)
    def ph_counts(tr):
        evs = tr.to_perfetto()["traceEvents"]
        return (sum(1 for e in evs if e.get("ph") == "X"),
                sum(1 for e in evs if e.get("ph") in ("i", "C")))

    o = e_on.obs
    kinds = o.registry.kinds()
    x0, i0 = ph_counts(o.tracer)
    snap0 = o.registry.snapshot()
    ntok, _ = run_once(e_on)
    x1, i1 = ph_counts(o.tracer)
    snap1 = o.registry.snapshot()

    def series_kind(key):
        return kinds.get(key.split("{", 1)[0], "gauge")

    n_observe = n_inc = 0
    tick_key = "repro_sched_tick_seconds"
    for key, v1 in snap1.items():
        v0 = snap0.get(key, {"count": 0} if isinstance(v1, dict) else 0.0)
        if isinstance(v1, dict):
            n_observe += v1["count"] - v0["count"]
        elif series_kind(key) == "counter":
            # every push-side counter inc is +1, so the value delta IS
            # the call count (pull-side .set()s only happen at
            # collect_metrics, which this run never calls)
            n_inc += int(v1 - v0)
    # gauges are set absolutely so snapshots can't be diffed for call
    # counts; the only per-run gauge sets are queue+running, twice per
    # tick
    n_ticks = (snap1[tick_key]["count"] - snap0[tick_key]["count"])
    counts = {"span": x1 - x0, "instant": i1 - i0,
              "observe": n_observe, "set": 2 * n_ticks, "inc": n_inc}

    # 2. per-op tight-loop costs (scratch tracer/registry, min-of-k)
    def bench(fn, n=5000 if smoke else 20000, passes=3 if smoke else 5):
        best = float("inf")
        for _ in range(passes):
            t0 = time.process_time()
            for _ in range(n):
                fn()
            best = min(best, (time.process_time() - t0) / n)
        return best

    st = Tracer(max_events=10_000_000)

    def op_span():
        sp = st.begin("scheduler", "micro_step", cat="sched",
                      decoding=3, prefilling=1)
        st.end(sp)

    sreg = MetricsRegistry()
    sh = sreg.histogram("repro_sched_tick_seconds", "bench")
    sg = sreg.gauge("repro_sched_queue_depth_requests", "bench")
    sc = sreg.counter("repro_sched_admitted_requests_total", "bench")
    costs = {
        "span": bench(op_span),
        "instant": bench(lambda: st.instant("req 0", "finish",
                                            cat="request", n_generated=1)),
        "observe": bench(lambda: sh.observe(0.013)),
        "set": bench(lambda: sg.set(5)),
        "inc": bench(lambda: sc.inc()),
    }

    # 3. attribute against the uninstrumented run (min: noise only adds)
    n_runs = 3 if smoke else 5
    t_off = min(run_once(e_off)[1] for _ in range(n_runs))
    t_on = min(run_once(e_on)[1] for _ in range(n_runs))
    extra = sum(counts[k] * costs[k] for k in counts)
    delta = extra / t_off
    tps_off, tps_on = ntok / t_off, ntok / t_on
    rows = [
        f"serve_obs_snapshot_series,{sum(n_series.values())},"
        + " ".join(f"{p.rstrip('_')}={n}" for p, n in n_series.items()),
        f"serve_obs_trace_events,{len(ev)},"
        f"lifecycles_reconstructed={lifecycle_ok}/{len(rids)}",
        f"serve_obs_overhead_pct,{delta * 100:.2f},"
        f"{sum(counts.values())} instrument ops (span={counts['span']}"
        f" observe={counts['observe']}) x tight-loop cost"
        f" / {t_off * 1e3:.0f}ms uninstrumented run; target<2",
        f"serve_obs_tps,{tps_on:.0f},on vs {tps_off:.0f} off"
        f" tokens_per_s (cpu-time, best of {n_runs}; reference only)",
    ]
    assert delta < 0.02, (
        f"observability overhead {delta * 100:.2f}% >= 2% "
        f"(counts={counts}, costs(us)="
        f"{ {k: round(v * 1e6, 2) for k, v in costs.items()} }, "
        f"t_off={t_off * 1e3:.1f}ms)")
    assert tps_on > 0.75 * tps_off, (
        f"instrumented engine tokens/s sanity band blown: "
        f"on={tps_on:.0f} off={tps_off:.0f}")
    return rows


def chaos_rows(smoke: bool = False) -> List[str]:
    """ISSUE 7 acceptance: serving-plane fault tolerance, end to end.

    Two engine replicas behind the resilient gateway on a VIRTUAL
    clock; a deterministic injector kills replica e0 mid-decode of one
    request.  The gateway must ride through it — breaker opens, the
    evacuated request (committed tokens folded into its prompt) retries
    onto e1 and resumes token-exactly — and, after e0 recovers and the
    breaker cooldown elapses, a half-open probe must re-close the
    circuit and return traffic to e0.  Hard asserts: 100% of requests
    complete, temp-0 outputs token-identical to a fault-free run, the
    breaker is seen opening AND re-closing in the metrics snapshot, and
    ``time.sleep`` is patched to raise for the whole run (retry backoff
    must use the injected clock only)."""
    import time

    from repro.core.gateway import Gateway, ModelEntry
    from repro.obs import Observability
    from repro.serving.faults import FaultInjector, FaultSpec, VirtualClock

    cfg, params = _tiny()
    gen = 8 if smoke else 12
    n_req = 8 if smoke else 12
    rng = np.random.default_rng(29)
    prompts = [list(map(int, rng.integers(1, 255,
                                          int(rng.integers(6, 12)))))
               for _ in range(n_req)]

    def serve(gw, key):
        outs = []
        for p in prompts:
            out = gw.completion(api_key=key.key, model=cfg.name,
                                prompt=list(p), max_tokens=gen)
            outs.append((out["tokens"], out["usage"]["engine"]))
        return outs

    # fault-free reference (token-identity baseline; routing is
    # irrelevant to greedy outputs — every replica holds the same
    # weights)
    e_ref = _mk_engine(capacity=192)
    gw_ref = Gateway()
    gw_ref.vet_model(ModelEntry(cfg.name, cfg.name, 0.5, 1.5), cfg)
    gw_ref.bind_endpoints(cfg.name, [e_ref])
    ref = [t for t, _ in serve(gw_ref, gw_ref.mint_key("chaos"))]

    # chaos run: crash e0 mid-decode of its 3rd request (each request
    # costs gen-1 micro-step fault checks after its one-shot prefill)
    at_call = 2 * (gen - 1) + 4
    vc = VirtualClock()
    obs = Observability(clock=vc.now)
    inj = FaultInjector(
        [FaultSpec(point="micro_step", kind="crash", at_call=at_call)],
        clock_advance=vc.advance)
    cfg_, params_ = _tiny()
    e0 = InferenceEngine(cfg_, params_, max_batch=4, capacity=192,
                         clock=vc, name="chaos-e0", faults=inj)
    e1 = InferenceEngine(cfg_, params_, max_batch=4, capacity=192,
                         clock=vc, name="chaos-e1")
    gw = Gateway(clock=vc, obs=obs, retry_budget=3, breaker_threshold=1,
                 breaker_cooldown_s=5.0, sleep=vc.sleep)
    gw.vet_model(ModelEntry(cfg.name, cfg.name, 0.5, 1.5), cfg)
    gw.bind_endpoints(cfg.name, [e0, e1])
    key = gw.mint_key("chaos")

    def no_real_sleep(_dt):
        raise AssertionError("real time.sleep in the retry/backoff path")

    outs, engines, recovered_after = [], [], None
    orig_sleep, time.sleep = time.sleep, no_real_sleep
    try:
        for i, p in enumerate(prompts):
            out = gw.completion(api_key=key.key, model=cfg.name,
                                prompt=list(p), max_tokens=gen)
            outs.append(out["tokens"])
            engines.append(out["usage"]["engine"])
            if e0.health() == "down" and recovered_after is None:
                # the "operator" restarts the dead replica; advancing
                # past the breaker cooldown arms the half-open probe
                e0.recover()
                vc.advance(gw.breaker_cooldown_s + 1.0)
                recovered_after = i
    finally:
        time.sleep = orig_sleep

    snap = obs.registry.snapshot()
    tr = {s: snap[s] for s in snap
          if s.startswith("repro_gateway_breaker_transitions_total")}
    n_open = sum(v for s, v in tr.items() if 'state="open"' in s)
    n_closed = sum(v for s, v in tr.items() if 'state="closed"' in s)
    final_state = snap.get(
        'repro_gateway_breaker_state{engine="chaos-e0"}', -1)
    n_retries = sum(v for s, v in snap.items()
                    if s.startswith("repro_serving_retries_total"))
    n_preempted = e0.metrics.summary()["preempted"]
    identical = int(outs == ref)
    failed_over = int("chaos-e1" in engines)
    returned = int(recovered_after is not None
                   and "chaos-e0" in engines[recovered_after + 1:])
    rows = [
        f"serve_chaos_completed,{len(outs)}/{n_req},"
        f"one of two engines crashed at micro-step {at_call}",
        f"serve_chaos_outputs_identical,{identical},"
        f"token-for-token vs fault-free run at temperature 0",
        f"serve_chaos_retries,{n_retries:.0f},"
        f"failed_over_to_e1={failed_over} budget=3",
        f"serve_chaos_preempted,{n_preempted:.0f},"
        f"committed tokens folded into the prompt on evacuation",
        f"serve_chaos_breaker_reclosed,{int(n_closed >= 1)},"
        f"open={n_open:.0f} closed={n_closed:.0f}"
        f" final_state={final_state:.0f}"
        f" traffic_returned_to_e0={returned}",
    ]
    assert len(outs) == n_req, f"only {len(outs)}/{n_req} completed"
    assert identical, "chaos run changed temp-0 tokens"
    assert inj.fired, "the injected crash never fired"
    assert n_retries >= 1 and failed_over, "gateway never retried"
    assert n_preempted >= 1, "crash evacuation never folded tokens"
    assert n_open >= 1 and n_closed >= 1, (
        f"breaker not seen opening AND re-closing: {tr}")
    assert final_state == 0 and returned, (
        "recovered engine never re-earned traffic")
    return rows


def sharded_rows(smoke: bool = False) -> List[str]:
    """ISSUE 8 acceptance: tensor-parallel serving token identity.

    The same greedy mix through a TP=2 engine (``("model",)`` mesh,
    serving_tp rules) and the plain single-device engine.  Token
    identity is a hard assert — TP reshards contractions, so this is
    the row that catches a rules/constraint regression; tokens/s is
    reported for parity (two forced host devices share one CPU, so no
    speedup is claimed), and per-device KV bytes shows the head-sharded
    pool halving each device's KV footprint."""
    if jax.device_count() < 2:
        return ["serve_tp_skipped,1,needs >=2 devices (CLI runs force "
                "2 host devices; in-process imports may be too late)"]
    cfg, params = _tiny()
    gen = 10 if smoke else 20
    rng = np.random.default_rng(31)
    prompts = [list(map(int, rng.integers(1, 255,
                                          int(rng.integers(6, 16)))))
               for _ in range(6)]

    def go(mesh):
        eng = InferenceEngine(cfg, params, max_batch=4, capacity=192,
                              mesh=mesh)
        reqs = [Request(prompt=list(p), max_new_tokens=gen)
                for p in prompts]
        for r in reqs:
            eng.submit(r)
        s = eng.run_until_idle()
        return [r.generated for r in reqs], s, eng.kv_stats()

    base, s1, kv1 = go(None)
    tp, s2, kv2 = go(jax.make_mesh((2,), ("model",)))
    identical = int(base == tp)
    rows = [
        f"serve_tp2_outputs_identical,{identical},"
        f"token-for-token vs TP=1 at temperature 0 (hard assert)",
        f"serve_tp2_decode_tokens_per_s,{s2['tokens_per_s']:.1f},"
        f"tp1={s1['tokens_per_s']:.1f} (2 host devices on one CPU: "
        f"parity, not speedup)",
        f"serve_tp2_kv_peak_bytes_per_device,"
        f"{kv2['kv_peak_bytes_per_device']},"
        f"tp1={kv1['kv_peak_bytes_per_device']}"
        f" block_bytes_per_device={kv2['kv_block_bytes_per_device']}"
        f" (KV-head-sharded pool)",
    ]
    assert identical, "TP=2 engine diverged from TP=1 greedy tokens"
    assert kv2["kv_tp_degree"] == 2 and kv1["kv_tp_degree"] == 1
    assert kv2["kv_block_bytes_per_device"] * 2 \
        == kv1["kv_block_bytes_per_device"], (kv1, kv2)
    return rows


def disagg_rows(smoke: bool = False) -> List[str]:
    """ISSUE 9 acceptance: disaggregated prefill/decode serving.

    The same greedy mix through (a) one unified paged engine and (b) a
    prefill-pool -> KV-handoff -> decode-pool pair driven by the
    gateway's :class:`DisaggRouter` pipelined batch driver — on paged
    GQA AND paged MLA.  Hard asserts: outputs token-identical at
    temperature 0, every prompt exported exactly one handoff and every
    handoff imported, and the ``repro_serving_handoff_*`` counters plus
    the handoff-latency histogram all land in ONE gateway metrics
    snapshot.  TTFT/ITL/tokens-per-s rows are reported for both sides;
    both pools share one CPU here, so the rows demonstrate phase
    separation and token-exactness, not acceleration (the paper's
    point is that the phases want *different* hardware)."""
    from repro.core.gateway import Gateway
    from repro.obs import Observability

    gen = 10 if smoke else 24
    n_req = 6 if smoke else 10
    rows = []
    for tag, (cfg, params) in (("gqa", _tiny()), ("mla", _tiny_mla())):
        rng = np.random.default_rng(41)
        prompts = [list(map(int, rng.integers(1, cfg.vocab_size - 1,
                                              int(rng.integers(8, 24)))))
                   for _ in range(n_req)]
        uni = InferenceEngine(cfg, params, max_batch=4, capacity=192,
                              paged=True)
        ureqs = [Request(prompt=list(p), max_new_tokens=gen)
                 for p in prompts]
        for r in ureqs:
            uni.submit(r)
        su = uni.run_until_idle()
        base = [r.generated for r in ureqs]

        obs = Observability()
        pre = InferenceEngine(cfg, params, max_batch=4, capacity=192,
                              paged=True, role="prefill", obs=obs,
                              name=f"{tag}-prefill0")
        dec = InferenceEngine(cfg, params, max_batch=4, capacity=192,
                              paged=True, role="decode", obs=obs,
                              name=f"{tag}-decode0")
        gw = Gateway(obs=obs)
        router = gw.bind_disagg(cfg.name, [pre], [dec])
        dreqs = [Request(prompt=list(p), max_new_tokens=gen)
                 for p in prompts]
        outs = router.run_pipelined(dreqs)
        sd = dec.metrics.summary()
        sp = pre.metrics.summary()
        identical = int(outs == base)
        snap = gw.collect_metrics().snapshot()
        n_out = snap.get("repro_serving_handoff_exported_total", 0)
        n_in = snap.get("repro_serving_handoff_imported_total", 0)
        n_bytes = snap.get("repro_serving_handoff_bytes_total", 0)
        n_lat = snap.get("repro_serving_handoff_seconds",
                         {}).get("count", 0)
        rows += [
            f"serve_disagg_{tag}_outputs_identical,{identical},"
            f"token-for-token vs unified paged engine at temperature 0 "
            f"(hard assert)",
            f"serve_disagg_{tag}_ttft_p50,{sd['ttft_p50_s'] * 1e6:.0f},"
            f"unified={su['ttft_p50_s'] * 1e6:.0f} (us; disagg TTFT "
            f"includes the handoff import)",
            f"serve_disagg_{tag}_itl_mean,{sd['itl_mean_s'] * 1e6:.0f},"
            f"unified={su['itl_mean_s'] * 1e6:.0f} (us)",
            f"serve_disagg_{tag}_decode_tokens_per_s,"
            f"{sd['tokens_per_s']:.1f},unified={su['tokens_per_s']:.1f}"
            f" (both pools share one CPU: parity, not speedup)",
            f"serve_disagg_{tag}_handoffs,{n_out:.0f},"
            f"imported={n_in:.0f} payload_bytes={n_bytes:.0f}"
            f" latency_samples={n_lat:.0f}",
        ]
        assert identical, f"disagg ({tag}) diverged from unified tokens"
        assert sp["handed_off"] == n_req and sd["completed"] == n_req, (
            sp["handed_off"], sd["completed"])
        assert n_out == n_req and n_in >= n_req, (n_out, n_in)
        assert n_bytes > 0 and n_lat >= n_req, (n_bytes, n_lat)
    return rows


def kv_quant_rows(smoke: bool = False) -> List[str]:
    """ISSUE 10 acceptance: int8 quantized KV serving, same-budget A/B.

    The same greedy mix through a bf16-KV and an int8-KV paged engine
    at a FIXED ``pool_tokens`` budget (bf16-byte-equivalent, so the
    int8 pool carries 2x the blocks in the same device bytes).  Hard
    asserts: the int8 engine sustains >= 1.8x the bf16 engine's peak
    concurrent decode batch, its per-block device bytes land at ~1/2
    (int8 payload + f32 scale sliver), and its greedy tokens match the
    bf16 run at >= 90% per-token agreement (the accuracy-guard floor —
    on these tiny models agreement is typically exact)."""
    budget, capacity = 96, 64
    gen = 10 if smoke else 16
    n_req = 8
    sched = SchedulerConfig(enable_prefix_cache=False, admit_per_tick=8,
                            prefill_chunk=32, prefix_block=8)
    rng = np.random.default_rng(47)
    # 20-token prompts = 3 blocks each at admission: the 12-block bf16
    # pool admits 4, the 24-block int8 pool the full max_batch of 8
    prompts = [list(map(int, rng.integers(1, 255, 20)))
               for _ in range(n_req)]
    cfg, params = _tiny()
    res = {}
    for dt in ("bf16", "int8"):
        eng = InferenceEngine(cfg, params, max_batch=8, capacity=capacity,
                              sched=sched, paged=True,
                              pool_tokens=budget, kv_dtype=dt)
        reqs = [Request(prompt=list(p), max_new_tokens=gen)
                for p in prompts]
        for r in reqs:
            eng.submit(r)
        peak = 0
        while eng.num_active:
            eng.step()
            peak = max(peak, len(eng.running))
        res[dt] = (peak, eng.metrics.summary(), eng.kv_stats(),
                   [r.generated for r in reqs])
    hit = tot = 0
    for a, b in zip(res["int8"][3], res["bf16"][3]):
        tot += len(b)
        hit += sum(1 for x, y in zip(a, b) if x == y)
    match = hit / max(tot, 1)
    gain = res["int8"][0] / max(res["bf16"][0], 1)
    bratio = (res["int8"][2]["kv_block_bytes_per_device"]
              / res["bf16"][2]["kv_block_bytes_per_device"])
    rows = []
    for dt in ("bf16", "int8"):
        peak, s, kv, _ = res[dt]
        rows.append(
            f"serve_kv_{dt}_concurrent_batch_peak,{peak},"
            f"pool_tokens={budget} blocks_total={kv['kv_blocks_total']}"
            f" block_tokens={kv['kv_block_size']}")
        rows.append(
            f"serve_kv_{dt}_block_bytes_per_device,"
            f"{kv['kv_block_bytes_per_device']},"
            f"peak_bytes={kv['kv_peak_bytes_per_device']}")
        rows.append(
            f"serve_kv_{dt}_decode_tokens_per_s,{s['tokens_per_s']:.1f},"
            f"generated={s['generated_tokens']}")
    rows.append(f"serve_kv_int8_batch_gain,{gain:.2f},"
                f"int8_peak/bf16_peak at equal pool_tokens; target>=1.8")
    rows.append(f"serve_kv_int8_block_bytes_ratio,{bratio:.2f},"
                f"int8/bf16 per-block device bytes; target~0.5")
    rows.append(f"serve_kv_int8_match_rate_pct,{match * 100:.1f},"
                f"greedy per-token agreement vs bf16; floor=90")
    assert gain >= 1.8, (
        f"int8 sustained only {gain:.2f}x the bf16 concurrent batch "
        f"({res['int8'][0]} vs {res['bf16'][0]}) at pool_tokens={budget}")
    assert 0.45 < bratio < 0.6, bratio
    assert match >= 0.90, f"int8 KV match rate {match:.2f} below floor"
    return rows


def analytic_itl(arch: str, tp: int, batch: int, ctx: int) -> float:
    """Decode step latency (s) on v5e: max(weights+KV reads / HBM, flops)."""
    cfg = get_config(arch)
    w_bytes = cfg.param_count() * 2 / tp
    kv_per_tok = (cfg.kv_cache_bytes_per_token_per_layer
                  * len(cfg.attn_layer_ids()))
    kv_bytes = kv_per_tok * ctx * batch / tp
    t_mem = (w_bytes + kv_bytes) / HBM_BW
    t_flops = 2 * cfg.param_count(active_only=True) * batch / (tp * PEAK)
    return max(t_mem, t_flops)


def analytic_rows() -> List[str]:
    rows = []
    for arch, tp, paper_ms in (("apertus-8b", 4, 11.0),
                               ("apertus-70b", 8, 42.0)):
        itl = analytic_itl(arch, tp, batch=8, ctx=1024)
        rows.append(f"serve_analytic_itl_{arch},{itl * 1e6:.0f},"
                    f"paper_ms={paper_ms} (GH200; v5e-chips={tp})")
    return rows


def run(paged: Optional[bool] = None, smoke: bool = False) -> List[str]:
    if smoke:
        return (shared_prefix_rows() + paged_vs_dense_rows(smoke=True)
                + multi_adapter_rows(smoke=True)
                + speculative_rows(smoke=True)
                + observability_rows(smoke=True)
                + chaos_rows(smoke=True)
                + sharded_rows(smoke=True)
                + disagg_rows(smoke=True)
                + kv_quant_rows(smoke=True))
    return (measured_rows(paged) + shared_prefix_rows()
            + paged_vs_dense_rows() + multi_adapter_rows()
            + speculative_rows() + observability_rows()
            + chaos_rows() + sharded_rows() + disagg_rows()
            + kv_quant_rows() + analytic_rows())


def rows_to_json(rows: List[str]) -> List[dict]:
    """``name,value,note`` row strings -> structured records (the CI
    build artifact; value stays a string — some rows carry composites)."""
    out = []
    for r in rows:
        parts = r.split(",", 2)
        out.append({"name": parts[0],
                    "value": parts[1] if len(parts) > 1 else "",
                    "note": parts[2] if len(parts) > 2 else ""})
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--paged", action="store_true",
                   help="paged KV for the measured mixes (default)")
    g.add_argument("--dense", action="store_true",
                   help="dense KV for the measured mixes (A/B baseline)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: shared-prefix + paged-vs-dense "
                         "+ multi-LoRA + speculative + obs + chaos + "
                         "sharded TP=2")
    ap.add_argument("--chaos-smoke", action="store_true",
                    help="run ONLY the fault-tolerance chaos mix (the "
                         "CI chaos job)")
    ap.add_argument("--disagg-smoke", action="store_true",
                    help="run ONLY the disaggregated prefill/decode mix "
                         "(the CI disagg job)")
    ap.add_argument("--kv-quant-smoke", action="store_true",
                    help="run ONLY the int8-vs-bf16 quantized-KV A/B "
                         "(the CI kv-quant job)")
    ap.add_argument("--json", default="",
                    help="also write rows as JSON to this path (CI "
                         "uploads it as a build artifact)")
    args = ap.parse_args()
    paged = False if args.dense else True
    if args.chaos_smoke:
        rows = chaos_rows(smoke=True)
    elif args.disagg_smoke:
        rows = disagg_rows(smoke=True)
    elif args.kv_quant_smoke:
        rows = kv_quant_rows(smoke=True)
    else:
        rows = run(paged=paged, smoke=args.smoke)
    print("\n".join(rows))
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump({"smoke": args.smoke, "kv": "paged" if paged
                       else "dense", "rows": rows_to_json(rows)}, f,
                      indent=2)
        print(f"wrote {args.json}")
        if "obs_artifacts" in _STATE:
            # sibling CI artifacts: the observability run's registry
            # snapshot (Prometheus text) and Perfetto trace
            prom, trace_text = _STATE["obs_artifacts"]
            stem = args.json.rsplit(".json", 1)[0]
            for path, text in ((stem + ".metrics.txt", prom),
                               (stem + ".trace.json", trace_text)):
                with open(path, "w", encoding="utf-8") as f:
                    f.write(text)
                print(f"wrote {path}")
