"""Roofline table: reads the dry-run result cache and emits one row per
(arch x shape x mesh) with the three terms + bottleneck (§Roofline source
of truth for EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
import os
from typing import List

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def rows(tag: str = "") -> List[dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        r = json.load(open(fn))
        if r.get("tag", "") != tag or r.get("status") != "ok":
            continue
        out.append(r)
    return out


def run() -> List[str]:
    lines = []
    for tag, label in (("", "baseline"), ("final", "optimized")):
        for r in rows(tag):
            ro = r["roofline"]
            name = f"{r['arch']}|{r['shape']}|{r['mesh']}|{label}"
            lines.append(
                f"roofline_{name},{ro['step_time_bound_s'] * 1e6:.0f},"
                f"bound={ro['bound']};t_comp={ro['t_compute_s']:.4f};"
                f"t_mem={ro['t_memory_s']:.4f};"
                f"t_coll={ro['t_collective_s']:.4f};"
                f"useful={ro['useful_flops_ratio']:.3f}")
    if not lines:
        lines.append("roofline_missing,0,run repro.launch.dryrun first")
    return lines


def markdown_table(tag: str = "") -> str:
    hdr = ("| arch | shape | mesh | t_compute | t_memory | t_collective | "
           "bound | step bound | useful FLOPs |\n"
           "|---|---|---|---|---|---|---|---|---|")
    body = []
    for r in rows(tag):
        ro = r["roofline"]
        body.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {ro['t_compute_s']:.3f}s | {ro['t_memory_s']:.3f}s "
            f"| {ro['t_collective_s']:.3f}s | **{ro['bound']}** "
            f"| {ro['step_time_bound_s']:.3f}s "
            f"| {ro['useful_flops_ratio']:.2f} |")
    return "\n".join([hdr] + body)


if __name__ == "__main__":
    print("\n".join(run()))
