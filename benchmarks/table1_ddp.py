"""Paper Table 1: DDP step time vs network path (ResNet-18/CIFAR scale,
~45 MB of gradients, world size 8).

TPU adaptation (DESIGN.md §2): the *insight* — the collective path, not
compute, dominates small-model DDP — transfers as the choice of gradient
reduction schedule.  Two reproductions:

1. analytic: the paper's four network paths under a (bandwidth,
   per-message overhead) model; reproduces the eth0/hsn0/multi-NIC
   ordering including the multi-NIC *regression* for small payloads.
2. measured: three JAX-native reduction schedules (per-tensor all-reduce,
   bucketed all-reduce, reduce-scatter+all-gather) wall-clocked on an
   8-fake-device host mesh in a subprocess.
"""
from __future__ import annotations

import os
import subprocess
import sys
from typing import Dict, List, Tuple

GRAD_BYTES = 45e6          # paper: ResNet-18 allreduce payload ~45 MB
WORLD = 8                  # 2 nodes x 4 GPUs
COMPUTE_MS = 3.0           # fwd/bwd of ResNet-18 on H100 at bs 256, approx

# (name, per-link bandwidth B/s, links, per-message overhead s, messages)
# eth0: management overlay, high stack overhead; hsn0 TCP: one 200 Gb NIC;
# hsn0-3 TCP: 4 sockets but per-message overhead x4 on a 45 MB payload;
# CXI RDMA: kernel-bypass tiny overhead. Ring all-reduce: 2(n-1)/n * bytes.
PATHS = [
    ("eth0_tcp", 25e9 / 8, 1, 6e-3, 25),
    ("hsn0_tcp", 200e9 / 8, 1, 1.2e-3, 25),
    ("hsn0-3_tcp", 200e9 / 8, 4, 1.2e-3, 50),  # 4 streams ~ 2x messages
    ("cxi_rdma", 200e9 / 8, 4, 15e-6, 25),
]
PAPER_MS = {"eth0_tcp": 190.0, "hsn0_tcp": 58.0, "hsn0-3_tcp": 79.0,
            "cxi_rdma": 4.0}


def analytic_rows() -> List[Tuple[str, float, float]]:
    out = []
    wire = 2 * (WORLD - 1) / WORLD * GRAD_BYTES
    for name, bw, links, overhead, msgs in PATHS:
        t = wire / (bw * links) + overhead * msgs + COMPUTE_MS / 1e3
        out.append((name, t * 1e3, PAPER_MS[name]))
    return out


_MEASURE_SRC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = jax.make_mesh((8,), ("dp",))
repl = NamedSharding(mesh, P())
shard = NamedSharding(mesh, P("dp"))
# ~45 MB of "gradients" in 25 tensors (sizes divisible by 64 so the
# tiled reduce-scatter shards evenly on the 8-way mesh)
sizes = [450_048] * 25
grads = [jax.device_put(jnp.ones((s,), jnp.float32), shard)
         for s in sizes]

def timeit(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3

def make(fn):
    return jax.jit(fn)

def psum_shardmap_per_tensor(gs):
    f = jax.shard_map(lambda *xs: tuple(jax.lax.psum(x, "dp") for x in xs),
                      mesh=mesh, in_specs=(P("dp"),) * len(gs),
                      out_specs=(P("dp"),) * len(gs))
    return f(*gs)

def psum_bucketed(gs):
    flat = jnp.concatenate(gs)
    f = jax.shard_map(lambda x: jax.lax.psum(x, "dp"), mesh=mesh,
                      in_specs=P("dp"), out_specs=P("dp"))
    return f(flat)

def rs_ag(gs):
    flat = jnp.concatenate(gs)
    def inner(x):
        r = jax.lax.psum_scatter(x, "dp", scatter_dimension=0, tiled=True)
        return jax.lax.all_gather(r, "dp", tiled=True)
    f = jax.shard_map(inner, mesh=mesh, in_specs=P("dp"), out_specs=P("dp"))
    return f(flat)

rows = [
    ("measured_per_tensor_psum", timeit(make(psum_shardmap_per_tensor), grads)),
    ("measured_bucketed_psum", timeit(make(psum_bucketed), grads)),
    ("measured_rs_ag", timeit(make(rs_ag), grads)),
]
for n, ms in rows:
    print(f"ROW,{n},{ms:.3f}")
"""


def measured_rows() -> List[Tuple[str, float]]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _MEASURE_SRC], env=env,
                         capture_output=True, text=True, timeout=900)
    rows = []
    for line in out.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, ms = line.split(",")
            rows.append((name, float(ms)))
    if not rows:
        raise RuntimeError(out.stderr[-2000:])
    return rows


def run() -> List[str]:
    lines = []
    for name, ms, paper in analytic_rows():
        lines.append(f"table1_analytic_{name},{ms * 1e3:.1f},"
                     f"paper_ms={paper}")
    for name, ms in measured_rows():
        lines.append(f"table1_{name},{ms * 1e3:.1f},host_mesh_8dev")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
