"""Per-kernel microbench: interpret-mode wall time (CPU correctness path)
vs the pure-jnp oracle, plus the kernel's analytic FLOPs and VMEM tile
footprint for the TPU target."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_ref
from repro.kernels.paged_attention.kernel import (
    paged_decode_attention, paged_decode_attention_int8,
    paged_verify_attention, paged_verify_attention_int8)
from repro.kernels.paged_attention.ref import (
    paged_decode_int8_ref, paged_decode_ref, paged_verify_ref)
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd.kernel import ssd_scan
from repro.kernels.ssd.ref import ssd_ref


def _t(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run() -> List[str]:
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention: B1 H8/KV2 S512 D64, blocks 128x128
    B, H, KV, S, D = 1, 8, 2, 512, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, D), jnp.float32)
    t_kern = _t(jax.jit(lambda q, k, v: flash_attention(
        q, k, v, causal=True, interpret=True)), q, k, v)
    t_ref = _t(jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True)),
               q, k, v)
    flops = 4 * B * H * S * S * D // 2  # causal
    vmem = (128 * D + 128 * D * 2 + 128 * D + 128 * 2) * 4
    rows.append(f"kernel_flash_interpret,{t_kern:.0f},"
                f"ref_us={t_ref:.0f};flops={flops};tile_vmem_B={vmem}")

    # decode attention: B4 H16/KV8 S4096 D128
    B, H, KV, S, D = 4, 16, 8, 4096, 128
    ks = jax.random.split(key, 4)
    q1 = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    lens = jnp.full((B,), S, jnp.int32)
    t_kern = _t(jax.jit(lambda a, b, c, l: decode_attention(
        a, b, c, l, interpret=True)), q1, kc, vc, lens)
    t_ref = _t(jax.jit(decode_ref), q1, kc, vc, lens)
    hbm = 2 * B * S * KV * D * 4
    rows.append(f"kernel_decode_interpret,{t_kern:.0f},"
                f"ref_us={t_ref:.0f};kv_bytes={hbm}")

    # paged vs dense decode attention: same logical sequences, KV split
    # into a permuted physical block pool (B4 H16/KV8 S2048 D128, bs 256)
    B, H, KV, S, D, bs = 4, 16, 8, 2048, 128, 256
    W = S // bs
    ks = jax.random.split(key, 3)
    q1 = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    lens = jnp.full((B,), S, jnp.int32)
    rng = np.random.default_rng(0)
    perm = rng.permutation(np.arange(1, 1 + B * W))
    kp = np.zeros((1 + B * W, bs, KV, D), np.float32)
    vp = np.zeros_like(kp)
    bt = np.zeros((B, W), np.int32)
    it = iter(perm)
    for b in range(B):
        for j in range(W):
            pid = int(next(it))
            kp[pid] = np.asarray(kc[b, j * bs:(j + 1) * bs])
            vp[pid] = np.asarray(vc[b, j * bs:(j + 1) * bs])
            bt[b, j] = pid
    kp, vp, bt = jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(bt)
    t_paged = _t(jax.jit(lambda a, k, v, t, l: paged_decode_attention(
        a, k, v, t, l, interpret=True)), q1, kp, vp, bt, lens)
    t_dense = _t(jax.jit(lambda a, b2, c, l: decode_attention(
        a, b2, c, l, blk_k=bs, interpret=True)), q1, kc, vc, lens)
    t_pref = _t(jax.jit(paged_decode_ref), q1, kp, vp, bt, lens)
    err = float(jnp.max(jnp.abs(
        paged_decode_attention(q1, kp, vp, bt, lens, interpret=True)
        - decode_attention(q1, kc, vc, lens, blk_k=bs, interpret=True))))
    rows.append(f"kernel_paged_decode_interpret,{t_paged:.0f},"
                f"dense_us={t_dense:.0f};gather_ref_us={t_pref:.0f};"
                f"max_err_vs_dense={err:.1e};block_tokens={bs}")

    # int8 paged decode/verify: fused-dequant kernels on the same pool,
    # quantized per-block-per-head (KV bytes halve; scales are a sliver)
    ksc = (np.abs(np.asarray(kp)).max(axis=(1, 3)) / 127.0).astype(
        np.float32)
    vsc = (np.abs(np.asarray(vp)).max(axis=(1, 3)) / 127.0).astype(
        np.float32)
    kq = jnp.asarray(np.clip(np.round(
        np.asarray(kp) / np.maximum(ksc, 1e-12)[:, None, :, None]),
        -127, 127).astype(np.int8))
    vq = jnp.asarray(np.clip(np.round(
        np.asarray(vp) / np.maximum(vsc, 1e-12)[:, None, :, None]),
        -127, 127).astype(np.int8))
    ksc, vsc = jnp.asarray(ksc), jnp.asarray(vsc)
    t_q8 = _t(jax.jit(lambda a, k, v, s1, s2, t, l:
                      paged_decode_attention_int8(
                          a, k, v, s1, s2, t, l, interpret=True)),
              q1, kq, vq, ksc, vsc, bt, lens)
    t_q8ref = _t(jax.jit(paged_decode_int8_ref), q1, kq, vq, ksc, vsc,
                 bt, lens)
    err8 = float(jnp.max(jnp.abs(
        paged_decode_attention_int8(q1, kq, vq, ksc, vsc, bt, lens,
                                    interpret=True)
        - decode_attention(q1, kc, vc, lens, blk_k=bs, interpret=True))))
    kv_b16 = 2 * kp.size * 2          # the serving pool stores bf16
    kv_b8 = kq.nbytes + vq.nbytes + ksc.nbytes + vsc.nbytes
    rows.append(f"kernel_paged_decode_int8_interpret,{t_q8:.0f},"
                f"bf16_us={t_paged:.0f};deq_ref_us={t_q8ref:.0f};"
                f"max_err_vs_fp={err8:.1e};"
                f"kv_bytes_ratio={kv_b8 / kv_b16:.2f}")
    T = 4
    qt = jax.random.normal(jax.random.split(key, 5)[4], (B, T, H, D),
                           jnp.float32)
    t_v16 = _t(jax.jit(lambda a, k, v, t, l: paged_verify_attention(
        a, k, v, t, l, interpret=True)), qt, kp, vp, bt, lens)
    t_v8 = _t(jax.jit(lambda a, k, v, s1, s2, t, l:
                      paged_verify_attention_int8(
                          a, k, v, s1, s2, t, l, interpret=True)),
              qt, kq, vq, ksc, vsc, bt, lens)
    errv = float(jnp.max(jnp.abs(
        paged_verify_attention_int8(qt, kq, vq, ksc, vsc, bt, lens,
                                    interpret=True)
        - paged_verify_ref(qt, kp, vp, bt, lens))))
    rows.append(f"kernel_paged_verify_int8_interpret,{t_v8:.0f},"
                f"bf16_us={t_v16:.0f};max_err_vs_fp={errv:.1e};"
                f"verify_tokens={T}")

    # ssd: BH8 L1024 P64 N64 chunk 128
    BH, L, P, N = 8, 1024, 64, 64
    ks = jax.random.split(key, 4)
    xdt = jax.random.normal(ks[0], (BH, L, P)) * 0.5
    dA = -jnp.abs(jax.random.normal(ks[1], (BH, L))) * 0.1
    Bm = jax.random.normal(ks[2], (BH, L, N)) * 0.3
    Cm = jax.random.normal(ks[3], (BH, L, N)) * 0.3
    t_kern = _t(jax.jit(lambda a, b, c, d: ssd_scan(
        a, b, c, d, chunk=128, interpret=True)), xdt, dA, Bm, Cm)
    t_ref = _t(jax.jit(ssd_ref), xdt, dA, Bm, Cm)
    rows.append(f"kernel_ssd_interpret,{t_kern:.0f},ref_us={t_ref:.0f};"
                f"chunk=128")

    # rmsnorm: 8192 x 1024
    x = jax.random.normal(key, (8192, 1024), jnp.float32)
    w = jnp.ones((1024,))
    t_kern = _t(jax.jit(lambda x, w: rmsnorm(x, w, interpret=True)), x, w)
    t_ref = _t(jax.jit(rmsnorm_ref), x, w)
    rows.append(f"kernel_rmsnorm_interpret,{t_kern:.0f},ref_us={t_ref:.0f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
