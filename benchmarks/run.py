"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Heavy roofline data comes from
the dry-run cache (``python -m repro.launch.dryrun --all``); everything
else runs at CPU-tiny scale here.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (inference_metrics, kernels_bench,
                            roofline_report, table1_ddp, throughput)
    print("name,us_per_call,derived")
    sections = [
        ("table1", table1_ddp.run),
        ("inference", inference_metrics.run),
        ("throughput", throughput.run),
        ("kernels", kernels_bench.run),
        ("roofline", roofline_report.run),
    ]
    failures = 0
    for name, fn in sections:
        try:
            for line in fn():
                print(line, flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}_FAILED,0,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
